"""Fig 7 + Table 6: offloaded decoding speed, PowerInfer-2 vs the
llama.cpp / LLMFlash analogues, ReLU vs SiLU sparsity modes.

Engine benches: the real reduced model decodes under each SystemSpec
with 50% FFN offload; speeds are the modeled effective tok/s from the
storage plane (UFS 4.0 tier, real activation traces).
"""

from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import ALL_SYSTEMS, POWERINFER2, LLMFLASH
from repro.serving.engine import ServeEngine


def run_spec(cfg, params, plan, prompt, spec, offload=0.5, max_new=16):
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=offload,
                      timing=paper_timing())
    res = eng.generate(prompt, max_new=max_new, temperature=0.8)
    return res


def main():
    rows = []
    # Fig 7: three systems on the ReLU2 (bamboo-like) model
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    speeds = {}
    for spec in ALL_SYSTEMS:
        res = run_spec(cfg, params, plan, prompt[:1], spec)
        speeds[spec.name] = res.tokens_per_s
        rows.append((f"fig7_decode_{spec.name}", round(res.tokens_per_s, 2),
                     "modeled tok/s, 50% FFN offload, UFS4.0"))
    rows.append(("fig7_speedup_vs_llamacpp",
                 round(speeds["powerinfer-2"] / speeds["llama.cpp-mmap"], 2),
                 "paper: 24.6x avg (trained 7B); reduced-model analogue"))
    rows.append(("fig7_speedup_vs_llmflash",
                 round(speeds["powerinfer-2"] / speeds["llmflash"], 2),
                 "paper: 3.84x avg"))

    # Table 6: SiLU (CATS-mode) variant — smaller but real speedup
    cfg_s, _, params_s, plan_s, prompt_s = engine_setup(
        "smollm-135m", activation="silu", mode="cats", seed=1)
    pi2 = run_spec(cfg_s, params_s, plan_s, prompt_s[:1], POWERINFER2)
    lf = run_spec(cfg_s, params_s, plan_s, prompt_s[:1], LLMFLASH)
    rows.append(("table6_silu_speedup",
                 round(pi2.tokens_per_s / lf.tokens_per_s, 2),
                 "paper: 2.4x on Mistral(SiLU)-7B"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
