"""Fig 13: Best-of-N decoding with dynamic batch decay.

N=4 candidates; one finishes every four iterations. The hybrid engine
(XPU) must beat the CPU-only configuration at every phase, with the
gap largest at high batch (dense union) — the paper's dynamic
adaptation claim."""
import dataclasses


from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import POWERINFER2
from repro.serving.engine import ServeEngine


def main():
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    cpu_only = dataclasses.replace(POWERINFER2, name="powerinfer2-cpuonly",
                                   hybrid_engines=False)
    rows = []
    speeds = {}
    for spec in (POWERINFER2, cpu_only):
        # Fig 13 is the IN-MEMORY setting: all params resident
        eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.0,
                          timing=paper_timing())
        res = eng.generate(prompt, max_new=16, temperature=0.8,
                           completion_schedule={3: 1, 7: 1, 11: 1})
        # phase speeds: batch 4 (steps 0-3) vs batch 1 (steps 12+)
        b4 = [s for s in res.stats if s.batch == 4]
        b1 = [s for s in res.stats if s.batch == 1]
        tps = lambda ss: (sum(s.batch for s in ss)
                          / max(sum(s.effective_s for s in ss), 1e-12))
        speeds[spec.name] = (tps(b4), tps(b1))
        rows.append((f"fig13_{spec.name}_batch4", round(tps(b4), 1),
                     "modeled tok/s at N=4"))
        rows.append((f"fig13_{spec.name}_batch1", round(tps(b1), 1),
                     "modeled tok/s at N=1"))
    adv4 = speeds["powerinfer-2"][0] / max(speeds["powerinfer2-cpuonly"][0],
                                           1e-12)
    adv1 = speeds["powerinfer-2"][1] / max(speeds["powerinfer2-cpuonly"][1],
                                           1e-12)
    rows.append(("fig13_hybrid_adv_batch4", round(adv4, 2),
                 "paper: 1.28x over CPU-only at N=4"))
    rows.append(("fig13_hybrid_adv_batch1", round(adv1, 2),
                 "paper: 1.1x at N=1"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
