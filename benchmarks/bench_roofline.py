"""Deliverable (g) surface: roofline terms per (arch × shape) from the
dry-run artifacts, plus the Table 8 energy proxy (J/token from the
bound time × chip power)."""
import os

from benchmarks.common import emit
from repro.launch.roofline import load_table

CHIP_W = 170.0   # v5e ~ per-chip board power (proxy for Table 8)


def main():
    art = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")
    rows_out = []
    rows = load_table(art, "16x16")
    if not rows:
        rows_out.append(("roofline_rows", 0,
                         "run launch/dryrun first (artifacts missing)"))
        emit(rows_out)
        return rows_out
    for r in rows:
        rows_out.append((f"roofline_{r['arch']}_{r['shape']}",
                         r["bound_time_s"],
                         f"bound={r['dominant']} useful={r['useful_ratio']}"))
    decode = [r for r in rows if r["shape"] == "decode_32k"]
    for r in decode:
        tokens = 128.0
        j_tok = r["bound_time_s"] * 256 * CHIP_W / tokens
        rows_out.append((f"table8_energy_proxy_{r['arch']}",
                         round(j_tok, 4), "J/token (roofline x 170W/chip)"))
    emit(rows_out)
    return rows_out


if __name__ == "__main__":
    main()
