"""Fig 14: ablation ladder — baseline -> +bundle -> +cache -> +pipeline
-> +xpu (hybrid). Paper: 0.4 -> 1.1 -> 4.18 -> 9.60 -> 11.07 tok/s."""
from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import ABLATION_LADDER
from repro.serving.engine import ServeEngine


def main():
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    rows = []
    prev = None
    for spec in ABLATION_LADDER:
        eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                          timing=paper_timing())
        res = eng.generate(prompt[:1], max_new=16, temperature=0.8)
        gain = "" if prev is None else f"{res.tokens_per_s/prev:.2f}x step"
        rows.append((f"fig14_{spec.name.replace('+','plus_')}",
                     round(res.tokens_per_s, 2),
                     f"modeled tok/s {gain}"))
        prev = res.tokens_per_s
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
