"""Table 5 / Fig 11: token-latency distribution (mean/P50/P90/P99).

Cache-miss variance between consecutive tokens drives the tail (the
paper: P99 40.9% above mean, P99 miss rate 18.9% vs 3.5% average)."""
import numpy as np

from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import POWERINFER2
from repro.serving.engine import ServeEngine


def main():
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, timing=paper_timing())
    res = eng.generate(prompt[:1], max_new=64, temperature=0.8)
    # steady state: drop cold-start warmup tokens (the paper measures
    # 1,024-token generations)
    import dataclasses as _dc
    steady = _dc.replace(res, stats=res.stats[8:])
    pct = steady.latency_percentiles()
    hits = [s.cache_hit_rate for s in steady.stats]
    rows = [
        ("table5_mean_ms", round(pct["mean"] * 1e3, 3), "modeled"),
        ("table5_p50_ms", round(pct["p50"] * 1e3, 3), "modeled"),
        ("table5_p90_ms", round(pct["p90"] * 1e3, 3), "modeled"),
        ("table5_p99_ms", round(pct["p99"] * 1e3, 3),
         f"paper: p99 40.9% over mean; here "
         f"{(pct['p99']/max(pct['mean'],1e-12)-1)*100:.0f}%"),
        ("table5_avg_hit_rate", round(float(np.mean(hits)), 3),
         "paper: 96.5% avg (3.5% miss)"),
        ("table5_p99_miss_rate",
         round(float(np.percentile([1 - h for h in hits], 99)), 3),
         "paper: 18.9% P99 miss"),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
