"""Fig 2: neuron activation union vs batch size.

Profiles real activations of the reduced ReLU² model, then reports the
fraction of neurons whose *union* activation probability across a
batch exceeds 0.5 — the paper's hot-spot growth (<1% at batch 1 to
~75% at batch 32 for trained models; synthetic Zipf shows the shape).
"""
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.planner import synthetic_frequencies


def main():
    cfg = get_config("bamboo-7b")
    freqs = synthetic_frequencies(cfg, seed=0)     # (L, N) per-token
    mean_f = np.sort(freqs.mean(0))[::-1]
    rows = []
    prev = 0.0
    for b in (1, 2, 4, 8, 16, 32):
        union = 1.0 - (1.0 - mean_f) ** b
        hot_frac = float((union > 0.5).mean())
        rows.append((f"fig2_hot_fraction_b{b}", round(hot_frac, 4),
                     f"union>0.5 at batch {b}"))
        assert hot_frac >= prev
        prev = hot_frac
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
