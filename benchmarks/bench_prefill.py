"""Fig 8: prefill speed — NPU-centric with sequential-I/O prefetch
(PowerInfer-2) vs QNN-analogue (NPU, no I/O overlap) vs llama.cpp
(CPU engine).

Analytic over the paper's own Bamboo-7B-size config: per-layer compute
time from FLOPs/engine rate; per-layer weight-streaming time from the
StorageModel at sequential bandwidth; PowerInfer-2 overlaps the next
layer's load with the current layer's compute (Fig 9)."""
from benchmarks.common import emit
from repro.configs.paper_models import BAMBOO_7B
from repro.core.io_model import UFS40
from repro.core.planner import HardwareProfile


def prefill_tok_s(cfg, prompt_len, engine_flops, overlap, offload=0.5,
                  storage=UFS40):
    L = cfg.num_layers
    R = 3
    ffn_flops = 2 * R * cfg.d_model * cfg.d_ff
    attn_flops = 4 * cfg.d_model * (cfg.num_heads + 2 * cfg.num_kv_heads) \
        * cfg.d_head + 4 * cfg.num_heads * cfg.d_head * prompt_len
    t_comp_layer = prompt_len * (ffn_flops + attn_flops) / engine_flops
    layer_bytes = (ffn_flops / 2) * offload * 0.5   # int4: 0.5 B/param
    t_io_layer = storage.read_time(int(layer_bytes), 524288, random=False)
    if overlap:
        t_layer = max(t_comp_layer, t_io_layer)     # Fig 9: fully hidden
    else:
        t_layer = t_comp_layer + t_io_layer
    return prompt_len / (L * t_layer)


def main():
    hw = HardwareProfile()
    rows = []
    for P in (128, 512):
        pi2 = prefill_tok_s(BAMBOO_7B, P, hw.dense_engine_flops, True)
        qnn = prefill_tok_s(BAMBOO_7B, P, hw.dense_engine_flops, False)
        lcpp = prefill_tok_s(BAMBOO_7B, P, hw.sparse_engine_flops, False)
        rows.append((f"fig8_prefill{P}_powerinfer2", round(pi2, 1),
                     "tok/s, NPU+overlapped seq I/O"))
        rows.append((f"fig8_prefill{P}_qnn", round(qnn, 1),
                     "tok/s, NPU, no overlap"))
        rows.append((f"fig8_prefill{P}_llamacpp", round(lcpp, 1),
                     "tok/s, CPU engine"))
        rows.append((f"fig8_prefill{P}_speedup_vs_qnn",
                     round(pi2 / qnn, 2), "paper: 1.99x at 512"))
        rows.append((f"fig8_prefill{P}_speedup_vs_llamacpp",
                     round(pi2 / lcpp, 2), "paper: ~44x at 512"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
