"""Table 7: model-quality preservation.

Two components:
  * quantization schemes on outlier-heavy weights — relative error of
    llama.cpp group-32 vs QNN per-channel vs PowerInfer-2 mixed;
  * hybrid hot/cold FFN fidelity — KL(dense || hybrid) of real decode
    logits and top-1 agreement at increasing cold budgets.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, engine_setup
from repro.core.clusters import HybridPlan
from repro.quant.quantize import quant_error


def main():
    rows = []
    # --- quantization (outlier-heavy weights) ---
    key = jax.random.key(0)
    w = jax.random.normal(key, (256, 512)) * 0.02
    mask = jax.random.bernoulli(jax.random.key(1), 0.005, w.shape)
    w = jnp.where(mask, w * 50.0, w)
    for scheme, kw, who in (("group32", {"group": 32}, "llama.cpp"),
                            ("per_channel", {}, "QNN"),
                            ("mixed", {"outlier_frac": 0.01},
                             "PowerInfer-2")):
        rows.append((f"table7_quant_err_{scheme}",
                     round(quant_error(w, scheme, **kw), 4),
                     f"{who} scheme, rel. Frobenius"))

    # --- hybrid FFN fidelity on real decode logits ---
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    step_dense = jax.jit(lambda p, t, c: model.decode_step(p, t, c, None))
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=20))(
        params, {"tokens": jnp.asarray(prompt[:2])})
    tok = jnp.asarray(prompt[:2, -1:])
    ref_logits, _ = step_dense(params, tok, cache)
    ref = jax.nn.log_softmax(ref_logits[:, 0].astype(jnp.float32))
    N = cfg.d_ff
    for ratio in (0.25, 0.5, 1.0):
        hp = HybridPlan(n_hot=int(N * 0.25) // 32 * 32,
                        k_cold=max(int(N * 0.75 * ratio) // 32 * 32, 32),
                        groups=1, cluster_size=32)
        step_h = jax.jit(lambda p, t, c: model.decode_step(p, t, c, hp))
        lg, _ = step_h(params, tok, cache)
        q = jax.nn.log_softmax(lg[:, 0].astype(jnp.float32))
        kl = float(jnp.sum(jnp.exp(ref) * (ref - q), -1).mean())
        agree = float((jnp.argmax(ref, -1) == jnp.argmax(q, -1)).mean())
        rows.append((f"table7_hybrid_kl_cold{int(ratio*100)}",
                     round(kl, 4), f"top1 agree {agree:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
