"""Fig 6: matrix-level vs neuron-cluster-level pipeline.

Discrete-event simulation with the paper's 4-compute + 1-I/O worker
layout across compute/I-O balance regimes. The cluster pipeline's win
is largest when compute and I/O are comparable (the offloaded-decode
regime) and it eliminates the per-matrix bubbles entirely in the
compute-bound regime.
"""
from benchmarks.common import emit
from repro.core.pipeline import make_decode_tasks, simulate_pipeline


def main():
    rows = []
    # 8 matrices (Gate/Up/Down x layers slice), 8 clusters each, half cached
    for tag, comp, io in (("compute_bound", 2.0, 1.0),
                          ("balanced", 1.0, 1.0),
                          ("io_bound", 0.5, 1.0)):
        tasks = make_decode_tasks(8, 8, 0.5, comp_time=comp, io_time=io,
                                  seed=1)
        rm = simulate_pipeline(tasks, n_compute=4, policy="matrix")
        rc = simulate_pipeline(tasks, n_compute=4, policy="cluster")
        rows.append((f"fig6_speedup_{tag}",
                     round(rm.makespan / rc.makespan, 3),
                     f"matrix {rm.makespan:.1f}s -> cluster "
                     f"{rc.makespan:.1f}s; io_frac "
                     f"{rm.io_fraction:.2f}->{rc.io_fraction:.2f}"))
    # cache-hit sweep at the balanced point
    for frac in (0.25, 0.5, 0.75, 0.95):
        tasks = make_decode_tasks(8, 8, frac, comp_time=1.0, io_time=1.0,
                                  seed=2)
        rm = simulate_pipeline(tasks, n_compute=4, policy="matrix")
        rc = simulate_pipeline(tasks, n_compute=4, policy="cluster")
        rows.append((f"fig6_speedup_cached{int(frac*100)}",
                     round(rm.makespan / rc.makespan, 3),
                     f"{int(frac*100)}% clusters cached"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
