"""Continuous-batching serving benchmark: throughput, TTFT and
per-token latency percentiles under a request stream.

A deterministic arrival schedule (seeded exponential inter-arrivals —
Poisson-like traffic on the modeled clock) drives the engine's
submit/step loop for each SystemSpec. Requests join the running batch
at decoder bucket boundaries (prefill-on-admit into free KV slots) and
leave as they complete, so the batch-size timeline — the signal the
paper's dynamic CPU/NPU adaptation consumes (§4.1.3) — moves both ways
under load.

All latencies are the storage plane's modeled effective seconds, so
llama.cpp-analogue vs PowerInfer-2 differences reflect the paper's
mechanisms, not host jit noise.
"""
import numpy as np

from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import LLAMACPP, POWERINFER2
from repro.serving.engine import ServeEngine

N_REQUESTS = 10
PROMPT_LEN = 16
MEAN_INTERARRIVAL_S = 2e-3
BUCKETS = (1, 2, 4, 8)


def run_spec(cfg, params, plan, spec, seed=0):
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                      timing=paper_timing(), buckets=BUCKETS,
                      ctx_budget=PROMPT_LEN + 16, temperature=0.8)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, N_REQUESTS))
    for t in arrivals:
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN),
                   max_new=int(rng.integers(6, 14)), arrival_time=float(t))
    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    return eng, rep


def main():
    rows = []
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    print(f"{'system':16s} {'tok/s':>10s} {'ttft-ms':>9s} {'p50-ms':>8s} "
          f"{'p90-ms':>8s} {'p99-ms':>8s} {'peak-batch':>10s}")
    for spec in (LLAMACPP, POWERINFER2):
        eng, rep = run_spec(cfg, params, plan, spec)
        pct = rep.latency_percentiles()
        ttft = float(rep.ttft().mean())
        peak = max(s.batch for s in rep.stats)
        print(f"{spec.name:16s} {rep.tokens_per_s:10.1f} "
              f"{ttft * 1e3:9.3f} {pct['p50'] * 1e3:8.3f} "
              f"{pct['p90'] * 1e3:8.3f} {pct['p99'] * 1e3:8.3f} "
              f"{peak:10d}")
        tag = spec.name.replace(".", "").replace("-", "_")
        rows.append((f"serving_tok_s_{tag}", round(rep.tokens_per_s, 2),
                     f"{N_REQUESTS} reqs, Poisson-like arrivals, "
                     f"50% offload"))
        rows.append((f"serving_ttft_ms_{tag}", round(ttft * 1e3, 4),
                     "mean time-to-first-token (modeled, incl prefill)"))
        rows.append((f"serving_p99_ms_{tag}", round(pct['p99'] * 1e3, 4),
                     f"p50 {pct['p50'] * 1e3:.4f} p90 "
                     f"{pct['p90'] * 1e3:.4f}"))
        rows.append((f"serving_batch_growth_{tag}",
                     f"{eng.sched.batch_history[0]}->{peak}",
                     "continuous batching: batch grew under load then "
                     "drained"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
