"""Continuous-batching serving benchmark: throughput, TTFT and
per-token latency percentiles under a request stream — single-device
spec comparison plus tensor-parallel mesh scaling.

A deterministic arrival schedule (seeded exponential inter-arrivals —
Poisson-like traffic on the modeled clock) drives the engine's
submit/step loop. Part 1 compares SystemSpecs (llama.cpp-analogue vs
PowerInfer-2) on one device; part 2 runs the PowerInfer-2 spec over
1/2/4/...-device meshes (same grouped plan everywhere, so cluster
selection — and the decoded tokens — are identical across mesh sizes)
and reports per-device-count throughput/TTFT.

All latencies are the storage plane's modeled effective seconds, so
differences reflect the paper's mechanisms (and the mesh split), not
host jit noise.

CLI (also runnable argless via benchmarks.run):
  python -m benchmarks.bench_serving --devices 2 --tiny \
      --json BENCH_serving_2dev.json
--devices N forces N host platform devices when jax is not yet
initialized (CI smoke); --json writes the machine-readable results.
"""
import argparse
import json
import os
import sys

N_REQUESTS = 10
PROMPT_LEN = 16
MEAN_INTERARRIVAL_S = 2e-3
BUCKETS = (1, 2, 4, 8)


def _scaled_plan(cfg, plan, groups: int):
    """Copy `plan` with per-bucket plans regrouped `groups`-way (the
    operating point benchmarks/common pins, cold region group-aligned
    so every divisor mesh size owns whole groups)."""
    import copy
    from repro.core.clusters import make_plan, scale_plan_for_batch
    cs = cfg.sparse_ffn.cluster_size
    base = make_plan(cfg.d_ff, 0.125, 0.10, cs, groups=groups)
    plan = copy.copy(plan)
    plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b, cs)
                  for b in (1, 2, 4, 8, 16, 32)}
    return plan


def _request_stream(cfg, eng, n_requests, max_new_hi, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    for t in arrivals:
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN),
                   max_new=int(rng.integers(6, max_new_hi)),
                   arrival_time=float(t))


def run_spec(cfg, params, plan, spec, seed=0, mesh=None, n_requests=None,
             max_new_hi=14):
    from benchmarks.common import paper_timing
    from repro.serving.engine import ServeEngine
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                      timing=paper_timing(), buckets=BUCKETS,
                      ctx_budget=PROMPT_LEN + 16, temperature=0.8,
                      mesh=mesh)
    _request_stream(cfg, eng, n_requests or N_REQUESTS, max_new_hi, seed)
    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    return eng, rep


def _summary(eng, rep):
    pct = rep.latency_percentiles()
    return {
        "tok_s": round(rep.tokens_per_s, 2),
        "ttft_ms": round(float(rep.ttft().mean()) * 1e3, 4),
        "p50_ms": round(pct["p50"] * 1e3, 4),
        "p90_ms": round(pct["p90"] * 1e3, 4),
        "p99_ms": round(pct["p99"] * 1e3, 4),
        "peak_batch": max(s.batch for s in rep.stats),
        "n_shards": rep.stats[0].n_shards,
        "tokens": {int(u): [int(t) for t in r.generated]
                   for u, r in eng.sched.sequences.items()},
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host platform devices (pre-jax-init "
                         "only); mesh sizes are the divisor chain up "
                         "to N")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer/shorter requests")
    ap.add_argument("--json", default=None,
                    help="write results JSON (BENCH_*.json artifact)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:]
                         if __name__ == "__main__" else [])

    if args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()

    import jax
    import numpy as np
    from benchmarks.common import emit, engine_setup
    from repro.core.baselines import LLAMACPP, POWERINFER2
    from repro.launch.mesh import make_serving_mesh

    n_req = 4 if args.tiny else N_REQUESTS
    max_new_hi = 8 if args.tiny else 14
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu",
        train_steps=10 if args.tiny else 40)
    rows, out = [], {"bench": "serving", "tiny": bool(args.tiny),
                     "device_count": jax.device_count(), "results": []}

    # ---- part 1: spec comparison, single device --------------------------
    print(f"{'system':16s} {'tp':>3s} {'tok/s':>10s} {'ttft-ms':>9s} "
          f"{'p50-ms':>8s} {'p90-ms':>8s} {'p99-ms':>8s} {'peak':>5s}")
    for spec in (LLAMACPP, POWERINFER2):
        eng, rep = run_spec(cfg, params, plan, spec, n_requests=n_req,
                            max_new_hi=max_new_hi)
        s = _summary(eng, rep)
        eng.close()
        print(f"{spec.name:16s} {1:3d} {s['tok_s']:10.1f} "
              f"{s['ttft_ms']:9.3f} {s['p50_ms']:8.3f} "
              f"{s['p90_ms']:8.3f} {s['p99_ms']:8.3f} "
              f"{s['peak_batch']:5d}")
        tag = spec.name.replace(".", "").replace("-", "_")
        rows.append((f"serving_tok_s_{tag}", s["tok_s"],
                     f"{n_req} reqs, Poisson-like arrivals, 50% offload"))
        rows.append((f"serving_ttft_ms_{tag}", s["ttft_ms"],
                     "mean time-to-first-token (modeled, incl prefill)"))
        rows.append((f"serving_p99_ms_{tag}", s["p99_ms"],
                     f"p50 {s['p50_ms']} p90 {s['p90_ms']}"))
        rows.append((f"serving_batch_growth_{tag}",
                     f"{eng.sched.batch_history[0]}->{s['peak_batch']}",
                     "continuous batching: batch grew under load then "
                     "drained"))
        out["results"].append(dict(s, system=spec.name, tp=1,
                                   tokens=None))

    # ---- part 2: tensor-parallel mesh scaling ----------------------------
    tp_sizes = [n for n in (1, 2, 4, 8) if n <= jax.device_count()]
    groups = max(tp_sizes)
    tokens_ref = None
    if groups > 1:
        tp_plan = _scaled_plan(cfg, plan, groups)
        for n in tp_sizes:
            mesh = make_serving_mesh(n) if n > 1 else None
            eng, rep = run_spec(cfg, params, tp_plan, POWERINFER2,
                                mesh=mesh, n_requests=n_req,
                                max_new_hi=max_new_hi)
            s = _summary(eng, rep)
            eng.close()
            if tokens_ref is None:
                tokens_ref = s["tokens"]
            ident = s["tokens"] == tokens_ref
            print(f"{'powerinfer-2':16s} {n:3d} {s['tok_s']:10.1f} "
                  f"{s['ttft_ms']:9.3f} {s['p50_ms']:8.3f} "
                  f"{s['p90_ms']:8.3f} {s['p99_ms']:8.3f} "
                  f"{s['peak_batch']:5d}"
                  + ("" if ident else "  [tokens diverged]"))
            rows.append((f"serving_tok_s_tp{n}", s["tok_s"],
                         f"{n}-device mesh, {groups}-group plan, "
                         f"tokens {'identical' if ident else 'DIVERGED'}"))
            rows.append((f"serving_ttft_ms_tp{n}", s["ttft_ms"],
                         f"{n}-device mesh mean TTFT"))
            out["results"].append(dict(s, system="powerinfer-2", tp=n,
                                       tokens_identical=ident,
                                       tokens=None))
    else:
        print("# single visible device: mesh scaling skipped "
              "(set --devices N before jax init)")

    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
