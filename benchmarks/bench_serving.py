"""Continuous-batching serving benchmark: throughput, TTFT and
per-token latency percentiles under a request stream — single-device
spec comparison plus mesh scaling over both axes.

A deterministic arrival schedule (seeded exponential inter-arrivals —
Poisson-like traffic on the modeled clock) drives the engine's
submit/step loop. Part 1 compares SystemSpecs (llama.cpp-analogue vs
PowerInfer-2) on one device; part 2 runs the PowerInfer-2 spec over a
dp×tp grid of (data, model) meshes — tensor-parallel shards per
replica, replica routing over the 'data' axis — and reports
per-configuration throughput/TTFT. Tokens are checked identical across
tp at fixed dp (cluster selection is shard-local, so the mesh's
'model' size never changes decode); the dp axis re-batches the stream,
so its throughput column is the scaling lever, not token identity.

Scaling metric: `span_tok_s` = total tokens / drained span on the
shared modeled timeline. Replicas decode concurrently, so the span
shrinks with dp while the legacy per-pipeline rate (`tok_s`,
sum-of-step-latency) does not — both are reported.

All latencies are the storage plane's modeled effective seconds, so
differences reflect the paper's mechanisms (and the mesh split), not
host jit noise.

CLI (also runnable argless via benchmarks.run):
  python -m benchmarks.bench_serving --devices 4 --tiny \
      --json BENCH_serving_4dev.json
  python -m benchmarks.bench_serving --family moe --devices 4 --tiny \
      --json BENCH_serving_moe.json
  python -m benchmarks.bench_serving --fleet 4 --tiny \
      --json BENCH_fleet.json
--devices N forces N host platform devices when jax is not yet
initialized (CI smoke) and sweeps every (dp, tp) with dp*tp <= N;
--family moe serves DeepSeekMoE through the family registry — the
mesh 'model' axis becomes the expert-parallel axis (tp == ep, E/n
experts per shard) and the storage plane prices per-device expert
slices; --json writes the machine-readable results.

Fleet leg (--fleet N, DESIGN.md §11): instead of meshing one engine,
stand up fleets of 1..N complete engines behind the FleetGateway and
sweep fleet size x arrival rate (--arrival-rate R1,R2 requests/s on
the fleet clock). Reports the saturation curve (span throughput per
fleet size at each rate), TTFT percentiles split cache-hit vs miss,
and rejected/retried counts; runs backend loss/rejoin and
draining-without-drops as first-class scenarios. Every leg asserts
drained == submitted (a dropped request exits nonzero) and the whole
sweep is deterministic on the modeled fleet clock.
"""
import argparse
import json
import os
import sys

N_REQUESTS = 10
PROMPT_LEN = 16
MEAN_INTERARRIVAL_S = 2e-3
BUCKETS = (1, 2, 4, 8)


def dp_tp_grid(n_devices: int, sizes=(1, 2, 4, 8)):
    """Every (dp, tp) with dp*tp <= n_devices, dp-major order."""
    return [(d, t) for d in sizes for t in sizes if d * t <= n_devices]


def _scaled_plan(cfg, plan, groups: int):
    """Copy `plan` with per-bucket plans regrouped `groups`-way (the
    operating point benchmarks/common pins, cold region group-aligned
    so every divisor mesh size owns whole groups)."""
    import copy
    from repro.core.clusters import make_plan, scale_plan_for_batch
    cs = cfg.sparse_ffn.cluster_size
    base = make_plan(cfg.d_ff, 0.125, 0.10, cs, groups=groups)
    plan = copy.copy(plan)
    plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b, cs)
                  for b in (1, 2, 4, 8, 16, 32)}
    return plan


def _request_stream(cfg, eng, n_requests, max_new_hi, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    for t in arrivals:
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN),
                   max_new=int(rng.integers(6, max_new_hi)),
                   arrival_time=float(t))


def run_spec(cfg, params, plan, spec, seed=0, mesh=None, n_requests=None,
             max_new_hi=14, dp=None, hw=None):
    from benchmarks.common import paper_timing
    from repro.serving.engine import ServeEngine
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                      timing=paper_timing(cfg.family), buckets=BUCKETS,
                      ctx_budget=PROMPT_LEN + 16, temperature=0.8,
                      mesh=mesh, dp=dp, hw=hw)
    _request_stream(cfg, eng, n_requests or N_REQUESTS, max_new_hi, seed)
    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    return eng, rep


def _summary(eng, rep):
    pct = rep.latency_percentiles()
    return {
        "tok_s": round(rep.tokens_per_s, 2),
        "span_tok_s": round(rep.throughput_tok_s, 2),
        "span_s": round(rep.span_s, 6),
        "ttft_ms": round(float(rep.ttft().mean()) * 1e3, 4),
        "p50_ms": round(pct["p50"] * 1e3, 4),
        "p90_ms": round(pct["p90"] * 1e3, 4),
        "p99_ms": round(pct["p99"] * 1e3, 4),
        "peak_batch": max(s.batch for s in rep.stats),
        "n_shards": rep.stats[0].n_shards,
        "tokens": {int(u): [int(t) for t in r.generated]
                   for u, r in eng.sched.sequences.items()},
    }


# --------------------------------------------------- fleet leg (§11) ----

def _fleet_gateway(cfg, params, plan, n, hw=None, heartbeat_s=1e-4):
    from benchmarks.common import paper_timing
    from repro.core.baselines import POWERINFER2
    from repro.serving.gateway import FleetGateway, local_fleet
    backends = local_fleet(cfg, params, plan, n, spec=POWERINFER2,
                           offload_ratio=0.5,
                           timing=paper_timing(cfg.family),
                           buckets=BUCKETS, ctx_budget=PROMPT_LEN + 16,
                           temperature=0.8, seed=0, hw=hw)
    return FleetGateway(backends, heartbeat_s=heartbeat_s)


def _fleet_stream(cfg, gw, n_req, rate, max_new, seed=0):
    """Deterministic Poisson-like stream at `rate` req/s on the fleet
    clock; returns the arrival times (the scenario legs key injected
    events off them)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_req))
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN)
               for _ in range(n_req)]
    for t, p in zip(arrivals, prompts):
        gw.submit(p, max_new=max_new, arrival_time=float(t))
    return arrivals, prompts


def _fleet_leg(args, cfg, params, plan, hw, rows):
    """The --fleet sweep: saturation curves over fleet size x arrival
    rate, TTFT split by cache hit/miss, loss/rejoin and draining
    scenarios. Returns the BENCH_fleet.json payload; appends any
    drained==submitted violations to `failures`."""
    fleet_sizes = [n for n in (1, 2, 4, 8, 16) if n <= args.fleet]
    rates = [float(r) for r in args.arrival_rate.split(",")]
    n_req = 16 if args.tiny else 48
    max_new = 5 if args.tiny else 8
    failures = []
    out = {"bench": "fleet", "tiny": bool(args.tiny),
           "family": args.family, "fleet_sizes": fleet_sizes,
           "arrival_rates": rates, "n_requests": n_req,
           "results": [], "scenarios": {}}

    def check(rep, tag):
        if not rep.drained:
            failures.append(
                f"{tag}: drained != submitted "
                f"({rep.n_completed}+{rep.n_rejected} of "
                f"{rep.n_submitted})")

    print(f"{'fleet':>5s} {'rate':>9s} {'span-tok/s':>10s} "
          f"{'ttft-miss-p50-ms':>16s} {'ttft-hit-p50-ms':>15s} "
          f"{'hits':>5s} {'rej':>4s} {'retry':>5s}")
    span_by = {}                           # rate -> {fleet: span_tok_s}
    for n in fleet_sizes:
        for rate in rates:
            gw = _fleet_gateway(cfg, params, plan, n, hw=hw)
            _, prompts = _fleet_stream(cfg, gw, n_req, rate, max_new)
            rep1 = gw.run_until_drained()  # saturation numbers
            check(rep1, f"fleet={n} rate={rate:g}")
            # replay a quarter of the stream: response-LRU hits, so
            # the report's TTFT split has both populations
            for p in prompts[:max(1, n_req // 4)]:
                gw.submit(p, max_new=max_new, arrival_time=gw.clock_s)
            rep = gw.run_until_drained()
            check(rep, f"fleet={n} rate={rate:g} (replay)")
            gw.close()
            hit = rep.ttft_percentiles("hit")
            miss = rep.ttft_percentiles("miss")
            span = round(rep1.throughput_tok_s, 2)
            span_by.setdefault(rate, {})[n] = span
            print(f"{n:5d} {rate:9g} {span:10.1f} "
                  f"{miss['p50'] * 1e3:16.4f} {hit['p50'] * 1e3:15.4f} "
                  f"{rep.cache_hits:5d} {rep.n_rejected:4d} "
                  f"{rep.n_retries:5d}")
            rows.append((f"fleet_span_tok_s_f{n}_r{rate:g}", span,
                         f"{n_req} reqs at {rate:g}/s over {n} engines"))
            out["results"].append({
                "fleet": n, "rate": rate, "span_tok_s": span,
                "span_s": round(rep1.span_s, 6),
                "total_tokens": rep1.total_tokens,
                "ttft_hit_ms": {k: round(v * 1e3, 4)
                                for k, v in hit.items()},
                "ttft_miss_ms": {k: round(v * 1e3, 4)
                                 for k, v in miss.items()},
                "cache_hits": rep.cache_hits,
                "cache_misses": rep.cache_misses,
                "n_rejected": rep.n_rejected,
                "n_retries": rep.n_retries,
                "drained": rep.drained and rep1.drained,
            })
    for rate, curve in span_by.items():
        base = curve[fleet_sizes[0]]
        scaling = {f"fleet{n}": round(v / max(base, 1e-9), 3)
                   for n, v in sorted(curve.items())}
        out.setdefault("saturation", {})[f"{rate:g}"] = scaling
        rows.append((f"fleet_scaling_r{rate:g}",
                     "|".join(f"{k}={v}x" for k, v in scaling.items()),
                     f"span throughput vs fleet={fleet_sizes[0]} at "
                     f"{rate:g} req/s"))
        print(f"# fleet saturation at {rate:g} req/s: {scaling}")

    # ---- scenarios: loss/rejoin and draining, no drops -------------------
    # Injection times are fractions of the *drained span*, calibrated
    # off a clean run of the same stream: modeled decode steps are
    # ~seconds while arrival spacing is ~microseconds, so arrival-
    # indexed times would all land inside the first decode step and
    # the loss would never be observed.
    n = fleet_sizes[-1]
    if n > 1:
        gw = _fleet_gateway(cfg, params, plan, n, hw=hw)
        _fleet_stream(cfg, gw, n_req, rates[0], max_new, seed=1)
        span = gw.run_until_drained().span_s
        gw.close()
        hb = span / 200                # loss-detection latency << span
        t_fail, t_back = 0.3 * span, 0.6 * span

        gw = _fleet_gateway(cfg, params, plan, n, hw=hw, heartbeat_s=hb)
        _fleet_stream(cfg, gw, n_req, rates[0], max_new, seed=1)
        gw.fail_backend(1, at=t_fail)
        gw.restore_backend(1, at=t_back)
        # a second wave lands after the rejoin so the breaker's
        # half-open canary path actually runs (the rejoined backend
        # must serve again, not just flip alive)
        import numpy as _np
        rng2 = _np.random.default_rng(3)
        for i in range(max(2, n_req // 4)):
            gw.submit(rng2.integers(0, cfg.vocab_size, PROMPT_LEN),
                      max_new=max_new,
                      arrival_time=t_back + (i + 1) * hb)
        rep = gw.run_until_drained()
        check(rep, "loss_rejoin")
        b1 = rep.per_backend[1]
        out["scenarios"]["loss_rejoin"] = {
            "fleet": n, "rate": rates[0], "t_fail": round(t_fail, 6),
            "t_rejoin": round(t_back, 6), "n_retries": rep.n_retries,
            "n_rejected": rep.n_rejected, "drained": rep.drained,
            "lost_backend_completed": b1["completed"],
            "lost_backend_breaker": b1["breaker"],
        }
        print(f"# loss/rejoin (fleet {n}): drained={rep.drained} "
              f"retries={rep.n_retries} rejected={rep.n_rejected} "
              f"lost backend completed {b1['completed']} "
              f"(breaker {b1['breaker']})")
        if rep.n_rejected:
            failures.append("loss_rejoin: requests rejected")
        if rep.n_retries == 0:
            failures.append("loss_rejoin: no in-flight work was "
                            "recalled — the loss was not exercised")
        if b1["completed"] == 0:
            failures.append("loss_rejoin: the rejoined backend never "
                            "served again — the rejoin was not "
                            "exercised")
        gw.close()

        gw = _fleet_gateway(cfg, params, plan, n, hw=hw, heartbeat_s=hb)
        _fleet_stream(cfg, gw, n_req, rates[0], max_new, seed=2)
        gw.drain_backend(1, at=t_fail)
        rep = gw.run_until_drained()
        check(rep, "draining")
        b1 = rep.per_backend[1]
        out["scenarios"]["draining"] = {
            "fleet": n, "rate": rates[0], "t_drain": round(t_fail, 6),
            "drained_backend_dispatched": b1["dispatched"],
            "drained_backend_completed": b1["completed"],
            "n_rejected": rep.n_rejected, "drained": rep.drained,
        }
        print(f"# draining (fleet {n}): drained={rep.drained} "
              f"drained backend finished {b1['completed']}/"
              f"{b1['dispatched']} dispatched, rejected={rep.n_rejected}")
        if b1["completed"] != b1["dispatched"]:
            failures.append("draining: drained backend dropped in-flight "
                            "work")
        if rep.n_rejected:
            failures.append("draining: requests rejected")
        gw.close()
    return out, failures


def _quant_leg(args, rows):
    """Storage-dtype leg (§7.6 + §4.4): quant x family x parallelism.

    The SAME briefly-trained params decode with cold bundles declared
    fp16 / int8 / int4-mixed; the data plane dequantizes at the gather
    boundary and the storage plane prices bundle I/O and cache
    residency at the declared dtype. Reports modeled cold-store
    bytes/token per cell, the fp16/int4 byte ratio (the paper's 3x
    bundle shrink — §4.4's 24KB vs 8KB at deployment constants),
    token agreement vs the fp16 decode, and Table-7 quant-error
    proxies on the real trained bundles.
    """
    import copy
    import dataclasses
    import jax
    import numpy as np
    from benchmarks.common import engine_setup, paper_timing
    from repro.core.baselines import POWERINFER2
    from repro.launch.mesh import make_serving_mesh
    from repro.quant.quantize import quant_error
    from repro.quant.storage import quantize_plan_params
    from repro.serving.engine import ServeEngine

    dtypes = (("fp16", "int8", "int4-mixed")
              if args.storage_dtype == "all"
              else ("fp16",) if args.storage_dtype == "fp16"
              else ("fp16", args.storage_dtype))
    # 87.5% offload: at int4 the ~3x residency gain must not make the
    # cold region fully resident — 0 cold bytes/token would turn the
    # byte ratio into a degenerate metric
    offload = 0.875
    max_new = 8 if args.tiny else 16
    train_steps = 10 if args.tiny else 40
    out = {"bench": "serving_quant", "tiny": bool(args.tiny),
           "device_count": jax.device_count(), "offload": offload,
           "results": [], "quant_error": {}, "ratios": {}}

    print(f"{'family':6s} {'dtype':11s} {'dp':>3s} {'tp':>3s} "
          f"{'tok/s':>8s} {'coldB/tok':>11s} {'bundleB':>8s} {'agree':>6s}")
    for family, arch in (("dense", "smollm-135m"),
                         ("moe", "deepseek-moe-16b")):
        if family == "moe":
            cfg, _, params, plan, _ = engine_setup(
                arch, train_steps=train_steps)
            w0 = params["layers"]["moe"]["experts"][0, 0, :, 0]
        else:
            cfg, _, params, plan, _ = engine_setup(
                arch, activation="relu2", mode="relu",
                train_steps=train_steps)
            w0 = params["layers"]["ffn"]["w"][0, :, 0]
        # Table-7 proxies on the real trained layer-0 gate bundles
        out["quant_error"][family] = {
            s: round(quant_error(w0, s), 6)
            for s in ("group32", "per_channel", "mixed")}
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, PROMPT_LEN)).astype(np.int32)
        cells = [(1, 1)]
        if family == "dense" and jax.device_count() >= 2:
            cells.append((1, 2))
        ref_toks, cold_bt = {}, {}
        for dt in dtypes:
            plan_q = copy.copy(plan)
            plan_q.plans = {
                b: dataclasses.replace(p, storage_dtype=dt)
                for b, p in plan.plans.items()}
            params_q = quantize_plan_params(params, plan_q)
            for d, t in cells:
                mesh = make_serving_mesh(t, d) if d * t > 1 else None
                eng = ServeEngine(cfg, params_q, plan_q, spec=POWERINFER2,
                                  offload_ratio=offload,
                                  timing=paper_timing(family),
                                  buckets=BUCKETS,
                                  ctx_budget=PROMPT_LEN + max_new,
                                  temperature=0.0, seed=0, mesh=mesh)
                res = eng.generate(prompt, max_new=max_new,
                                   temperature=0.0)
                n = sum(s.batch for s in res.stats)
                toks = np.asarray(res.tokens)
                ref = ref_toks.setdefault((d, t), toks)
                agree = float((toks == ref).mean())
                cell = {
                    "family": family, "storage_dtype": dt, "dp": d,
                    "tp": t,
                    "tok_s": round(res.tokens_per_s, 2),
                    "cold_bytes_per_tok": round(
                        eng.coldstore.total_bytes / max(n, 1), 1),
                    "bundle_bytes": eng.storage.bundle_bytes,
                    "resident_neurons":
                        eng.storage.resident_capacity_neurons,
                    "token_agreement": round(agree, 4),
                }
                cold_bt[(dt, d, t)] = cell["cold_bytes_per_tok"]
                out["results"].append(cell)
                print(f"{family:6s} {dt:11s} {d:3d} {t:3d} "
                      f"{cell['tok_s']:8.1f} "
                      f"{cell['cold_bytes_per_tok']:11.0f} "
                      f"{cell['bundle_bytes']:8d} {agree:6.3f}")
                rows.append((
                    f"serving_quant_{family}_{dt}_dp{d}_tp{t}_tok_s",
                    cell["tok_s"],
                    f"cold {cell['cold_bytes_per_tok']:.0f} B/tok, "
                    f"agreement {agree}"))
                eng.close()
        for dt in dtypes[1:]:
            ratio = cold_bt[("fp16", 1, 1)] / max(cold_bt[(dt, 1, 1)],
                                                  1e-9)
            key = f"{family}_fp16_over_{dt.replace('-', '_')}_cold_bytes"
            out["ratios"][key] = round(ratio, 4)
            rows.append((f"serving_quant_{key}", round(ratio, 4),
                         "modeled cold-store bytes/token, fp16 vs "
                         "quantized bundles on the same stream"))
            print(f"# {family}: fp16/{dt} cold-byte ratio {ratio:.3f}x")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host platform devices (pre-jax-init "
                         "only); part 2 sweeps every dp*tp <= N")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer/shorter requests")
    ap.add_argument("--family", choices=("dense", "moe"), default="dense",
                    help="serving family: dense (smollm) or moe "
                         "(deepseek — tp is the expert-parallel axis)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="fleet leg: sweep gateway fleets of 1..N "
                         "engines x arrival rates instead of the mesh "
                         "grid (emits a BENCH_fleet.json-shaped --json)")
    ap.add_argument("--storage-dtype", default=None,
                    choices=("fp16", "int8", "int4-mixed", "all"),
                    help="storage-dtype leg: decode the same params "
                         "with cold bundles declared at this dtype "
                         "(plus the fp16 reference) across both "
                         "families, reporting modeled cold bytes/token "
                         "and token agreement (emits a "
                         "BENCH_serving_quant.json-shaped --json; "
                         "--family is ignored)")
    ap.add_argument("--arrival-rate", default="20000,100000",
                    help="comma-separated request rates (req/s on the "
                         "fleet clock) for the --fleet sweep")
    ap.add_argument("--json", default=None,
                    help="write results JSON (BENCH_*.json artifact)")
    ap.add_argument("--kernel-calibration", default=None,
                    help="BENCH_kernels.json from bench_kernels: price "
                         "the storage plane with the HardwareProfile "
                         "its measured kernel rates calibrate "
                         "(core/io_model.KernelCalibration) instead of "
                         "the hand-set constants")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:]
                         if __name__ == "__main__" else [])

    if args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()

    import jax
    import numpy as np
    from benchmarks.common import emit, engine_setup
    from repro.core.baselines import LLAMACPP, POWERINFER2
    from repro.launch.mesh import make_serving_mesh

    # ---- storage-dtype leg: quant x family grid replaces the rest --------
    if args.storage_dtype:
        rows = []
        out = _quant_leg(args, rows)
        emit(rows)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(out, f, indent=1)
            print(f"# wrote {args.json}")
        return rows

    n_req = 4 if args.tiny else N_REQUESTS
    max_new_hi = 8 if args.tiny else 14
    if args.family == "moe":
        cfg, model, params, plan, prompt = engine_setup(
            "deepseek-moe-16b", train_steps=10 if args.tiny else 40)
    else:
        cfg, model, params, plan, prompt = engine_setup(
            "smollm-135m", activation="relu2", mode="relu",
            train_steps=10 if args.tiny else 40)
    fam_tag = "" if args.family == "dense" else f"{args.family}_"
    rows, out = [], {"bench": "serving", "tiny": bool(args.tiny),
                     "family": args.family,
                     "device_count": jax.device_count(), "results": []}

    hw = None
    if args.kernel_calibration:
        from dataclasses import asdict
        from repro.core.io_model import KernelCalibration
        calib = KernelCalibration.from_bench_json(args.kernel_calibration)
        hw = calib.hardware()
        out["kernel_calibration"] = asdict(calib)
        print(f"# storage plane priced with measured kernel rates: "
              f"{hw.name}")

    # ---- fleet leg: gateway sweep replaces the mesh grid -----------------
    if args.fleet:
        out, failures = _fleet_leg(args, cfg, params, plan, hw, rows)
        if args.kernel_calibration:
            out["kernel_calibration"] = dict(
                (("hw", hw.name),)) if hw else None
        emit(rows)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(out, f, indent=1)
            print(f"# wrote {args.json}")
        if failures:
            for msg in failures:
                print(f"FLEET FAILURE: {msg}", file=sys.stderr)
            raise SystemExit(1)
        return rows

    # ---- part 1: spec comparison, single device --------------------------
    print(f"{'system':16s} {'dp':>3s} {'tp':>3s} {'tok/s':>10s} "
          f"{'span-tok/s':>10s} {'ttft-ms':>9s} {'p50-ms':>8s} "
          f"{'p90-ms':>8s} {'p99-ms':>8s} {'peak':>5s}")
    for spec in (LLAMACPP, POWERINFER2):
        eng, rep = run_spec(cfg, params, plan, spec, n_requests=n_req,
                            max_new_hi=max_new_hi, hw=hw)
        s = _summary(eng, rep)
        print(f"{spec.name:16s} {1:3d} {1:3d} {s['tok_s']:10.1f} "
              f"{s['span_tok_s']:10.1f} {s['ttft_ms']:9.3f} "
              f"{s['p50_ms']:8.3f} {s['p90_ms']:8.3f} {s['p99_ms']:8.3f} "
              f"{s['peak_batch']:5d}")
        tag = fam_tag + spec.name.replace(".", "").replace("-", "_")
        rows.append((f"serving_tok_s_{tag}", s["tok_s"],
                     f"{n_req} reqs, Poisson-like arrivals, 50% offload"))
        rows.append((f"serving_ttft_ms_{tag}", s["ttft_ms"],
                     "mean time-to-first-token (modeled, incl prefill)"))
        rows.append((f"serving_p99_ms_{tag}", s["p99_ms"],
                     f"p50 {s['p50_ms']} p90 {s['p90_ms']}"))
        rows.append((f"serving_batch_growth_{tag}",
                     f"{eng.sched.batch_history[0]}->{s['peak_batch']}",
                     "continuous batching: batch grew under load then "
                     "drained"))
        out["results"].append(dict(s, system=spec.name, dp=1, tp=1,
                                   tokens=None))
        eng.close()

    # ---- part 2: dp×tp mesh-scaling grid ---------------------------------
    # The 'data' axis is a load-scaling lever: replicas only pay off
    # once a single engine's batch bucket saturates and requests
    # queue, so the grid serves a 3x heavier stream than part 1
    # (under-loaded, one replica batches everything and dp buys
    # nothing — the modeled numbers honestly say so).
    n_grid = 3 * n_req
    grid = dp_tp_grid(jax.device_count())
    if len(grid) > 1:
        if args.family == "moe":
            # experts shard as-is over every divisor mesh (tp == ep);
            # the moe plan is already bucket-scaled by build_moe_plan
            grid_plan = plan
        else:
            groups = max(t for _, t in grid)
            grid_plan = _scaled_plan(cfg, plan, groups)
        tokens_ref = {}                      # dp -> token dict at lowest tp
        span_by_dp = {}                      # dp -> span_tok_s at tp=1
        for d, t in grid:
            mesh = make_serving_mesh(t, d) if d * t > 1 else None
            eng, rep = run_spec(cfg, params, grid_plan, POWERINFER2,
                                mesh=mesh, n_requests=n_grid,
                                max_new_hi=max_new_hi, hw=hw)
            s = _summary(eng, rep)
            eng.close()
            ident = s["tokens"] == tokens_ref.setdefault(d, s["tokens"])
            print(f"{'powerinfer-2':16s} {d:3d} {t:3d} {s['tok_s']:10.1f} "
                  f"{s['span_tok_s']:10.1f} {s['ttft_ms']:9.3f} "
                  f"{s['p50_ms']:8.3f} {s['p90_ms']:8.3f} "
                  f"{s['p99_ms']:8.3f} {s['peak_batch']:5d}"
                  + ("" if ident else "  [tokens diverged]"))
            # span-prefixed name: these rows hold the span rate, not
            # part 1's per-pipeline tokens_per_s — don't let the two
            # semantics share a metric prefix in the trajectory
            rows.append((f"serving_{fam_tag}span_tok_s_dp{d}_tp{t}",
                         s["span_tok_s"],
                         f"({d},{t}) mesh span throughput; per-pipeline "
                         f"{s['tok_s']}; tokens vs dp={d} ref "
                         f"{'identical' if ident else 'DIVERGED'}"))
            rows.append((f"serving_{fam_tag}ttft_ms_dp{d}_tp{t}",
                         s["ttft_ms"], f"({d},{t}) mesh mean TTFT"))
            if t == 1:
                span_by_dp[d] = s["span_tok_s"]
            out["results"].append(dict(s, system="powerinfer-2", dp=d,
                                       tp=t, tokens_identical=ident,
                                       tokens=None))
        if len(span_by_dp) > 1:
            base = span_by_dp[1]
            scaling = {f"dp{d}": round(v / base, 3)
                       for d, v in sorted(span_by_dp.items())}
            out["dp_scaling"] = scaling
            rows.append((f"serving_{fam_tag}dp_scaling",
                         "|".join(f"{k}={v}x" for k, v in scaling.items()),
                         "span throughput vs dp=1, tp=1 (replica "
                         "routing over the 'data' axis)"))
            print(f"# dp-axis span-throughput scaling: {scaling}")
    else:
        print("# single visible device: mesh scaling skipped "
              "(set --devices N before jax init)")

    # ---- part 3 (moe): intra-expert sparsity pricing leg -----------------
    # The paper's TurboSparse-Mixtral case (DESIGN.md §9): the SAME
    # permuted params decode under two-level pricing (per-expert
    # hot/cold clusters, (L, E, 1+ncc) trace) and under whole-expert
    # pricing; the expert compute is identical either way, so tokens
    # match bit-for-bit and the delta isolates what intra-expert
    # granularity saves in modeled cold-store I/O at batch 1.
    if args.family == "moe":
        from benchmarks.common import paper_timing
        from repro.core.baselines import POWERINFER2 as PI2
        from repro.core.planner import PHONE, build_moe_plan
        from repro.serving.engine import ServeEngine
        cfgs, _, params_s, plan_s, _ = engine_setup(
            "turbosparse-mixtral-47b", train_steps=10 if args.tiny else 40)
        cfgw = cfgs.replace(moe_intra_expert=False)
        plan_w = build_moe_plan(cfgw, hw=PHONE)
        prompt1 = np.random.default_rng(0).integers(
            0, cfgs.vocab_size, (1, PROMPT_LEN)).astype(np.int32)
        max_new = 8 if args.tiny else 16
        leg = {}
        for tag, c, pl in (("intra_expert", cfgs, plan_s),
                           ("whole_expert", cfgw, plan_w)):
            eng = ServeEngine(c, params_s, pl, spec=PI2, offload_ratio=0.5,
                              timing=paper_timing("moe"), buckets=BUCKETS,
                              ctx_budget=PROMPT_LEN + max_new,
                              temperature=0.8, seed=0)
            res = eng.generate(prompt1, max_new=max_new, temperature=0.8)
            n = sum(s.batch for s in res.stats)
            leg[tag] = {
                "tok_s": round(res.tokens_per_s, 2),
                "cold_bytes_per_tok": round(
                    eng.coldstore.total_bytes / max(n, 1), 1),
                "n_expert_hot": pl.plan_for_batch(1).n_expert_hot,
                "tokens": res.tokens.tolist(),
            }
            eng.close()
        ident = leg["intra_expert"]["tokens"] == leg["whole_expert"]["tokens"]
        ratio = (leg["intra_expert"]["cold_bytes_per_tok"]
                 / max(leg["whole_expert"]["cold_bytes_per_tok"], 1e-9))
        print(f"# moe intra-expert pricing (turbosparse, batch 1): "
              f"{leg['intra_expert']['cold_bytes_per_tok']:.0f} vs "
              f"{leg['whole_expert']['cold_bytes_per_tok']:.0f} cold "
              f"B/tok ({ratio:.3f}x), tok/s "
              f"{leg['intra_expert']['tok_s']} vs "
              f"{leg['whole_expert']['tok_s']}, tokens "
              f"{'identical' if ident else 'DIVERGED'}")
        rows.append(("serving_moe_sparse_cold_bytes_ratio", round(ratio, 4),
                     "intra-expert / whole-expert modeled cold bytes per "
                     f"token at batch 1 (tokens "
                     f"{'identical' if ident else 'DIVERGED'})"))
        rows.append(("serving_moe_sparse_tok_s",
                     leg["intra_expert"]["tok_s"],
                     f"two-level pricing; whole-expert "
                     f"{leg['whole_expert']['tok_s']}"))
        sparse_out = {"bench": "serving_moe_sparse", "tiny": bool(args.tiny),
                      "arch": "turbosparse-mixtral-47b",
                      "tokens_identical": ident,
                      "cold_bytes_ratio": round(ratio, 4), "legs": leg}
        if args.json:
            sp = args.json.replace(".json", "_sparse.json")
            with open(sp, "w") as f:
                json.dump(sparse_out, f, indent=1)
            print(f"# wrote {sp}")

    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
