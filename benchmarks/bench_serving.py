"""Continuous-batching serving benchmark: throughput, TTFT and
per-token latency percentiles under a request stream — single-device
spec comparison plus mesh scaling over both axes.

A deterministic arrival schedule (seeded exponential inter-arrivals —
Poisson-like traffic on the modeled clock) drives the engine's
submit/step loop. Part 1 compares SystemSpecs (llama.cpp-analogue vs
PowerInfer-2) on one device; part 2 runs the PowerInfer-2 spec over a
dp×tp grid of (data, model) meshes — tensor-parallel shards per
replica, replica routing over the 'data' axis — and reports
per-configuration throughput/TTFT. Tokens are checked identical across
tp at fixed dp (cluster selection is shard-local, so the mesh's
'model' size never changes decode); the dp axis re-batches the stream,
so its throughput column is the scaling lever, not token identity.

Scaling metric: `span_tok_s` = total tokens / drained span on the
shared modeled timeline. Replicas decode concurrently, so the span
shrinks with dp while the legacy per-pipeline rate (`tok_s`,
sum-of-step-latency) does not — both are reported.

All latencies are the storage plane's modeled effective seconds, so
differences reflect the paper's mechanisms (and the mesh split), not
host jit noise.

CLI (also runnable argless via benchmarks.run):
  python -m benchmarks.bench_serving --devices 4 --tiny \
      --json BENCH_serving_4dev.json
  python -m benchmarks.bench_serving --family moe --devices 4 --tiny \
      --json BENCH_serving_moe.json
--devices N forces N host platform devices when jax is not yet
initialized (CI smoke) and sweeps every (dp, tp) with dp*tp <= N;
--family moe serves DeepSeekMoE through the family registry — the
mesh 'model' axis becomes the expert-parallel axis (tp == ep, E/n
experts per shard) and the storage plane prices per-device expert
slices; --json writes the machine-readable results.
"""
import argparse
import json
import os
import sys

N_REQUESTS = 10
PROMPT_LEN = 16
MEAN_INTERARRIVAL_S = 2e-3
BUCKETS = (1, 2, 4, 8)


def dp_tp_grid(n_devices: int, sizes=(1, 2, 4, 8)):
    """Every (dp, tp) with dp*tp <= n_devices, dp-major order."""
    return [(d, t) for d in sizes for t in sizes if d * t <= n_devices]


def _scaled_plan(cfg, plan, groups: int):
    """Copy `plan` with per-bucket plans regrouped `groups`-way (the
    operating point benchmarks/common pins, cold region group-aligned
    so every divisor mesh size owns whole groups)."""
    import copy
    from repro.core.clusters import make_plan, scale_plan_for_batch
    cs = cfg.sparse_ffn.cluster_size
    base = make_plan(cfg.d_ff, 0.125, 0.10, cs, groups=groups)
    plan = copy.copy(plan)
    plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b, cs)
                  for b in (1, 2, 4, 8, 16, 32)}
    return plan


def _request_stream(cfg, eng, n_requests, max_new_hi, seed=0):
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    for t in arrivals:
        eng.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN),
                   max_new=int(rng.integers(6, max_new_hi)),
                   arrival_time=float(t))


def run_spec(cfg, params, plan, spec, seed=0, mesh=None, n_requests=None,
             max_new_hi=14, dp=None, hw=None):
    from benchmarks.common import paper_timing
    from repro.serving.engine import ServeEngine
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                      timing=paper_timing(cfg.family), buckets=BUCKETS,
                      ctx_budget=PROMPT_LEN + 16, temperature=0.8,
                      mesh=mesh, dp=dp, hw=hw)
    _request_stream(cfg, eng, n_requests or N_REQUESTS, max_new_hi, seed)
    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    return eng, rep


def _summary(eng, rep):
    pct = rep.latency_percentiles()
    return {
        "tok_s": round(rep.tokens_per_s, 2),
        "span_tok_s": round(rep.throughput_tok_s, 2),
        "span_s": round(rep.span_s, 6),
        "ttft_ms": round(float(rep.ttft().mean()) * 1e3, 4),
        "p50_ms": round(pct["p50"] * 1e3, 4),
        "p90_ms": round(pct["p90"] * 1e3, 4),
        "p99_ms": round(pct["p99"] * 1e3, 4),
        "peak_batch": max(s.batch for s in rep.stats),
        "n_shards": rep.stats[0].n_shards,
        "tokens": {int(u): [int(t) for t in r.generated]
                   for u, r in eng.sched.sequences.items()},
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host platform devices (pre-jax-init "
                         "only); part 2 sweeps every dp*tp <= N")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer/shorter requests")
    ap.add_argument("--family", choices=("dense", "moe"), default="dense",
                    help="serving family: dense (smollm) or moe "
                         "(deepseek — tp is the expert-parallel axis)")
    ap.add_argument("--json", default=None,
                    help="write results JSON (BENCH_*.json artifact)")
    ap.add_argument("--kernel-calibration", default=None,
                    help="BENCH_kernels.json from bench_kernels: price "
                         "the storage plane with the HardwareProfile "
                         "its measured kernel rates calibrate "
                         "(core/io_model.KernelCalibration) instead of "
                         "the hand-set constants")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:]
                         if __name__ == "__main__" else [])

    if args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()

    import jax
    import numpy as np
    from benchmarks.common import emit, engine_setup
    from repro.core.baselines import LLAMACPP, POWERINFER2
    from repro.launch.mesh import make_serving_mesh

    n_req = 4 if args.tiny else N_REQUESTS
    max_new_hi = 8 if args.tiny else 14
    if args.family == "moe":
        cfg, model, params, plan, prompt = engine_setup(
            "deepseek-moe-16b", train_steps=10 if args.tiny else 40)
    else:
        cfg, model, params, plan, prompt = engine_setup(
            "smollm-135m", activation="relu2", mode="relu",
            train_steps=10 if args.tiny else 40)
    fam_tag = "" if args.family == "dense" else f"{args.family}_"
    rows, out = [], {"bench": "serving", "tiny": bool(args.tiny),
                     "family": args.family,
                     "device_count": jax.device_count(), "results": []}

    hw = None
    if args.kernel_calibration:
        from dataclasses import asdict
        from repro.core.io_model import KernelCalibration
        calib = KernelCalibration.from_bench_json(args.kernel_calibration)
        hw = calib.hardware()
        out["kernel_calibration"] = asdict(calib)
        print(f"# storage plane priced with measured kernel rates: "
              f"{hw.name}")

    # ---- part 1: spec comparison, single device --------------------------
    print(f"{'system':16s} {'dp':>3s} {'tp':>3s} {'tok/s':>10s} "
          f"{'span-tok/s':>10s} {'ttft-ms':>9s} {'p50-ms':>8s} "
          f"{'p90-ms':>8s} {'p99-ms':>8s} {'peak':>5s}")
    for spec in (LLAMACPP, POWERINFER2):
        eng, rep = run_spec(cfg, params, plan, spec, n_requests=n_req,
                            max_new_hi=max_new_hi, hw=hw)
        s = _summary(eng, rep)
        print(f"{spec.name:16s} {1:3d} {1:3d} {s['tok_s']:10.1f} "
              f"{s['span_tok_s']:10.1f} {s['ttft_ms']:9.3f} "
              f"{s['p50_ms']:8.3f} {s['p90_ms']:8.3f} {s['p99_ms']:8.3f} "
              f"{s['peak_batch']:5d}")
        tag = fam_tag + spec.name.replace(".", "").replace("-", "_")
        rows.append((f"serving_tok_s_{tag}", s["tok_s"],
                     f"{n_req} reqs, Poisson-like arrivals, 50% offload"))
        rows.append((f"serving_ttft_ms_{tag}", s["ttft_ms"],
                     "mean time-to-first-token (modeled, incl prefill)"))
        rows.append((f"serving_p99_ms_{tag}", s["p99_ms"],
                     f"p50 {s['p50_ms']} p90 {s['p90_ms']}"))
        rows.append((f"serving_batch_growth_{tag}",
                     f"{eng.sched.batch_history[0]}->{s['peak_batch']}",
                     "continuous batching: batch grew under load then "
                     "drained"))
        out["results"].append(dict(s, system=spec.name, dp=1, tp=1,
                                   tokens=None))
        eng.close()

    # ---- part 2: dp×tp mesh-scaling grid ---------------------------------
    # The 'data' axis is a load-scaling lever: replicas only pay off
    # once a single engine's batch bucket saturates and requests
    # queue, so the grid serves a 3x heavier stream than part 1
    # (under-loaded, one replica batches everything and dp buys
    # nothing — the modeled numbers honestly say so).
    n_grid = 3 * n_req
    grid = dp_tp_grid(jax.device_count())
    if len(grid) > 1:
        if args.family == "moe":
            # experts shard as-is over every divisor mesh (tp == ep);
            # the moe plan is already bucket-scaled by build_moe_plan
            grid_plan = plan
        else:
            groups = max(t for _, t in grid)
            grid_plan = _scaled_plan(cfg, plan, groups)
        tokens_ref = {}                      # dp -> token dict at lowest tp
        span_by_dp = {}                      # dp -> span_tok_s at tp=1
        for d, t in grid:
            mesh = make_serving_mesh(t, d) if d * t > 1 else None
            eng, rep = run_spec(cfg, params, grid_plan, POWERINFER2,
                                mesh=mesh, n_requests=n_grid,
                                max_new_hi=max_new_hi, hw=hw)
            s = _summary(eng, rep)
            eng.close()
            ident = s["tokens"] == tokens_ref.setdefault(d, s["tokens"])
            print(f"{'powerinfer-2':16s} {d:3d} {t:3d} {s['tok_s']:10.1f} "
                  f"{s['span_tok_s']:10.1f} {s['ttft_ms']:9.3f} "
                  f"{s['p50_ms']:8.3f} {s['p90_ms']:8.3f} "
                  f"{s['p99_ms']:8.3f} {s['peak_batch']:5d}"
                  + ("" if ident else "  [tokens diverged]"))
            # span-prefixed name: these rows hold the span rate, not
            # part 1's per-pipeline tokens_per_s — don't let the two
            # semantics share a metric prefix in the trajectory
            rows.append((f"serving_{fam_tag}span_tok_s_dp{d}_tp{t}",
                         s["span_tok_s"],
                         f"({d},{t}) mesh span throughput; per-pipeline "
                         f"{s['tok_s']}; tokens vs dp={d} ref "
                         f"{'identical' if ident else 'DIVERGED'}"))
            rows.append((f"serving_{fam_tag}ttft_ms_dp{d}_tp{t}",
                         s["ttft_ms"], f"({d},{t}) mesh mean TTFT"))
            if t == 1:
                span_by_dp[d] = s["span_tok_s"]
            out["results"].append(dict(s, system="powerinfer-2", dp=d,
                                       tp=t, tokens_identical=ident,
                                       tokens=None))
        if len(span_by_dp) > 1:
            base = span_by_dp[1]
            scaling = {f"dp{d}": round(v / base, 3)
                       for d, v in sorted(span_by_dp.items())}
            out["dp_scaling"] = scaling
            rows.append((f"serving_{fam_tag}dp_scaling",
                         "|".join(f"{k}={v}x" for k, v in scaling.items()),
                         "span throughput vs dp=1, tp=1 (replica "
                         "routing over the 'data' axis)"))
            print(f"# dp-axis span-throughput scaling: {scaling}")
    else:
        print("# single visible device: mesh scaling skipped "
              "(set --devices N before jax init)")

    # ---- part 3 (moe): intra-expert sparsity pricing leg -----------------
    # The paper's TurboSparse-Mixtral case (DESIGN.md §9): the SAME
    # permuted params decode under two-level pricing (per-expert
    # hot/cold clusters, (L, E, 1+ncc) trace) and under whole-expert
    # pricing; the expert compute is identical either way, so tokens
    # match bit-for-bit and the delta isolates what intra-expert
    # granularity saves in modeled cold-store I/O at batch 1.
    if args.family == "moe":
        from benchmarks.common import paper_timing
        from repro.core.baselines import POWERINFER2 as PI2
        from repro.core.planner import PHONE, build_moe_plan
        from repro.serving.engine import ServeEngine
        cfgs, _, params_s, plan_s, _ = engine_setup(
            "turbosparse-mixtral-47b", train_steps=10 if args.tiny else 40)
        cfgw = cfgs.replace(moe_intra_expert=False)
        plan_w = build_moe_plan(cfgw, hw=PHONE)
        prompt1 = np.random.default_rng(0).integers(
            0, cfgs.vocab_size, (1, PROMPT_LEN)).astype(np.int32)
        max_new = 8 if args.tiny else 16
        leg = {}
        for tag, c, pl in (("intra_expert", cfgs, plan_s),
                           ("whole_expert", cfgw, plan_w)):
            eng = ServeEngine(c, params_s, pl, spec=PI2, offload_ratio=0.5,
                              timing=paper_timing("moe"), buckets=BUCKETS,
                              ctx_budget=PROMPT_LEN + max_new,
                              temperature=0.8, seed=0)
            res = eng.generate(prompt1, max_new=max_new, temperature=0.8)
            n = sum(s.batch for s in res.stats)
            leg[tag] = {
                "tok_s": round(res.tokens_per_s, 2),
                "cold_bytes_per_tok": round(
                    eng.coldstore.total_bytes / max(n, 1), 1),
                "n_expert_hot": pl.plan_for_batch(1).n_expert_hot,
                "tokens": res.tokens.tolist(),
            }
            eng.close()
        ident = leg["intra_expert"]["tokens"] == leg["whole_expert"]["tokens"]
        ratio = (leg["intra_expert"]["cold_bytes_per_tok"]
                 / max(leg["whole_expert"]["cold_bytes_per_tok"], 1e-9))
        print(f"# moe intra-expert pricing (turbosparse, batch 1): "
              f"{leg['intra_expert']['cold_bytes_per_tok']:.0f} vs "
              f"{leg['whole_expert']['cold_bytes_per_tok']:.0f} cold "
              f"B/tok ({ratio:.3f}x), tok/s "
              f"{leg['intra_expert']['tok_s']} vs "
              f"{leg['whole_expert']['tok_s']}, tokens "
              f"{'identical' if ident else 'DIVERGED'}")
        rows.append(("serving_moe_sparse_cold_bytes_ratio", round(ratio, 4),
                     "intra-expert / whole-expert modeled cold bytes per "
                     f"token at batch 1 (tokens "
                     f"{'identical' if ident else 'DIVERGED'})"))
        rows.append(("serving_moe_sparse_tok_s",
                     leg["intra_expert"]["tok_s"],
                     f"two-level pricing; whole-expert "
                     f"{leg['whole_expert']['tok_s']}"))
        sparse_out = {"bench": "serving_moe_sparse", "tiny": bool(args.tiny),
                      "arch": "turbosparse-mixtral-47b",
                      "tokens_identical": ident,
                      "cold_bytes_ratio": round(ratio, 4), "legs": leg}
        if args.json:
            sp = args.json.replace(".json", "_sparse.json")
            with open(sp, "w") as f:
                json.dump(sparse_out, f, indent=1)
            print(f"# wrote {sp}")

    emit(rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
