"""Fig 10: decode speed vs available memory (cache size).

Sweeps the offload ratio (= 1 - resident fraction): decode speed must
scale with cache size as I/O shrinks (the paper sees linear scaling
from 7GB to 19GB on TurboSparse-Mixtral-47B)."""
import numpy as np

from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import POWERINFER2
from repro.serving.engine import ServeEngine


def main():
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    rows = []
    speeds = []
    for offload in (0.95, 0.75, 0.5, 0.25, 0.05):
        eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                          offload_ratio=offload, timing=paper_timing())
        res = eng.generate(prompt[:1], max_new=16, temperature=0.8)
        hit = float(np.mean([s.cache_hit_rate for s in res.stats]))
        speeds.append(res.tokens_per_s)
        rows.append((f"fig10_decode_resident{int((1-offload)*100)}pct",
                     round(res.tokens_per_s, 2),
                     f"modeled tok/s; cache hit {hit:.2f}"))
    rows.append(("fig10_scaling_monotone", int(speeds == sorted(speeds)),
                 "speed increases with resident memory"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
