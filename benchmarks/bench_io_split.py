"""Table 2/4: compute vs I/O time split.

Paper: offloaded decode is 76.7% I/O for LLMFlash but 13.7% for
PowerInfer-2 (cluster pipeline + bundles hide the storage tier)."""

from benchmarks.common import emit, engine_setup, paper_timing
from repro.core.baselines import LLMFLASH, POWERINFER2, LLAMACPP
from repro.serving.engine import ServeEngine


def main():
    cfg, model, params, plan, prompt = engine_setup(
        "smollm-135m", activation="relu2", mode="relu")
    rows = []
    for spec, paper in ((POWERINFER2, "paper: 13.7% I/O"),
                        (LLMFLASH, "paper: 76.7% I/O"),
                        (LLAMACPP, "paper: ~82% I/O (PowerInfer ext)")):
        eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=0.5,
                          timing=paper_timing())
        res = eng.generate(prompt[:1], max_new=16, temperature=0.8)
        eff = sum(s.effective_s for s in res.stats)
        comp = sum(s.compute_s for s in res.stats)
        io_share = max(0.0, 1.0 - comp / max(eff, 1e-12))
        rows.append((f"table4_io_share_{spec.name}",
                     round(io_share, 3), paper))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
