"""Shared benchmark helpers.

Engine benches run the REAL reduced model (jit) with the storage plane
driven by true activation traces; analytic benches use the full-size
configs with the HardwareProfile/StorageModel cost model only (no
allocation). Both are deterministic.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.planner import build_moe_plan, build_plan, \
    permute_ffn_params
from repro.serving.families import serving_family


@functools.lru_cache(maxsize=1)
def _source_digest() -> str:
    """Digest of the model/training sources (src/repro + this file):
    folded into the disk-cache key so editing anything that shapes
    training invalidates local caches too, not just CI's hashFiles
    key."""
    import hashlib
    here = os.path.abspath(__file__)
    root = os.path.join(os.path.dirname(os.path.dirname(here)),
                        "src", "repro")
    h = hashlib.sha1()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                p = os.path.join(dirpath, f)
                h.update(os.path.relpath(p, root).encode())
                with open(p, "rb") as fh:
                    h.update(fh.read())
    with open(here, "rb") as fh:
        h.update(fh.read())
    return h.hexdigest()[:12]


def _setup_cache_path(arch, activation, mode, seed, train_steps):
    """Disk-cache path for the trained engine_setup params, or None
    when caching is off (no REPRO_BENCH_CACHE dir in the env). Keyed
    by every input that shapes training — the setup args, the jax
    version and a digest of the sources — so a CI runner shares one
    training across its bench processes without ever mixing numerics
    across toolchains or code revisions."""
    root = os.environ.get("REPRO_BENCH_CACHE")
    if not root:
        return None
    import hashlib
    key = (f"{arch}|{activation}|{mode}|{seed}|{train_steps}"
           f"|jax-{jax.__version__}|src-{_source_digest()}")
    h = hashlib.sha1(key.encode()).hexdigest()[:16]
    return os.path.join(root, f"engine_setup_{h}.npz")


def _load_trained(path, template_leaves):
    """Load cached (params leaves, counts, n_tok); None on any
    mismatch with the template (source drift -> retrain)."""
    try:
        z = np.load(path)
        leaves = [z[f"p{i}"] for i in range(len(template_leaves))]
        counts, n_tok = z["counts"], int(z["n_tok"])
        for got, want in zip(leaves, template_leaves):
            w = np.asarray(want)
            if got.shape != w.shape or got.dtype != w.dtype:
                return None
    except Exception:          # missing/corrupt member -> retrain
        return None
    return leaves, counts, n_tok


def _save_trained(path, leaves, counts, n_tok):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.npz"   # savez appends .npz otherwise
    arrs = {f"p{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp, counts=np.asarray(counts), n_tok=np.int64(n_tok), **arrs)
    os.replace(tmp, path)


@functools.lru_cache(maxsize=8)
def engine_setup(arch: str = "smollm-135m", activation: str = None,
                 mode: str = None, seed: int = 0, train_steps: int = 40,
                 cache: bool = True):
    """Reduced model, briefly trained (real activation skew), profiled,
    planned for the PHONE hardware profile, hot-first permuted. Cached
    in-process (lru) and, when REPRO_BENCH_CACHE points at a
    directory, on disk across processes — a CI runner's bench matrix
    trains once, later processes reload the trained+calibrated params
    and activation counts, and everything downstream (plan, permute)
    recomputes deterministically from them. `cache=False` bypasses the
    disk layer (scripts/check_param_cache.py uses it to prove the
    cached and fresh params decode identically).

    Family-generic through the serving registry: MoE archs skip
    predictor calibration / activation profiling / hot-first
    permutation (the router is the predictor, experts are the
    clusters) and get the experts-as-clusters build_moe_plan."""
    import dataclasses
    from repro.core.planner import PHONE, profile_activations
    cfg = get_config(arch).reduced()
    if activation:
        cfg = cfg.replace(activation=activation)
    if mode:
        cfg = cfg.replace(sparse_ffn=dataclasses.replace(cfg.sparse_ffn,
                                                         mode=mode))
    model = serving_family(cfg).make_model(cfg)
    params = model.init(jax.random.key(seed))
    path = _setup_cache_path(arch, activation, mode, seed, train_steps) \
        if cache else None
    hit = None
    if path and os.path.exists(path):
        treedef = jax.tree.structure(params)
        hit = _load_trained(path, jax.tree.leaves(params))
    if hit is not None:
        leaves, counts, n_tok = hit
        params = jax.tree.unflatten(treedef, [jax.numpy.asarray(l)
                                              for l in leaves])
    else:
        if train_steps:
            params, _ = _train_with_cfg(cfg, params, train_steps, seed)
        if cfg.num_experts:
            counts, n_tok = np.zeros((1,), np.int64), 1     # moe: unused
        else:
            batches = [jax.random.randint(jax.random.key(seed * 13 + i),
                                          (4, 64), 0, cfg.vocab_size)
                       for i in range(4)]
            from repro.core.planner import calibrate_predictor
            params = calibrate_predictor(params, cfg, batches)
            counts, n_tok = profile_activations(params, cfg, batches)
        if path:
            _save_trained(path, jax.tree.leaves(params), counts, n_tok)
    if cfg.num_experts:
        plan = build_moe_plan(cfg, hw=PHONE)
        # whole-expert plans prepare to identity; two-level plans
        # (cfg.moe_intra_expert) apply the per-expert hot-first
        # permutation the plan's neuron_order records
        params = serving_family(cfg).prepare_params(params, plan)
        prompt = np.random.default_rng(seed).integers(
            0, cfg.vocab_size, (4, 16)).astype(np.int32)
        return cfg, model, params, plan, prompt
    plan = build_plan(cfg, (counts / n_tok).astype(np.float32), hw=PHONE)
    # Operating-point calibration: a briefly-trained reduced model is
    # far denser (~70% active) than the paper's trained 7Bs (~15%).
    # The plan budgets are the offline planner's choice — pin them to
    # the paper's regime; cluster *selection* stays real (calibrated
    # predictor on real hidden states).
    from repro.core.clusters import make_plan, scale_plan_for_batch
    base = make_plan(cfg.d_ff, 0.125, 0.10, cfg.sparse_ffn.cluster_size)
    plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b,
                                          cfg.sparse_ffn.cluster_size)
                  for b in (1, 2, 4, 8, 16, 32)}
    params = permute_ffn_params(params, plan.neuron_order)
    prompt = np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    return cfg, model, params, plan, prompt


def _train_with_cfg(cfg, params, steps, seed):
    import jax as _jax
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.models.model import build_model
    from repro.optim.adamw import AdamW
    from repro.train.steps import make_train_step
    model = build_model(cfg)
    opt = AdamW(lr=2e-3)
    step = _jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    state = opt.init(params)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=seed))
    losses = []
    for _ in range(steps):
        params, state, m = step(params, state, data.batch())
        losses.append(float(m["loss"]))
    return params, losses


def paper_timing(family: str = "dense"):
    """Storage-plane cost constants at the paper's deployment size —
    dense: Bamboo-7B FP16 (24KB Gate-Up-Down bundles, 32 layers); moe:
    DeepSeekMoE-16B (per-expert d_ff=1408, 28 layers — the storage
    view multiplies widths by the expert count)."""
    from repro.serving.engine import TimingProfile
    if family == "moe":
        return TimingProfile.from_config(get_config("deepseek-moe-16b"), 3)
    from repro.configs.paper_models import BAMBOO_7B
    return TimingProfile.from_config(BAMBOO_7B, 3)


def timeit(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n


def emit(rows):
    """Print the harness CSV: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
