"""Shared benchmark helpers.

Engine benches run the REAL reduced model (jit) with the storage plane
driven by true activation traces; analytic benches use the full-size
configs with the HardwareProfile/StorageModel cost model only (no
allocation). Both are deterministic.
"""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.planner import build_plan, permute_ffn_params
from repro.models.dense import make_model


@functools.lru_cache(maxsize=4)
def engine_setup(arch: str = "smollm-135m", activation: str = None,
                 mode: str = None, seed: int = 0, train_steps: int = 40):
    """Reduced model, briefly trained (real activation skew), profiled,
    planned for the PHONE hardware profile, hot-first permuted. Cached."""
    import dataclasses
    from repro.core.planner import PHONE, profile_activations
    cfg = get_config(arch).reduced()
    if activation:
        cfg = cfg.replace(activation=activation)
    if mode:
        cfg = cfg.replace(sparse_ffn=dataclasses.replace(cfg.sparse_ffn,
                                                         mode=mode))
    model = make_model(cfg)
    params = model.init(jax.random.key(seed))
    if train_steps:
        params, _ = _train_with_cfg(cfg, params, train_steps, seed)
    batches = [jax.random.randint(jax.random.key(seed * 13 + i), (4, 64), 0,
                                  cfg.vocab_size) for i in range(4)]
    from repro.core.planner import calibrate_predictor
    params = calibrate_predictor(params, cfg, batches)
    counts, n_tok = profile_activations(params, cfg, batches)
    plan = build_plan(cfg, (counts / n_tok).astype(np.float32), hw=PHONE)
    # Operating-point calibration: a briefly-trained reduced model is
    # far denser (~70% active) than the paper's trained 7Bs (~15%).
    # The plan budgets are the offline planner's choice — pin them to
    # the paper's regime; cluster *selection* stays real (calibrated
    # predictor on real hidden states).
    from repro.core.clusters import make_plan, scale_plan_for_batch
    base = make_plan(cfg.d_ff, 0.125, 0.10, cfg.sparse_ffn.cluster_size)
    plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b,
                                          cfg.sparse_ffn.cluster_size)
                  for b in (1, 2, 4, 8, 16, 32)}
    params = permute_ffn_params(params, plan.neuron_order)
    prompt = np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    return cfg, model, params, plan, prompt


def _train_with_cfg(cfg, params, steps, seed):
    import jax as _jax
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.models.model import build_model
    from repro.optim.adamw import AdamW
    from repro.train.steps import make_train_step
    model = build_model(cfg)
    opt = AdamW(lr=2e-3)
    step = _jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    state = opt.init(params)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=seed))
    losses = []
    for _ in range(steps):
        params, state, m = step(params, state, data.batch())
        losses.append(float(m["loss"]))
    return params, losses


def paper_timing():
    """Storage-plane cost constants at the paper's deployment size
    (Bamboo-7B FP16: 24KB Gate-Up-Down bundles, 32 layers)."""
    from repro.configs.paper_models import BAMBOO_7B
    from repro.serving.engine import TimingProfile
    return TimingProfile.from_config(BAMBOO_7B, 3)


def timeit(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n


def emit(rows):
    """Print the harness CSV: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
