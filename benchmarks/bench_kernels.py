"""Kernel micro-bench: gathered-cluster FFN vs dense FFN vs jnp oracle
(interpret mode on CPU — numbers are structural, not TPU wall time)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.ops import cluster_gather_ffn, dense_ffn
from repro.kernels.ref import cluster_gather_ffn_ref, dense_ffn_ref


def main():
    B, D, N, cs = 4, 256, 2048, 128
    x = jax.random.normal(jax.random.key(0), (B, D)) * 0.5
    w = jax.random.normal(jax.random.key(1), (N, 3, D)) * 0.1
    idx = jnp.arange(4, dtype=jnp.int32)   # 4 of 16 clusters active

    g = jax.jit(lambda: cluster_gather_ffn(
        x, w, idx, activation="silu", cluster_size=cs))
    gr = jax.jit(lambda: cluster_gather_ffn_ref(
        x, w, idx, activation="silu", cluster_size=cs))
    d = jax.jit(lambda: dense_ffn(x, w, activation="silu", block_n=cs))
    dr = jax.jit(lambda: dense_ffn_ref(x, w, activation="silu"))

    rows = []
    for name, fn in (("kernel_gather_interp", g), ("ref_gather_jnp", gr),
                     ("kernel_dense_interp", d), ("ref_dense_jnp", dr)):
        us = timeit(lambda: jax.block_until_ready(fn()), n=5) * 1e6
        rows.append((name, round(us, 1), "us/call CPU"))
    # structural metric: bytes fetched by the gather vs dense
    frac = idx.shape[0] * cs / N
    rows.append(("gather_weight_traffic_fraction", round(float(frac), 3),
                 "HBM->VMEM bytes vs dense (the cold-path win)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
