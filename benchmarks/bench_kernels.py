"""Kernel roofline bench: XLA vs the fused Pallas cold path, per
serving batch bucket (DESIGN.md §10).

For every serving bucket the engine actually decodes at, this times —
on the reduced smollm operating point the serving benches pin — the
dense FFN, the hybrid FFN under both cold-path backends, and the
cold-only path under both backends (the jnp score->top-k->gather chain
vs the one-pallas_call fused kernel with double-buffered cluster DMA),
asserting the two backends agree numerically while they race. Two
plan legs per bucket: `op`, the serving operating point (Fig-2 scaled
hot share, thin cold budget), and `deep`, a cold-heavy plan that keeps
several clusters in flight so the kernel's c+1-fetch-overlaps-c-compute
pipeline actually pipelines.

Besides the CSV rows it emits the BENCH_kernels.json artifact (same
--json convention as bench_serving) carrying per-bucket timings, the
weight-traffic fraction (bytes the gather moves vs dense — the
cold-path win the paper's Fig 6(b) pipeline banks on) and the
KernelCalibration block (core/io_model.py): measured dense/sparse
engine rates that replace HardwareProfile's hand-set constants, e.g.

  PYTHONPATH=src python -m benchmarks.bench_kernels --json \
      BENCH_kernels.json
  PYTHONPATH=src python -m benchmarks.bench_serving --kernel-calibration \
      BENCH_kernels.json ...

On this CPU container the kernels run in interpret mode, so absolute
times are structural, not TPU wall clock — the JSON's calibration
`source` says so; on a real TPU the same harness measures real rates.
"""
import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_config
from repro.core.clusters import make_plan, scale_plan_for_batch
from repro.core.io_model import KernelCalibration
from repro.core.sparse_ffn import ffn_dense, ffn_hybrid, init_ffn

BUCKETS = (1, 2, 4, 8, 16, 32)
TINY_BUCKETS = (1, 4)


def _legs(cfg):
    """(leg name, base plan) pairs: the serving operating point and a
    cold-heavy plan with a multi-cluster in-flight budget."""
    cs = cfg.sparse_ffn.cluster_size
    return (("op", make_plan(cfg.d_ff, 0.125, 0.10, cs)),
            ("deep", make_plan(cfg.d_ff, 0.125, 0.50, cs)))


def _flops(batch: int, n_neurons: int, R: int, D: int) -> float:
    """MACs*2 for `n_neurons` bundled rows: R GEMVs of D each."""
    return 2.0 * batch * n_neurons * R * D


def bench_bucket(params, cfg, plan, batch: int, reps: int):
    """Time every leg for one (bucket, plan); returns the JSON row."""
    D, N = cfg.d_model, cfg.d_ff
    R = params["w"].shape[1]
    act, mode = cfg.activation, cfg.sparse_ffn.mode
    x = jax.random.normal(jax.random.key(batch), (batch, D)) * 0.5
    p_jnp = dataclasses.replace(plan, backend="jnp")
    p_pal = dataclasses.replace(plan, backend="pallas")
    cold_jnp = dataclasses.replace(p_jnp, n_hot=0)
    cold_pal = dataclasses.replace(p_pal, n_hot=0)

    fns = {
        "t_dense_s": jax.jit(lambda: ffn_dense(params, x, act)),
        "t_xla_hybrid_s": jax.jit(
            lambda: ffn_hybrid(params, x, act, mode, p_jnp)),
        "t_pallas_hybrid_s": jax.jit(
            lambda: ffn_hybrid(params, x, act, mode, p_pal)),
        "t_xla_cold_s": jax.jit(
            lambda: ffn_hybrid(params, x, act, mode, cold_jnp)),
        "t_pallas_cold_s": jax.jit(
            lambda: ffn_hybrid(params, x, act, mode, cold_pal)),
    }
    row = {"batch": batch, "D": D, "N": N,
           "cs": plan.cluster_size, "n_hot": plan.n_hot,
           "k_cold": plan.k_cold,
           "clusters_in_flight": plan.clusters_per_group}
    for name, fn in fns.items():
        row[name] = timeit(lambda fn=fn: jax.block_until_ready(fn()),
                           n=reps, warmup=1)
    # the backends must agree while they race — a bench that silently
    # compared a wrong kernel would calibrate garbage
    np.testing.assert_allclose(np.asarray(fns["t_pallas_hybrid_s"]()),
                               np.asarray(fns["t_xla_hybrid_s"]()),
                               atol=1e-3, rtol=1e-3)

    # structural roofline inputs: work + weight traffic per call
    cold_total = cold_pal.total_cold        # gathered neurons, cold-only leg
    bpe = np.dtype(np.asarray(params["w"]).dtype).itemsize
    row.update(
        dense_flops=_flops(batch, N, R, D),
        cold_flops=_flops(batch, cold_total, R, D),
        gather_bytes=float(cold_total * R * D * bpe),
        # the cold-path win: fraction of the full weight bytes a decode
        # step actually touches (dense hot prefix + gathered clusters)
        weight_traffic_fraction=round(
            (plan.n_hot + plan.total_cold) / N, 4),
        gather_traffic_fraction=round(
            plan.total_cold / max(N - plan.n_hot, 1), 4),
    )
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m",
                    help="dense-family arch whose reduced config sets "
                         "the (D, N, cs) operating point")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: buckets (1, 4) only, fewer reps")
    ap.add_argument("--json", default=None,
                    help="write results JSON (BENCH_kernels.json "
                         "artifact, incl. the io_model calibration)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:]
                         if __name__ == "__main__" else [])

    cfg = get_config(args.arch).reduced()
    D, N = cfg.d_model, cfg.d_ff
    params = init_ffn(jax.random.key(0), D, N, cfg.activation, jnp.float32,
                      predictor_rank=cfg.sparse_ffn.predictor_rank)
    buckets = TINY_BUCKETS if args.tiny else BUCKETS
    reps = 3 if args.tiny else 5
    source = f"interpret-cpu jax {jax.__version__}" \
        if jax.default_backend() != "tpu" else f"tpu jax {jax.__version__}"

    rows, results = [], []
    for leg, base in _legs(cfg):
        for b in buckets:
            plan = scale_plan_for_batch(base, N, b, cfg.sparse_ffn
                                        .cluster_size)
            r = bench_bucket(params, cfg, plan, b, reps)
            r["leg"], r["source"] = leg, source
            results.append(r)
            tag = f"{leg}_b{b}"
            rows.append((f"kernels_{tag}_xla_cold",
                         round(r["t_xla_cold_s"] * 1e6, 1), "us/call CPU"))
            rows.append((f"kernels_{tag}_pallas_cold",
                         round(r["t_pallas_cold_s"] * 1e6, 1),
                         f"us/call CPU ({r['clusters_in_flight']} "
                         f"clusters in flight)"))
            rows.append((f"kernels_{tag}_weight_traffic_fraction",
                         r["weight_traffic_fraction"],
                         "step bytes vs dense (the cold-path win)"))

    calib = KernelCalibration.from_rows(results)
    rows.append(("kernels_calibrated_dense_gflops",
                 round(calib.dense_flops_per_s / 1e9, 3), calib.source))
    rows.append(("kernels_calibrated_sparse_gflops",
                 round(calib.sparse_flops_per_s / 1e9, 3), calib.source))
    emit(rows)

    if args.json:
        out = {"bench": "kernels", "arch": cfg.name, "tiny": bool(args.tiny),
               "D": D, "N": N, "cs": cfg.sparse_ffn.cluster_size,
               "activation": cfg.activation, "mode": cfg.sparse_ffn.mode,
               "results": results,
               "calibration": dataclasses.asdict(calib)}
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
