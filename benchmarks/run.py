"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig7 fig14 # filter by tag
"""
import sys
import time
import traceback

MODULES = [
    ("fig2_sparsity", "benchmarks.bench_sparsity"),
    ("fig6_pipeline", "benchmarks.bench_pipeline"),
    ("fig7_decode", "benchmarks.bench_decode"),
    ("fig8_prefill", "benchmarks.bench_prefill"),
    ("fig10_memory", "benchmarks.bench_memory"),
    ("table5_latency", "benchmarks.bench_latency"),
    ("fig13_bon", "benchmarks.bench_bon"),
    ("serving_stream", "benchmarks.bench_serving"),
    ("fig14_ablation", "benchmarks.bench_ablation"),
    ("table4_io_split", "benchmarks.bench_io_split"),
    ("table7_accuracy", "benchmarks.bench_accuracy"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for tag, modname in MODULES:
        if filters and not any(f in tag for f in filters):
            continue
        t0 = time.time()
        print(f"# --- {tag} ({modname}) ---", flush=True)
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
            print(f"# {tag} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {tag} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == '__main__':
    main()
