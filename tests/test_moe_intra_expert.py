"""Two-level MoE sparsity (DESIGN.md §9): expert-level gating composed
with intra-expert hot/cold neuron clusters — the paper's
TurboSparse-Mixtral path.

Covers the two-level `build_moe_plan` invariants (deterministic sweep
+ hypothesis property test), the per-expert hot-first permutation, the
(E, 1+ncc) trace -> flat-neuron-id mapping (corrupted traces raise
instead of silently under-pricing), expert-block shard ownership with
non-divisible E, the ep=1 golden (intra-expert decode token-identical
to dense-expert decode, with strictly cheaper cold I/O at batch 1),
and the dp replica cache-budget split.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import POWERINFER2
from repro.core.clusters import HybridPlan
from repro.core.planner import build_moe_plan, permute_moe_params
from repro.serving.engine import ServeEngine
from repro.serving.families import serving_family
from repro.serving.storage_plane import MoEStorageView

BASE = get_config("deepseek-moe-16b").reduced()
CS = BASE.sparse_ffn.cluster_size


def _check_plan(cfg):
    plan = build_moe_plan(cfg)
    E, k, f = cfg.num_experts, cfg.experts_per_token, cfg.d_ff
    S = cfg.num_shared_experts * f
    N = cfg.moe_flat_neurons
    prev_act = 0
    for b in sorted(plan.plans):
        p = plan.plans[b]
        n_act = min(max(int(round(E * (1.0 - (1.0 - k / E) ** b))),
                        min(k, E)), E)
        assert min(k, E) <= n_act <= E
        assert n_act >= prev_act, "n_act must be nondecreasing in batch"
        prev_act = n_act
        assert p.n_hot + p.k_cold <= N
        assert p.resident_hot >= p.n_hot
        if cfg.moe_intra_expert:
            h = p.n_expert_hot
            assert h % CS == 0 and 0 <= h <= f - CS
            assert p.n_hot == S + n_act * h
            assert p.n_pinned == S + E * h
            assert p.n_pinned <= N
            assert p.cluster_size == CS
            assert p.k_cold % n_act == 0
            kc_e = p.k_cold // n_act
            assert CS <= kc_e <= f - h
        else:
            assert p.n_hot == S and p.k_cold == n_act * f
            assert p.n_expert_hot == 0 and p.cluster_size == f
    # the flat order is a bijection per layer (identity shared prefix,
    # per-expert hot-first blocks)
    assert sorted(plan.neuron_order[0].tolist()) == list(range(N))
    if S:
        assert plan.neuron_order[0][:S].tolist() == list(range(S))
    if cfg.moe_intra_expert:
        for e in range(E):
            blk = plan.neuron_order[0][S + e * f: S + (e + 1) * f]
            assert sorted(blk.tolist()) == list(range(S + e * f,
                                                      S + (e + 1) * f))


def test_moe_plan_invariants_sweep():
    for (E, k), s, m, intra in itertools.product(
            [(1, 1), (2, 1), (4, 2), (6, 3), (8, 2)], (0, 1), (2, 16),
            (False, True)):
        _check_plan(BASE.replace(num_experts=E, experts_per_token=k,
                                 num_shared_experts=s, d_ff=CS * m,
                                 moe_intra_expert=intra))


def test_moe_plan_invariants_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def run(data):
        E = data.draw(st.integers(1, 8), label="E")
        k = data.draw(st.integers(1, E), label="k")
        s = data.draw(st.integers(0, 2), label="shared")
        m = data.draw(st.integers(2, 8), label="d_ff/cs")
        intra = data.draw(st.booleans(), label="intra")
        _check_plan(BASE.replace(num_experts=E, experts_per_token=k,
                                 num_shared_experts=s, d_ff=CS * m,
                                 moe_intra_expert=intra))

    run()


def test_non_multiple_cluster_dff_raises():
    cfg = BASE.replace(d_ff=CS * 2 + 1, moe_intra_expert=True)
    with pytest.raises(ValueError, match="multiple of"):
        build_moe_plan(cfg)


def test_bad_frequency_shape_raises():
    cfg = BASE.replace(moe_intra_expert=True)
    with pytest.raises(ValueError, match="L, E\\*f"):
        build_moe_plan(cfg, freqs=np.ones((cfg.num_layers, 7), np.float32))


# ------------------------------------------------ trace -> flat ids ----

def _two_level_cfg(E=2, shared=1, m=2):
    return BASE.replace(num_experts=E, num_shared_experts=shared,
                        experts_per_token=min(2, E), d_ff=CS * m,
                        moe_intra_expert=True)


def test_trace_cold_ids_two_level_mapping():
    cfg = _two_level_cfg()                       # f=64, S=64, E=2
    view = MoEStorageView(cfg)
    f, S = cfg.d_ff, cfg.num_shared_experts * cfg.d_ff
    plan = HybridPlan(n_hot=S + CS, k_cold=CS, cluster_size=CS,
                      n_expert_hot=CS, n_pinned=S + 2 * CS)
    ncc = (f - CS) // CS                         # 1 cold cluster/expert
    trace = np.array([[3, 1], [0, 0]], np.int32)
    assert trace.shape == (cfg.num_experts, 1 + ncc)
    ids = view.trace_cold_ids(trace, plan)
    # expert 0's single cold cluster: rows [S + n_hot_e, S + f)
    np.testing.assert_array_equal(ids, np.arange(S + CS, S + f))
    # both experts active -> both cold blocks
    trace = np.array([[3, 1], [2, 5]], np.int32)
    ids = view.trace_cold_ids(trace, plan)
    np.testing.assert_array_equal(
        ids, np.concatenate([np.arange(S + CS, S + f),
                             np.arange(S + f + CS, S + 2 * f)]))
    # an active expert whose cold clusters all stayed inactive pays
    # no cold I/O (its hot prefix is pinned)
    assert view.trace_cold_ids(np.array([[4, 0], [0, 0]], np.int32),
                               plan).size == 0


def test_corrupted_trace_raises_two_level():
    """A trace whose shape disagrees with the stepped plan (wrong
    n_hot -> wrong cluster count, wrong expert count) must raise, not
    silently drop ids as under-priced I/O."""
    cfg = _two_level_cfg(m=4)                    # f=128, ncc=3 at h=CS
    view = MoEStorageView(cfg)
    S = cfg.num_shared_experts * cfg.d_ff
    plan = HybridPlan(n_hot=S + CS, k_cold=CS, cluster_size=CS,
                      n_expert_hot=CS, n_pinned=S + 2 * CS)
    good = np.zeros((2, 4), np.int32)
    view.trace_cold_ids(good, plan)              # shape matches: fine
    with pytest.raises(ValueError, match="two-level MoE trace shape"):
        view.trace_cold_ids(np.zeros((2, 3), np.int32), plan)  # wrong ncc
    with pytest.raises(ValueError, match="two-level MoE trace shape"):
        view.trace_cold_ids(np.zeros((3, 4), np.int32), plan)  # wrong E


def test_corrupted_trace_raises_whole_expert():
    cfg = _two_level_cfg().replace(moe_intra_expert=False)
    view = MoEStorageView(cfg)
    plan = HybridPlan(n_hot=cfg.d_ff, k_cold=cfg.d_ff,
                      cluster_size=cfg.d_ff)
    view.trace_cold_ids(np.array([1, 0], np.int32), plan)
    with pytest.raises(ValueError, match="disagree about the expert"):
        view.trace_cold_ids(np.array([1, 0, 2], np.int32), plan)


# -------------------------------------------------- shard ownership ----

def test_owner_of_non_divisible_expert_blocks():
    """E % n_shards != 0 must mirror the divisible layout — clamped
    contiguous expert blocks + a uniform shared-prefix split — instead
    of round-robining every id (which scattered the pinned shared
    prefix and disagreed with `_moe_ep_shard_map`)."""
    cfg = _two_level_cfg(E=6, shared=1, m=2)     # f=64, S=64
    f, S = cfg.d_ff, 64
    for view in (MoEStorageView(cfg),
                 MoEStorageView(cfg.replace(moe_intra_expert=False))):
        ids = np.arange(view.n_neurons)
        owner = view.owner_of(ids, None, 4)      # ceil(6/4) = 2/shard
        # every expert block is wholly owned, blocks are contiguous
        for e in range(6):
            blk = owner[S + e * f: S + (e + 1) * f]
            assert (blk == e // 2).all(), (e, set(blk.tolist()))
        # the pinned shared prefix splits uniformly (not round-robin)
        sh = owner[:S]
        assert (np.diff(sh) >= 0).all()
        assert set(sh.tolist()) == set(range(4))
    # divisible case keeps the historical layout: E/n whole experts
    view = MoEStorageView(_two_level_cfg(E=4, shared=1, m=2))
    owner = view.owner_of(np.arange(view.n_neurons), None, 2)
    for e in range(4):
        blk = owner[S + e * f: S + (e + 1) * f]
        assert (blk == e // 2).all()


# ----------------------------------------------------- end to end ----

@pytest.fixture(scope="module")
def trained():
    """Briefly-trained reduced TurboSparse-Mixtral: real logit margins
    so greedy decode is robust to the per-expert permutation's fp
    reassociation noise (~1e-5), mirroring the distributed goldens."""
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.models.model import build_model
    from repro.optim.adamw import AdamW
    from repro.train.steps import make_train_step
    cfg = get_config("turbosparse-mixtral-47b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=2e-3)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    state = opt.init(params)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=0))
    for _ in range(20):
        params, state, _ = step(params, state, data.batch())
    return cfg, params


def test_permutation_preserves_moe_output(trained):
    """The per-expert hot-first permutation is numerics-preserving:
    MoE layer outputs match up to fp reassociation."""
    from repro.models.moe import apply_moe_ffn
    cfg, params = trained
    plan = build_moe_plan(cfg)
    p2 = permute_moe_params(params, plan.neuron_order)
    x = jax.random.normal(jax.random.key(5), (4, cfg.d_model)) * 0.1
    for l in range(cfg.num_layers):
        l0 = jax.tree.map(lambda a, l=l: a[l], params["layers"]["moe"])
        l1 = jax.tree.map(lambda a, l=l: a[l], p2["layers"]["moe"])
        y0, _ = apply_moe_ffn(l0, x, cfg)
        y1, _ = apply_moe_ffn(l1, x, cfg)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-4, rtol=1e-4)


def _run_engine(cfg, params, plan, prompt, max_new=6):
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2), ctx_budget=32,
                      temperature=0.0, seed=0)
    res = eng.generate(prompt, max_new=max_new, temperature=0.0)
    n_tok = sum(s.batch for s in res.stats)
    bytes_tok = eng.coldstore.total_bytes / max(n_tok, 1)
    eng.close()
    return res, bytes_tok


def test_intra_expert_golden_token_identical_and_cheaper(trained):
    """The ep=1 golden: intra-expert decode (two-level plan, permuted
    params) is token-identical to dense-expert decode (whole-expert
    plan, unpermuted params) — the trace thresholds the same dense
    GEMMs — and intra-expert pricing strictly reduces modeled
    cold-store bytes/token at batch 1."""
    cfg, params = trained
    fam = serving_family(cfg)
    plan = fam.build_plan(cfg)
    assert all(p.n_expert_hot > 0 for p in plan.plans.values())
    p_intra = fam.prepare_params(params, plan)
    cfgw = cfg.replace(moe_intra_expert=False)
    planw = serving_family(cfgw).build_plan(cfgw)
    assert serving_family(cfgw).prepare_params(params, planw) is params

    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)).astype(np.int32)
    res_i, _ = _run_engine(cfg, p_intra, plan, prompt)
    res_w, _ = _run_engine(cfgw, params, planw, prompt)
    np.testing.assert_array_equal(res_i.tokens, res_w.tokens)
    assert (res_i.tokens >= 0).all()

    # batch 1: strictly fewer modeled cold-store bytes per token
    _, b_i = _run_engine(cfg, p_intra, plan, prompt[:1])
    _, b_w = _run_engine(cfgw, params, planw, prompt[:1])
    assert b_i < b_w, (b_i, b_w)


def test_two_level_trace_shape_and_content(trained):
    """The traced decode emits (L, E, 1+ncc): column 0 the kept
    dispatch counts, the rest real cold-cluster activations — an
    expert with no kept tokens can't activate a cluster."""
    cfg, params = trained
    fam = serving_family(cfg)
    plan_all = fam.build_plan(cfg)
    p = fam.prepare_params(params, plan_all)
    plan = plan_all.plan_for_batch(2)
    model = fam.make_model(cfg)
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    _, cache = model.prefill(p, {"tokens": prompt}, max_len=12)
    step = fam.make_decode_step(cfg)
    toks = jnp.asarray(np.array([[3], [5]], np.int32))
    _, _, trace = step(p, toks, cache, plan, jnp.ones((2,), bool))
    tr = np.asarray(trace)
    ncc = (cfg.d_ff - plan.n_expert_hot) // plan.cluster_size
    assert tr.shape == (cfg.num_layers, cfg.num_experts, 1 + ncc)
    assert (tr >= 0).all()
    kept = tr[:, :, 0]
    assert (kept.sum(axis=1) == 2 * cfg.experts_per_token).all()
    assert (tr[:, :, 1:].sum(axis=2)[kept == 0] == 0).all()
    # a dead lane must not contribute: masking row 1 changes the trace
    _, _, tr_masked = step(p, toks, cache, plan,
                           jnp.asarray([True, False]))
    km = np.asarray(tr_masked)[:, :, 0]
    assert (km.sum(axis=1) == cfg.experts_per_token).all()


# -------------------------------------------- dp replica budgeting ----

def test_dp_replica_residency_within_one_budget():
    """Satellite bugfix: with dp=N each replica's StoragePlane used to
    claim the FULL resident budget, so modeled residency exceeded the
    device budget N times over. Capacity now splits over the 'data'
    axis like DESIGN.md §3 splits it over 'model'."""
    cfg = get_config("smollm-135m").reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = fam.build_plan(cfg)
    params = fam.prepare_params(params, plan)

    def make(dp=None):
        return ServeEngine(cfg, params, plan, buckets=(1, 2),
                           ctx_budget=40, temperature=0.8, seed=0, dp=dp)

    e1 = make()
    budget = e1.storage.resident_capacity_neurons
    assert budget == int(cfg.d_ff * 0.5) * cfg.num_layers
    try:
        for dp in (2, 4):
            edp = make(dp=dp)
            per = [r.storage.resident_capacity_neurons
                   for r in edp.replicas]
            assert sum(per) <= budget
            assert sum(per) == budget    # even splits lose nothing
            assert max(per) - min(per) <= per[0] // 4   # balanced
            edp.close()
    finally:
        e1.close()
