"""Family-conformance harness (DESIGN.md §8): every servable family —
dense, vlm, moe — runs through ONE shared battery of serving-contract
tests, parametrized over the registry. A future family plugs into the
grid by registering a `ServingFamily` and adding its arch below,
instead of re-deriving engine tests.

The battery:
  * submit/cancel/drain lifecycle (queued cancels finish tokenless,
    TTFT never sees them);
  * golden token-identity of the static-batch `generate()` compat
    wrapper vs the streaming submit/run_until_drained path;
  * empty-report stats (whole stream cancelled before any step);
  * KV-arena exhaustion guards (oversized requests rejected, slot
    accounting conserved);
  * fleet-gateway scenarios (DESIGN.md §11) over a two-engine fleet of
    the family: circuit-breaker open -> half-open -> closed recovery
    around a backend loss, response-LRU hits replaying the cached
    request's exact tokens with zero extra decode work, and draining
    (in-flight finishes, no new dispatches land).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import POWERINFER2
from repro.serving.engine import ServeEngine
from repro.serving.families import default_archs, servable_families, \
    serving_family

# one representative arch per registered family — extend this map when
# registering a new family and the whole battery applies to it
FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "vlm": "qwen2-vl-2b",
    "moe": "deepseek-moe-16b",
}

# additional archs that exercise a distinct serving mode of an
# already-registered family (not a family of their own, so they ride
# through the same battery without their own registry entry):
# turbosparse = two-level MoE sparsity (intra-expert hot/cold clusters
# + per-expert hot-first permutation, DESIGN.md §9)
EXTRA_BATTERY_ARCHS = ("turbosparse-mixtral-47b",)

BATTERY_ARCHS = sorted(FAMILY_ARCHS.values()) + list(EXTRA_BATTERY_ARCHS)


def test_every_registered_family_is_in_the_battery():
    """The harness must cover exactly the registry: a family
    registered without a conformance arch (or a default_arch that
    drifted from the battery's) fails here, keeping the grid, the
    registry and launch/serve.py --family in lock-step."""
    assert set(FAMILY_ARCHS) == set(servable_families())
    assert FAMILY_ARCHS == default_archs()


def test_unregistered_family_raises_with_servable_set():
    cfg = get_config("mamba2-130m")            # ssm: not servable
    with pytest.raises(ValueError, match="ssm.*not servable"):
        serving_family(cfg)
    with pytest.raises(ValueError, match="moe"):
        serving_family(cfg)                    # names the servable set


@pytest.fixture(scope="module", params=BATTERY_ARCHS)
def family_setup(request):
    """(family, cfg, params, plan, prompt) for one servable family
    (plus the extra serving-mode archs), built through the registry
    exactly as launch/serve.py builds it."""
    cfg = get_config(request.param).reduced()
    family = cfg.family
    if request.param in FAMILY_ARCHS.values():
        assert FAMILY_ARCHS[family] == request.param
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = fam.build_plan(cfg)
    params = fam.prepare_params(params, plan)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)).astype(np.int32)
    return family, cfg, params, plan, prompt


def _engine(setup, **kw):
    _, cfg, params, plan, _ = setup
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("ctx_budget", 32)
    kw.setdefault("temperature", 0.8)
    return ServeEngine(cfg, params, plan, spec=POWERINFER2,
                       offload_ratio=0.5, seed=0, **kw)


# ------------------------------------------------- shared battery ----

def test_submit_cancel_drain(family_setup):
    family, cfg, _, _, prompt = family_setup
    eng = _engine(family_setup)
    try:
        uids = [eng.submit(prompt[i % 2], max_new=3) for i in range(3)]
        eng.cancel([uids[2]])                  # still queued: tokenless
        rep = eng.run_until_drained()
        assert not eng.sched.has_work
        seqs = eng.sched.sequences
        assert all(seqs[u].finished for u in uids)
        assert seqs[uids[2]].generated == []
        assert seqs[uids[2]].first_token_time is None
        assert all(len(seqs[u].generated) == 3 for u in uids[:2])
        assert rep.ttft().size == 2            # cancelled filtered
        assert rep.total_tokens == sum(s.batch for s in rep.stats) == 6
        assert rep.span_s > 0 and rep.throughput_tok_s > 0
        # every step produced a live trace the storage plane priced
        assert all(s.effective_s > 0 for s in rep.stats)
    finally:
        eng.close()


def test_generate_token_identical_to_stream(family_setup):
    """The compat wrapper and the streaming path must decode the same
    tokens — same executables, same sampling-key chain — whatever the
    family's data plane looks like."""
    family, cfg, _, _, prompt = family_setup
    gen = _engine(family_setup)
    srv = _engine(family_setup)
    try:
        res = gen.generate(prompt, max_new=4, temperature=0.8)
        uids = [srv.submit(prompt[i], max_new=4) for i in range(2)]
        srv.run_until_drained()
        stream = np.stack([srv.sched.sequences[u].generated
                           for u in uids]).astype(np.int32)
        np.testing.assert_array_equal(res.tokens, stream)
        # determinism: a fresh engine reproduces the stream exactly
        srv2 = _engine(family_setup)
        try:
            uids2 = [srv2.submit(prompt[i], max_new=4) for i in range(2)]
            srv2.run_until_drained()
            again = np.stack([srv2.sched.sequences[u].generated
                              for u in uids2]).astype(np.int32)
            np.testing.assert_array_equal(stream, again)
        finally:
            srv2.close()
    finally:
        gen.close(), srv.close()


def test_empty_report_stats(family_setup):
    """Cancelling the whole stream before any step must yield a
    well-formed zero report for every family (no percentile crash, no
    inf rates, no TTFT coercion)."""
    eng = _engine(family_setup)
    try:
        _, cfg, _, _, prompt = family_setup
        uids = [eng.submit(prompt[0], max_new=4) for _ in range(2)]
        eng.cancel(uids)
        rep = eng.run_until_drained()
        assert rep.stats == [] and len(rep.requests) == 2
        assert rep.ttft().size == 0
        assert rep.tokens_per_s == 0.0 and rep.throughput_tok_s == 0.0
        assert rep.latency_percentiles()["p99"] == 0.0
    finally:
        eng.close()


# ------------------------------------------- gateway battery (§11) ----

def _fleet_gateway(setup, n=2, **kw):
    from repro.serving.gateway import FleetGateway, local_fleet
    _, cfg, params, plan, _ = setup
    backends = local_fleet(cfg, params, plan, n, spec=POWERINFER2,
                           offload_ratio=0.5, seed=0, buckets=(1, 2, 4),
                           ctx_budget=32, temperature=0.8)
    return FleetGateway(backends, heartbeat_s=0.001, **kw)


def test_gateway_breaker_recovers_after_loss(family_setup):
    """Backend loss mid-stream for every family: the heartbeat trips
    the breaker open, recalled work redispatches and completes on the
    survivor, and after restore the half-open canary closes the
    breaker — the rejoined backend serves again. No request drops."""
    from repro.serving.gateway import CLOSED
    family, cfg, _, _, prompt = family_setup
    gw = _fleet_gateway(family_setup)
    gw.backends[1].breaker.open_timeout_s = 0.002
    try:
        rng = np.random.default_rng(1)
        for _i in range(6):
            gw.submit(rng.integers(0, cfg.vocab_size, 12), max_new=3,
                      arrival_time=0.0)
        while not gw.backends[1].inflight:
            assert gw.step()
        lost = list(gw.backends[1].inflight.values())
        gw.fail_backend(1)
        gw.restore_backend(1, at=gw.clock_s + 0.004)
        # traffic past the rejoin so the half-open canary path runs
        for i in range(4):
            gw.submit(rng.integers(0, cfg.vocab_size, 12), max_new=3,
                      arrival_time=gw.clock_s + 0.005 + 0.001 * i)
        rep = gw.run_until_drained()
        assert rep.drained and rep.n_rejected == 0
        assert rep.n_completed == 10
        assert rep.n_retries >= len(lost) >= 1
        assert all(gw.requests[u].done and not gw.requests[u].rejected
                   for u in lost)
        b1 = gw.backends[1]
        assert b1.alive and b1.breaker.state == CLOSED
        assert b1.n_completed >= 1          # served after rejoining
    finally:
        gw.close()


def test_gateway_lru_hit_is_token_identical_no_second_decode(family_setup):
    """A repeated request is a response-LRU hit for every family: it
    replays the cached request's exact tokens and costs zero backend
    decode steps (and zero submits)."""
    family, cfg, _, _, prompt = family_setup
    gw = _fleet_gateway(family_setup, cache_capacity=8)
    try:
        u1 = gw.submit(prompt[0], max_new=3, arrival_time=0.0)
        gw.run_until_drained()
        steps = sum(b.n_steps for b in gw.backends)
        disp = sum(b.n_dispatched for b in gw.backends)
        u2 = gw.submit(prompt[0], max_new=3, arrival_time=gw.clock_s)
        rep = gw.run_until_drained()
        assert gw.requests[u2].cache_hit
        assert gw.requests[u2].tokens == gw.requests[u1].tokens
        assert len(gw.requests[u2].tokens) == 3
        assert sum(b.n_steps for b in gw.backends) == steps
        assert sum(b.n_dispatched for b in gw.backends) == disp
        assert rep.cache_hits == 1 and rep.drained
        # the hit is instantaneous on the fleet clock; the miss wasn't
        assert float(rep.ttft_hit[0]) == 0.0
        assert float(rep.ttft_miss[0]) > 0.0
    finally:
        gw.close()


def test_gateway_draining_backend_finishes_inflight_no_new(family_setup):
    """Draining for every family: the drained backend completes its
    in-flight requests, receives no new dispatches, and the stream
    still drains without drops (the rolling-restart contract)."""
    family, cfg, _, _, prompt = family_setup
    gw = _fleet_gateway(family_setup)
    try:
        rng = np.random.default_rng(2)
        for _i in range(4):
            gw.submit(rng.integers(0, cfg.vocab_size, 12), max_new=3,
                      arrival_time=0.0)
        while not gw.backends[1].inflight:
            assert gw.step()
        inflight = list(gw.backends[1].inflight.values())
        disp_before = gw.backends[1].n_dispatched
        gw.drain_backend(1)
        for _i in range(4):
            gw.submit(rng.integers(0, cfg.vocab_size, 12), max_new=3,
                      arrival_time=gw.clock_s)
        rep = gw.run_until_drained()
        assert rep.drained and rep.n_rejected == 0
        assert rep.n_completed == 8
        assert gw.backends[1].n_dispatched == disp_before
        assert not gw.backends[1].inflight
        assert all(gw.requests[u].done and not gw.requests[u].rejected
                   for u in inflight)
    finally:
        gw.close()


def test_kv_arena_exhaustion(family_setup):
    """Oversized requests are rejected with the ctx_budget hint both
    at submit time (arena live) and admission time; slot accounting
    stays conserved through completions."""
    family, cfg, _, _, prompt = family_setup
    eng = _engine(family_setup, ctx_budget=16)
    try:
        uid = eng.submit(prompt[0], max_new=2)     # 12 + 2 <= 16: fits
        assert eng.step() is not None              # arena exists now
        with pytest.raises(ValueError, match="raise ctx_budget"):
            eng.submit(prompt[1], max_new=8)       # 12 + 8 > 16
        eng.run_until_drained()
        assert eng.sched.sequences[uid].finished
        assert eng.arena.n_free == eng.arena.n_slots
        # the arena refuses double-allocation outright
        with pytest.raises(RuntimeError):
            for i in range(eng.arena.n_slots + 1):
                eng.arena.alloc(1000 + i)
    finally:
        eng.close()
