"""The paper's technique: hybrid hot/cold FFN correctness properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clusters import HybridPlan, make_plan, scale_plan_for_batch
from repro.core.sparse_ffn import ffn_dense, ffn_hybrid, init_ffn
from repro.core.predictor import predict_scores


def _params(D=64, N=512, act="relu2", rank=16, seed=0):
    return init_ffn(jax.random.key(seed), D, N, act, jnp.float32,
                    predictor_rank=rank)


def test_hybrid_equals_dense_at_full_budget():
    """hot=100% makes the hybrid path exactly the dense path."""
    D, N = 64, 512
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(1), (4, D)) * 0.5
    plan = HybridPlan(n_hot=N, k_cold=0, groups=1, cluster_size=64)
    yh = ffn_hybrid(p, x, "relu2", "relu", plan)
    yd = ffn_dense(p, x, "relu2")
    np.testing.assert_allclose(np.asarray(yh), np.asarray(yd),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_hybrid_cold_only_selects_top_clusters(backend):
    """With hot=0, the computed output must equal manually gathering the
    predictor's top clusters — under both cold-path backends."""
    D, N, cs = 64, 512, 64
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(2), (2, D)) * 0.5
    plan = HybridPlan(n_hot=0, k_cold=128, groups=1, cluster_size=cs,
                      backend=backend)
    y = ffn_hybrid(p, x, "relu2", "relu", plan)
    scores = predict_scores(p["pred"], x)
    union = np.asarray(scores).max(0)
    cscore = union.reshape(N // cs, cs).max(-1)
    top = np.argsort(-cscore)[:2]
    w = np.asarray(p["w"]).reshape(N // cs, cs, 3, D)
    xs = np.asarray(x)
    g = np.einsum("bd,kd->bk", xs, w[top].reshape(-1, 3, D)[:, 0])
    u = np.einsum("bd,kd->bk", xs, w[top].reshape(-1, 3, D)[:, 1])
    h = np.square(np.maximum(g, 0)) * u
    ref = np.einsum("bk,kd->bd", h, w[top].reshape(-1, 3, D)[:, 2])
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-3, rtol=1e-3)


def test_hybrid_approaches_dense_as_budget_grows():
    """Approximation error must fall monotonically-ish with cold budget
    (relu2 zeros make the missing clusters mostly irrelevant)."""
    D, N, cs = 64, 1024, 64
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(3), (4, D)) * 0.5
    yd = np.asarray(ffn_dense(p, x, "relu2"))
    errs = []
    for ratio in (0.125, 0.25, 0.5, 1.0):
        k = int(N * ratio)
        plan = HybridPlan(n_hot=0, k_cold=k, groups=1, cluster_size=cs)
        yh = np.asarray(ffn_hybrid(p, x, "relu2", "relu", plan))
        errs.append(np.linalg.norm(yh - yd) / np.linalg.norm(yd))
    assert errs[-1] < 1e-5                       # full budget == dense
    assert errs[0] > errs[-1]
    assert errs[1] >= errs[2] - 1e-6


def test_grouped_equals_ungrouped():
    """Group partitioning (sharding) must not change the selected-cluster
    set when scores are spread evenly — validated via equal budgets."""
    D, N, cs = 64, 512, 32
    p = _params(D, N, rank=8, seed=5)
    x = jax.random.normal(jax.random.key(6), (2, D)) * 0.5
    # all clusters selected -> grouping irrelevant
    plan1 = HybridPlan(n_hot=0, k_cold=N, groups=1, cluster_size=cs)
    plan4 = HybridPlan(n_hot=0, k_cold=N // 4, groups=4, cluster_size=cs)
    y1 = ffn_hybrid(p, x, "relu2", "relu", plan1)
    y4 = ffn_hybrid(p, x, "relu2", "relu", plan4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("mode", ["relu", "cats"])
def test_pallas_backend_matches_jnp(mode):
    """The fused pallas cold path must match jnp in output AND in the
    selected cluster ids — including mode='cats', whose per-token
    gating the old pallas branch silently dropped (the reduced smollm
    serving config runs CATS, so this is the token-identity keystone).
    """
    D, N = 64, 512
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(7), (2, D)) * 0.5
    pj = make_plan(N, 0.25, 0.25, 64, groups=2)
    pp = dataclasses.replace(pj, backend="pallas")
    yj, cj = ffn_hybrid(p, x, "relu2", mode, pj, return_indices=True)
    yp, cp = ffn_hybrid(p, x, "relu2", mode, pp, return_indices=True)
    np.testing.assert_array_equal(np.asarray(cj), np.asarray(cp))
    np.testing.assert_allclose(np.asarray(yj), np.asarray(yp),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("mode", ["relu", "cats"])
def test_pallas_active_mask_parity(mode):
    """Freed-lane masking steers selection identically on both
    backends: a masked row must not vote in the batch union."""
    D, N = 64, 512
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(9), (4, D)) * 0.5
    mask = jnp.array([True, False, True, False])
    pj = make_plan(N, 0.25, 0.25, 64, groups=2)
    pp = dataclasses.replace(pj, backend="pallas")
    yj, cj = ffn_hybrid(p, x, "relu2", mode, pj, return_indices=True,
                        active_mask=mask)
    yp, cp = ffn_hybrid(p, x, "relu2", mode, pp, return_indices=True,
                        active_mask=mask)
    np.testing.assert_array_equal(np.asarray(cj), np.asarray(cp))
    np.testing.assert_allclose(np.asarray(yj)[np.asarray(mask)],
                               np.asarray(yp)[np.asarray(mask)],
                               atol=1e-3, rtol=1e-3)
    # and the mask must matter: all-active selection differs somewhere
    _, c_all = ffn_hybrid(p, x, "relu2", mode, pp, return_indices=True)
    assert cp.shape == c_all.shape


def test_make_plan_alignment():
    for N, hot, cold, cs, g in [(1536, 0.25, 0.15, 64, 16),
                                (24576, 0.25, 0.1, 128, 16),
                                (512, 0.5, 0.5, 32, 4)]:
        plan = make_plan(N, hot, cold, cs, groups=g)
        n_cold = N - plan.n_hot
        assert n_cold % (g * cs) == 0
        assert plan.k_cold % cs == 0
        assert 0 <= plan.n_hot <= N


def test_batch_scaling_grows_hot_share():
    base = make_plan(4096, 0.2, 0.1, 128, groups=1)
    hots = [scale_plan_for_batch(base, 4096, b, 128).n_hot
            for b in (1, 4, 16, 32)]
    assert hots == sorted(hots)
    assert hots[-1] > hots[0]


def test_return_indices_shape():
    D, N, cs = 64, 512, 64
    p = _params(D, N)
    x = jax.random.normal(jax.random.key(8), (2, D)) * 0.5
    plan = HybridPlan(n_hot=128, k_cold=128, groups=2, cluster_size=cs)
    y, cidx = ffn_hybrid(p, x, "relu2", "relu", plan, return_indices=True)
    assert cidx.shape == (2, 2)                 # (groups, clusters/group)
    nc_g = (N - plan.n_hot) // plan.groups // cs
    assert (np.asarray(cidx) >= 0).all() and (np.asarray(cidx) < nc_g).all()


def test_shard_map_cold_path_matches_local():
    """§Perf C4: the shard-local cold path must equal the grouped path.

    Runs in a subprocess-free way by spawning a mesh of host devices is
    not possible here (device count locks at first jax use), so this
    test exercises the code path only when the session already has >=4
    devices; otherwise it checks the selector logic.
    """
    import jax
    from repro.core.sparse_ffn import _use_shard_map

    if jax.device_count() < 4:
        # no mesh in context -> never selects shard_map
        assert _use_shard_map(4) is False
        return

    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import AxisType, make_mesh, set_mesh
    D, N, cs, G = 64, 512, 32, 4
    params = _params(D, N)
    x = jax.random.normal(jax.random.key(1), (2, D)) * 0.5
    plan = HybridPlan(n_hot=128, k_cold=64, groups=G, cluster_size=cs)
    y_local = ffn_hybrid(params, x, "relu2", "relu", plan)
    mesh = make_mesh((1, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    with set_mesh(mesh):
        pspec = {"w": NamedSharding(mesh, P("model", None, None)),
                 "pred": {"A": NamedSharding(mesh, P(None, None)),
                          "B": NamedSharding(mesh, P(None, "model"))}}
        params_s = jax.tree.map(jax.device_put, params, pspec)
        y_sm = jax.jit(lambda p, xx: ffn_hybrid(p, xx, "relu2", "relu",
                                                plan))(params_s, x)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_local),
                               atol=1e-3, rtol=1e-3)
