"""Launch layer: spec fitting, input specs, collective parsing,
roofline math — all without touching the 512-device dry-run."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.dryrun import parse_collectives, _shape_bytes
from repro.launch.input_specs import adapt_config, input_specs
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import analyze_record, model_flops
from repro.sharding import _filter_spec


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_filter_spec_drops_nondividing_axes():
    m = FakeMesh()
    # batch=1 cannot shard over data=16
    assert _filter_spec(P("data", None), m, shape=(1, 8)) == P(None, None)
    assert _filter_spec(P("data", None), m, shape=(32, 8)) == P("data", None)
    # tuple axes: ('pod','data') with pod absent -> ('data',)
    assert _filter_spec(P(("pod", "data")), m, shape=(32,)) == P(("data",))
    # unknown axis names dropped entirely
    assert _filter_spec(P("nope", "model"), m, shape=(4, 32)) == \
        P(None, "model")


def test_shape_bytes():
    assert _shape_bytes("bf16[8,64]") == 8 * 64 * 2
    assert _shape_bytes("f32[2,2]") == 16
    assert _shape_bytes("(bf16[8], f32[4])") == 16 + 16
    assert _shape_bytes("pred[10]") == 10


def test_parse_collectives():
    hlo = """
ENTRY %main {
  %ar = bf16[8,64] all-reduce(%x), replica_groups={}
  %ag.1 = f32[16,16]{1,0} all-gather(%y), dimensions={0}
  %cp = bf16[4,4] collective-permute-start(%z)
  %cpd = bf16[4,4] collective-permute-done(%cp)
  %notacoll = bf16[8] add(%a, %b)
}
"""
    out = parse_collectives(hlo)
    assert out["bytes"]["all-reduce"] == 8 * 64 * 2
    assert out["bytes"]["all-gather"] == 16 * 16 * 4
    assert out["bytes"]["collective-permute"] == 4 * 4 * 2  # start only
    assert out["counts"]["all-to-all"] == 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    """Every (arch x shape) pair must produce well-formed input specs —
    the cheap half of the dry-run guarantee."""
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape)
    if shape_name == "long_500k":
        assert cfg.subquadratic, f"{arch} must decode 500k sub-quadratically"
    mesh = make_host_mesh()
    specs = input_specs(cfg, shape, mesh)
    toks = specs["tokens"]
    if shape.kind == "decode":
        assert toks.shape == (shape.global_batch, 1)
    elif cfg.family == "vlm":
        assert toks.shape[1] + cfg.num_image_tokens == shape.seq_len
    else:
        assert toks.shape == (shape.global_batch, shape.seq_len)
    if cfg.family == "encdec" and shape.kind != "decode":
        assert specs["frames"].shape == (shape.global_batch,
                                         cfg.num_frames, cfg.d_model)


def test_roofline_terms_and_dominance():
    rec = {"arch": "smollm-135m", "shape": "decode_32k",
           "flops_per_device": 197e12, "bytes_per_device": 819e9,
           "n_devices": 256,
           "collectives": {"bytes": {"all-reduce": 50e9 * 2},
                           "counts": {}}}
    out = analyze_record(rec)
    assert abs(out["compute_s"] - 1.0) < 1e-6
    assert abs(out["memory_s"] - 1.0) < 1e-6
    assert abs(out["collective_s"] - 2.0) < 1e-6
    assert out["dominant"] == "collective"


def test_dispatch_groups_single_source_of_truth():
    """MoE dispatch groups derive from launch.mesh.dispatch_groups
    everywhere: one group per (pod x data) row, 1 without a mesh, and
    1 on a serving replica's (1, n_model) submesh — which is what
    makes dp x tp x ep compose (each replica dispatches over exactly
    its local tokens)."""
    from repro.launch.mesh import dispatch_groups

    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    class ReplicaSubmesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 4}

    assert dispatch_groups(None) == 1
    assert dispatch_groups(PodMesh()) == 32
    assert dispatch_groups(FakeMesh()) == 16
    assert dispatch_groups(ReplicaSubmesh()) == 1


def test_dryrun_moe_group_inference_deduplicated():
    """Satellite regression: the two dry-run lowering paths used to
    re-derive moe_dispatch_groups inline; both must now go through
    adapt_moe_groups (which defers to the shared mesh helper), and
    the adapter passes non-MoE configs through untouched."""
    import inspect
    from repro.launch import dryrun
    src = inspect.getsource(dryrun)
    assert src.count("cfg = adapt_moe_groups(cfg, mesh)") == 2  # both paths
    assert "moe_dispatch_groups=nb" not in src             # inline gone
    cfg = get_config("deepseek-moe-16b")
    assert dryrun.adapt_moe_groups(cfg, FakeMesh()) \
        .moe_dispatch_groups == 16
    dense = get_config("smollm-135m")
    assert dryrun.adapt_moe_groups(dense, FakeMesh()) is dense


def test_dryrun_moe_decode_smoke():
    """The moe family's decode dry-run path end to end (adapt config,
    infer groups, lower the decode step on the mesh) — the cheap
    1-device half of the 256-device sweep guarantee."""
    from repro.compat import set_mesh
    from repro.launch.dryrun import adapt_moe_groups, decode_plan_for
    from repro.launch.input_specs import cache_specs, param_specs
    from repro.models.model import build_model

    shape = INPUT_SHAPES["decode_32k"]
    mesh = make_host_mesh()
    cfg = adapt_config(get_config("deepseek-moe-16b"), shape).reduced()
    cfg = adapt_moe_groups(cfg, mesh)
    assert cfg.moe_dispatch_groups == 1        # host mesh: data == 1
    assert decode_plan_for(cfg, mesh.shape["model"]) is None  # router=plan
    model = build_model(cfg)
    with set_mesh(mesh):
        pspecs = param_specs(model, cfg, mesh)
        batch = input_specs(cfg, shape, mesh)
        cspecs = cache_specs(model, cfg, shape, mesh)
        lowered = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, None)).lower(
            pspecs, batch["tokens"], cspecs)
    # the lowered program must actually carry the stacked expert
    # tensor (L, E, f, R, D) — a dense-only fallthrough would drop it
    from repro.core.sparse_ffn import ffn_rows
    expert_dims = "x".join(map(str, (
        cfg.num_layers, cfg.num_experts, cfg.d_ff,
        ffn_rows(cfg.activation), cfg.d_model)))
    assert expert_dims in lowered.as_text()


def test_model_flops_moe_uses_active_params():
    dense = model_flops("qwen3-14b", "train_4k")
    moe_total = get_config("deepseek-moe-16b").param_count()
    moe_active = get_config("deepseek-moe-16b").active_param_count()
    assert moe_active < moe_total * 0.6
    assert model_flops("deepseek-moe-16b", "train_4k") == \
        6 * moe_active * 256 * 4096
    assert dense > 0
