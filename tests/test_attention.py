"""Attention substrate: flash vs naive oracle, decode vs full,
sliding window, RoPE / M-RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    NEG_INF, apply_rotary, decode_attention, flash_attention, mrope_angles,
    rope_angles)


def naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    i = jnp.arange(Sq)[:, None]
    j = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= j <= i
    if window:
        m &= (i - j) < window
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


@pytest.mark.parametrize("Sq,Skv,H,KV,window", [
    (64, 64, 4, 2, 0), (128, 128, 8, 8, 0), (64, 64, 4, 1, 16),
    (256, 256, 4, 2, 64),
])
def test_flash_matches_naive(Sq, Skv, H, KV, window):
    B, dh = 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, Skv, KV, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, Skv, KV, dh)) * 0.5
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_block=32, kv_block=32)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bidirectional():
    B, S, H, KV, dh = 2, 64, 4, 4, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, dh)) * 0.5
    out = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    ref = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_last_row_of_full():
    B, S, H, KV, dh = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, dh)) * 0.5
    full = naive_attention(q, k, v, causal=True)
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    dec = decode_attention(q[:, -1:], k, v, kv_pos, pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-5)


def test_decode_attention_ignores_empty_and_future_slots():
    B, T, H, KV, dh = 1, 16, 2, 1, 8
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, dh))
    k = jax.random.normal(ks[1], (B, T, KV, dh))
    v = jax.random.normal(ks[2], (B, T, KV, dh))
    kv_pos = jnp.where(jnp.arange(T) < 8, jnp.arange(T), -1)[None]
    pos = jnp.array([7], jnp.int32)
    out1 = decode_attention(q, k, v, kv_pos.astype(jnp.int32), pos)
    # corrupt the masked slots: output must not change
    k2 = k.at[:, 8:].set(99.0)
    v2 = v.at[:, 8:].set(-99.0)
    out2 = decode_attention(q, k2, v2, kv_pos.astype(jnp.int32), pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_rotary_preserves_norm_and_relative_phase():
    B, S, H, dh = 1, 16, 1, 32
    x = jax.random.normal(jax.random.key(4), (B, S, H, dh))
    ang = rope_angles(jnp.arange(S), dh // 2, 10000.0)
    y = apply_rotary(x, ang)
    # rotation preserves the norm of each (x1_i, x2_i) pair
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               atol=1e-4, rtol=1e-4)
    # inner products depend only on relative distance
    # <q_i, k_j> == <q_{i+1}, k_{j+1}> when inputs are identical rows
    x0 = jnp.broadcast_to(x[:, :1], x.shape)
    q0 = apply_rotary(x0, ang)
    d = np.einsum("bshd,bthd->st", np.asarray(q0), np.asarray(q0))
    np.testing.assert_allclose(np.diag(d, 1)[:-1], np.diag(d, 1)[1:],
                               atol=1e-3, rtol=1e-3)


def test_mrope_reduces_to_rope_when_streams_equal():
    S, dhh = 8, 32
    pos = jnp.arange(S)[None]                      # (B=1, S)
    pos3 = jnp.stack([pos, pos, pos])
    sections = (8, 12, 12)
    a3 = mrope_angles(pos3, sections, 10000.0)
    a1 = rope_angles(pos, dhh, 10000.0)
    np.testing.assert_allclose(np.asarray(a3), np.asarray(a1),
                               atol=1e-5, rtol=1e-5)
