"""Fleet gateway tests (DESIGN.md §11): dispatch policy, circuit
breaker state machine, response LRU, heartbeat loss/rejoin, draining,
typed rejections, streaming passthrough, the async facade, and the
fleet clock's determinism.

The pure state machines (CircuitBreaker, ResponseLRU, canonical_key)
are unit-tested with stub backends — no jax needed; the integration
scenarios run real ServeEngines on the tiny reduced config.
"""
import numpy as np
import pytest

from repro.serving.gateway import (
    CLOSED, HALF_OPEN, OPEN, AsyncGateway, Backend, BackendHandle,
    BackendUnavailable, CircuitBreaker, FleetGateway,
    ResponseLRU, canonical_key, local_fleet)

# ---------------------------------------------------- pure state units ----


def test_breaker_closed_to_open_on_consecutive_failures():
    br = CircuitBreaker(failure_threshold=3, open_timeout_s=1.0)
    assert br.state == CLOSED and br.allow(0.0)
    br.record_failure(0.0)
    br.record_failure(0.0)
    br.record_success()                    # resets the consecutive count
    br.record_failure(0.1)
    br.record_failure(0.1)
    assert br.state == CLOSED
    br.record_failure(0.2)
    assert br.state == OPEN
    assert not br.allow(0.5)               # still inside the timeout


def test_breaker_half_open_canary_closes_or_reopens():
    br = CircuitBreaker(failure_threshold=1, open_timeout_s=1.0,
                        half_open_probes=1)
    br.record_failure(0.0)
    assert br.state == OPEN
    assert br.allow(1.5)                   # timeout passed -> half-open
    assert br.state == HALF_OPEN
    br.on_dispatch()
    assert not br.allow(1.6)               # probe budget spent
    br.record_success()                    # canary completed
    assert br.state == CLOSED
    # the reopen path: a half-open canary failing trips it again
    br.record_failure(2.0)
    assert br.allow(3.5) and br.state == HALF_OPEN
    br.on_dispatch()
    br.record_failure(3.6)
    assert br.state == OPEN and br.opened_at == 3.6


def test_response_lru_eviction_and_canonical_key():
    lru = ResponseLRU(capacity=2)
    ka = canonical_key([1, 2, 3], 4)
    # canonicalization: list vs array vs dtype never splits the cache
    assert ka == canonical_key(np.array([1, 2, 3], np.int64), 4)
    assert ka != canonical_key([1, 2, 3], 5)
    lru.put(ka, [7, 8])
    kb = canonical_key([9], 4)
    lru.put(kb, [1])
    assert lru.get(ka) == [7, 8]           # touch: ka is now most recent
    lru.put(canonical_key([5], 4), [2])    # evicts kb, not ka
    assert lru.get(kb) is None
    assert lru.get(ka) == [7, 8]
    assert lru.hits == 2 and lru.misses == 1
    off = ResponseLRU(capacity=0)
    off.put(ka, [7])
    assert off.get(ka) is None and len(off) == 0
    # a disabled cache reports no traffic at all, not all-misses
    assert off.hits == 0 and off.misses == 0


def test_local_fleet_rejects_mismatched_weights():
    with pytest.raises(ValueError, match="weights"):
        local_fleet(None, None, None, n=2, weights=[1.0])


# ------------------------------------------------------- stub backends ----

class StubBackend(BackendHandle):
    """Scripted backend: each request decodes `max_new` tokens, one
    per step of fixed `step_s` modeled seconds, FIFO one at a time."""

    def __init__(self, step_s=0.01, tokens=(1, 2, 3, 4, 5, 6, 7, 8)):
        self.step_s = step_s
        self.toks = list(tokens)
        self.clock_s = 0.0
        self.queue = []                    # (local_uid, remaining, done)
        self._uid = 0
        self.lost = False
        self.n_submits = 0

    def submit(self, prompt, max_new, arrival_time):
        if self.lost:
            raise BackendUnavailable("down")
        uid = self._uid
        self._uid += 1
        self.n_submits += 1
        self.clock_s = max(self.clock_s, arrival_time)
        self.queue.append([uid, int(max_new), 0])
        return uid

    def step(self):
        from repro.serving.engine import StepResult
        from repro.serving.storage_plane import TokenStats
        if self.lost or not self.queue:
            return None
        uid, max_new, n = self.queue[0]
        self.clock_s += self.step_s
        self.queue[0][2] = n + 1
        fin = []
        if n + 1 >= max_new:
            self.queue.pop(0)
            fin = [uid]
        st = TokenStats(compute_s=self.step_s, io_s=0.0,
                        effective_s=self.step_s, cache_hit_rate=1.0,
                        n_miss=0, batch=1)
        return StepResult(stats=st, tokens={uid: self.toks[n]},
                          finished=fin, t_s=self.clock_s)

    def cancel(self, local_uids):
        keep = [q for q in self.queue if q[0] not in set(local_uids)]
        self.queue = keep

    @property
    def load(self):
        return len(self.queue)

    def next_event_time(self):
        if self.lost or not self.queue:
            return None
        return self.clock_s + self.step_s


def _gw(n=2, **kw):
    kw.setdefault("heartbeat_s", 0.005)
    kw.setdefault("cache_capacity", 0)
    return FleetGateway([StubBackend() for _ in range(n)], **kw)


# ----------------------------------------------------- dispatch policy ----

def test_weighted_least_loaded_dispatch_shares_by_weight():
    """A weight-2 backend absorbs ~2x the requests of a weight-1 one:
    the router divides reported load by weight (the knob that absorbs
    heterogeneous per-device throughput)."""
    gw = FleetGateway([Backend(handle=StubBackend(), weight=2.0,
                               max_concurrency=64),
                       Backend(handle=StubBackend(), weight=1.0,
                               max_concurrency=64)],
                      heartbeat_s=0.0, cache_capacity=0)
    for i in range(12):
        gw.submit([i], max_new=2, arrival_time=0.0)
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_completed == 12
    d = [b["dispatched"] for b in rep.per_backend]
    assert d[0] == 8 and d[1] == 4       # 2:1 split, deterministic


def test_max_concurrency_cap_queues_at_gateway():
    """A backend at its cap receives nothing more until a completion
    frees a slot — the overflow waits at the gateway, uncounted as a
    dispatch attempt (it is healthy queueing, not failure)."""
    gw = FleetGateway([Backend(handle=StubBackend(), max_concurrency=2)],
                      heartbeat_s=0.0, cache_capacity=0)
    uids = [gw.submit([i], max_new=2, arrival_time=0.0) for i in range(5)]
    # step until the first dispatch round has happened
    gw.step()
    b = gw.backends[0]
    assert len(b.inflight) == 2 and len(gw.pending) == 3
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_completed == 5 and rep.n_rejected == 0
    assert all(gw.requests[u].attempts == 1 for u in uids)


def test_idle_fleet_round_robins_fifo():
    gw = _gw(3, heartbeat_s=0.0)
    order = []
    for i in range(6):
        gw.submit([i], max_new=1, arrival_time=float(i))
        gw.run_until_drained()
        order.append([b.n_dispatched for b in gw.backends])
    assert order[-1] == [2, 2, 2]


# ----------------------------------------- failures, breaker, rejoin ----

def test_dispatch_failure_trips_breaker_and_retries_elsewhere():
    gw = _gw(2, heartbeat_s=0.0)
    gw.backends[0].handle.lost = True      # not yet detected
    uid = gw.submit([1], max_new=2, arrival_time=0.0)
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_completed == 1
    assert gw.requests[uid].retries >= 1 and rep.n_retries >= 1
    assert not gw.backends[0].alive        # failure marked it dead
    assert gw.backends[1].n_completed == 1


def test_heartbeat_detects_loss_recalls_inflight_and_rejoins():
    """The full scenario: backend dies mid-decode, the next heartbeat
    recalls its in-flight work onto the healthy backend, and after
    restore + breaker timeout the rejoined backend serves again
    (half-open canary completing closes the breaker)."""
    gw = FleetGateway(
        [Backend(handle=StubBackend(), max_concurrency=4,
                 breaker=CircuitBreaker(open_timeout_s=0.02))
         for _ in range(2)],
        heartbeat_s=0.01, cache_capacity=0)
    for i in range(4):
        gw.submit([i], max_new=4, arrival_time=0.0)
    # let both backends take work, then kill backend 1
    while not gw.backends[1].inflight:
        assert gw.step()
    lost_uids = list(gw.backends[1].inflight.values())
    gw.backends[1].handle.lost = True
    gw.restore_backend(1, at=0.05)
    # keep traffic flowing past the rejoin so the canary path runs
    for i in range(6):
        gw.submit([10 + i], max_new=4, arrival_time=0.06 + 0.01 * i)
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_rejected == 0
    assert rep.n_retries >= len(lost_uids) >= 1
    assert all(gw.requests[u].done and not gw.requests[u].rejected
               for u in lost_uids)
    b1 = gw.backends[1]
    assert b1.alive and b1.breaker.state == CLOSED
    assert b1.n_completed >= 1             # it served after rejoining


def test_all_backends_down_surfaces_typed_rejection():
    """The bugfix contract: every dispatch attempt hitting dead
    backends/open breakers must end in a typed rejection — never a
    hang, never an unhandled exception."""
    gw = _gw(2, max_attempts=3, retry_backoff_s=0.001)
    gw.backends[0].handle.lost = True
    gw.backends[1].handle.lost = True
    uid = gw.submit([1], max_new=4, arrival_time=0.0)
    rep = gw.run_until_drained(max_events=10000)
    assert rep.drained
    assert rep.n_rejected == 1 and rep.n_completed == 0
    rej = rep.rejected[0]
    assert rej.uid == uid and rej.reason == "no_backend_available"
    assert rej.attempts == 3
    assert gw.requests[uid].rejected
    # the typed rejection propagates through the streaming surface too
    with pytest.raises(BackendUnavailable, match="no_backend_available"):
        list(gw.stream(uid))


def test_empty_fleet_rejects_and_report_has_no_div_by_zero():
    gw = FleetGateway([], heartbeat_s=0.01)
    gw.submit([1, 2], max_new=4)
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_rejected == 1
    assert rep.rejected[0].reason == "empty_fleet"
    assert rep.throughput_tok_s == 0.0
    assert rep.ttft_percentiles("hit")["p99"] == 0.0
    assert rep.ttft_percentiles("miss")["mean"] == 0.0
    # a fleet whose whole stream was rejected reports zeros the same way
    assert FleetReport_zero_ok()


def FleetReport_zero_ok():
    from repro.serving.gateway import FleetReport
    rep = FleetReport()
    return (rep.throughput_tok_s == 0.0 and rep.drained
            and rep.ttft_percentiles()["p50"] == 0.0)


def test_fleet_stalled_guard_rejects_instead_of_spinning():
    """No heartbeat, both backends dead with work in flight: the
    deadlock guard recalls and rejects rather than spinning the
    event loop forever."""
    gw = FleetGateway([StubBackend(), StubBackend()], heartbeat_s=0.0,
                      cache_capacity=0, max_attempts=2,
                      retry_backoff_s=0.001)
    uids = [gw.submit([i], max_new=4, arrival_time=0.0) for i in range(2)]
    gw.step()                              # both dispatched
    gw.backends[0].handle.lost = True
    gw.backends[1].handle.lost = True
    rep = gw.run_until_drained(max_events=10000)
    assert rep.drained and rep.n_rejected == 2
    assert all(gw.requests[u].done for u in uids)


# ------------------------------------------------- draining lifecycle ----

def test_draining_backend_finishes_inflight_receives_no_new():
    gw = _gw(2, heartbeat_s=0.0)
    for i in range(4):
        gw.submit([i], max_new=3, arrival_time=0.0)
    while not gw.backends[1].inflight:
        gw.step()
    inflight = list(gw.backends[1].inflight.values())
    disp_before = gw.backends[1].n_dispatched
    gw.drain_backend(1)
    for i in range(4):
        gw.submit([10 + i], max_new=3, arrival_time=gw.clock_s)
    rep = gw.run_until_drained()
    assert rep.drained and rep.n_rejected == 0
    assert gw.backends[1].n_dispatched == disp_before
    assert all(gw.requests[u].done and not gw.requests[u].rejected
               for u in inflight)
    # undrain readmits it
    gw.undrain_backend(1)
    gw.submit([99], max_new=1, arrival_time=gw.clock_s)
    gw.run_until_drained()
    assert gw.backends[1].n_dispatched == disp_before + 1


# --------------------------------------------- cache + streaming + TTFT ----

def test_response_lru_hit_skips_decode_and_splits_ttft():
    gw = FleetGateway([StubBackend()], heartbeat_s=0.0,
                      cache_capacity=8)
    u1 = gw.submit([5, 6], max_new=3, arrival_time=0.0)
    gw.run_until_drained()
    toks = list(gw.requests[u1].tokens)
    submits_before = gw.backends[0].handle.n_submits
    u2 = gw.submit([5, 6], max_new=3, arrival_time=1.0)
    rep = gw.run_until_drained()
    assert gw.requests[u2].cache_hit
    assert gw.requests[u2].tokens == toks
    assert gw.backends[0].handle.n_submits == submits_before
    assert rep.cache_hits == 1
    # TTFT split: the hit is instantaneous on the fleet clock
    assert rep.ttft_hit.size == 1 and float(rep.ttft_hit[0]) == 0.0
    assert rep.ttft_miss.size == 1 and float(rep.ttft_miss[0]) > 0.0


def test_streaming_passthrough_yields_tokens_in_decode_order():
    gw = FleetGateway([StubBackend(step_s=0.01)], heartbeat_s=0.0,
                      cache_capacity=8)
    seen = []
    gw.on_token(lambda uid, tok, t: seen.append((uid, tok)))
    uid = gw.submit([1], max_new=4, arrival_time=0.0)
    out = list(gw.stream(uid))
    assert [tok for _, tok in out] == [1, 2, 3, 4]
    ts = [t for t, _ in out]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert seen == [(uid, t) for t in (1, 2, 3, 4)]
    # a cached replay streams the same tokens with zero new events
    uid2 = gw.submit([1], max_new=4, arrival_time=gw.clock_s)
    assert [tok for _, tok in gw.stream(uid2)] == [1, 2, 3, 4]


def test_fleet_clock_is_deterministic():
    def once():
        gw = _gw(3, heartbeat_s=0.004)
        rng = np.random.default_rng(7)
        arr = np.cumsum(rng.exponential(0.003, 10))
        for i, t in enumerate(arr):
            gw.submit([i % 4], max_new=3, arrival_time=float(t))
        gw.fail_backend(2, at=float(arr[3]))
        gw.restore_backend(2, at=float(arr[3]) + 0.05)
        rep = gw.run_until_drained()
        return (rep.span_s, rep.n_retries, rep.total_tokens,
                tuple(b["dispatched"] for b in rep.per_backend))
    assert once() == once()


# ----------------------------------------------------- async facade ----

def test_async_gateway_concurrent_generate_and_stream():
    import asyncio
    gw = FleetGateway([StubBackend(), StubBackend()], heartbeat_s=0.0,
                      cache_capacity=8)
    agw = AsyncGateway(gw)

    async def main():
        stream_toks = []

        async def consume():
            async for tok in agw.stream([9], max_new=3):
                stream_toks.append(tok)

        outs = await asyncio.gather(
            agw.generate([1], max_new=4),
            agw.generate([2], max_new=2),
            consume())
        return outs[0], outs[1], stream_toks

    a, b, c = asyncio.run(main())
    assert a == [1, 2, 3, 4] and b == [1, 2] and c == [1, 2, 3]
    assert gw.report().drained

    async def rejected():
        gw.backends[0].handle.lost = True
        gw.backends[1].handle.lost = True
        await agw.generate([3], max_new=2)

    with pytest.raises(BackendUnavailable):
        asyncio.run(rejected())


def test_async_gateway_crashed_driver_propagates():
    """A driver coroutine that dies mid-drive must raise in the
    waiting client, not leave it spinning on an unfinished request."""
    import asyncio
    gw = FleetGateway([StubBackend()], heartbeat_s=0.0)
    agw = AsyncGateway(gw)

    def boom():
        raise RuntimeError("driver crashed")
    gw.step = boom

    async def main():
        await agw.generate([1], max_new=4)

    with pytest.raises(RuntimeError, match="driver crashed"):
        asyncio.run(main())


# --------------------------------------------- real-engine integration ----

@pytest.fixture(scope="module")
def tiny_setup():
    import jax
    from repro.configs import get_config
    from repro.serving.families import serving_family
    cfg = get_config("smollm-135m").reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = fam.build_plan(cfg)
    return cfg, fam.prepare_params(params, plan), plan


def _engine_fleet(tiny_setup, n, **kw):
    from repro.core.baselines import POWERINFER2
    cfg, params, plan = tiny_setup
    return local_fleet(cfg, params, plan, n, spec=POWERINFER2,
                       offload_ratio=0.5, seed=0, buckets=(1, 2, 4),
                       ctx_budget=32, temperature=0.8, **kw)


def test_engine_fleet_scales_and_survives_loss(tiny_setup):
    """Real engines behind the gateway: a saturating stream drains
    completely with span throughput scaling fleet 1 -> 2, including a
    mid-stream backend loss/rejoin on the larger fleet."""
    cfg, _, _ = tiny_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12) for _ in range(16)]

    def run(n, lose=False):
        gw = FleetGateway(_engine_fleet(tiny_setup, n),
                          heartbeat_s=0.0005)
        arr = np.cumsum(rng.exponential(1e-5, 16))
        for i, t in enumerate(arr):
            gw.submit(prompts[i], max_new=5, arrival_time=float(t))
        if lose:
            gw.fail_backend(1, at=0.001)
            gw.restore_backend(1, at=0.003)
        rep = gw.run_until_drained()
        gw.close()
        return rep

    r1, r2 = run(1), run(2, lose=True)
    assert r1.drained and r1.n_rejected == 0
    assert r2.drained and r2.n_rejected == 0
    assert r2.throughput_tok_s > r1.throughput_tok_s
    assert r2.n_completed == 16


def test_engine_fleet_lru_hit_is_token_identical(tiny_setup):
    """Sequential identical requests through real engines: the second
    is a cache hit replaying the first's exact tokens, with no second
    decode (backend step count unchanged)."""
    cfg, _, _ = tiny_setup
    gw = FleetGateway(_engine_fleet(tiny_setup, 2), heartbeat_s=0.001,
                      cache_capacity=8)
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, 12)
    u1 = gw.submit(prompt, max_new=4, arrival_time=0.0)
    gw.run_until_drained()
    steps = sum(b.n_steps for b in gw.backends)
    u2 = gw.submit(prompt, max_new=4, arrival_time=gw.clock_s)
    rep = gw.run_until_drained()
    assert gw.requests[u2].cache_hit
    assert gw.requests[u2].tokens == gw.requests[u1].tokens
    assert sum(b.n_steps for b in gw.backends) == steps
    assert rep.cache_hits == 1 and rep.drained
    gw.close()
