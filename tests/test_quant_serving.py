"""Storage-dtype thread (DESIGN.md §13, paper §7.6 + §4.4): plan ->
params -> kernels -> storage-plane pricing.

The plan declares how cold bundles live on the slow tier
(`HybridPlan.storage_dtype`); `prepare_params` quantizes the cold FFN
rows; both cold-path backends dequantize at the gather boundary; and
the storage plane prices I/O + residency at the declared bundle bytes.
These tests pin each link plus the end-to-end quality gate (declared
token-divergence bounds on the conformance battery archs).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.quant.quantize import bundle_nbytes
from repro.quant.storage import (
    OUTLIER_FRAC, TOKEN_AGREEMENT_BOUND, dequantize_bundles,
    plan_storage_dtype, quant_boundary, quantize_bundles)
from repro.serving.families import serving_family

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BATTERY_ARCHS = ("smollm-135m", "qwen2-vl-2b", "deepseek-moe-16b",
                 "turbosparse-mixtral-47b")


def _setup(arch, sd, seed=0):
    cfg = get_config(arch).reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(seed))
    plan = fam.build_plan(cfg, storage_dtype=sd)
    return cfg, fam, model, params, plan


# ------------------------------------------------- plan threading ----

def test_plan_carries_storage_dtype_on_every_bucket():
    _, _, _, _, plan = _setup("smollm-135m", "int4-mixed")
    assert plan_storage_dtype(plan) == "int4-mixed"
    assert all(p.storage_dtype == "int4-mixed"
               for p in plan.plans.values())
    # bucket scaling keeps the declaration
    assert plan.plan_for_batch(17).storage_dtype == "int4-mixed"


def test_plan_save_load_roundtrips_storage_dtype(tmp_path):
    from repro.core.planner import ExecutionPlan
    _, _, _, _, plan = _setup("smollm-135m", "int8")
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = ExecutionPlan.load(path)
    assert plan_storage_dtype(loaded) == "int8"


def test_mixed_bucket_dtypes_rejected():
    _, _, _, _, plan = _setup("smollm-135m", "int8")
    b = sorted(plan.plans)[0]
    plan.plans[b] = dataclasses.replace(plan.plans[b],
                                        storage_dtype="fp16")
    with pytest.raises(ValueError, match="disagree"):
        plan_storage_dtype(plan)


def test_hot_io_cap_scales_with_declared_dtype():
    """§4.4 at deployment scale: int4-mixed bundles are 3x smaller
    than fp16 for d=4096, so the I/O-balance boundary admits 3x more
    hot neurons per I/O budget."""
    from repro.configs.paper_models import BAMBOO_7B
    from repro.core.planner import PHONE, hot_io_cap
    cap_fp = hot_io_cap(BAMBOO_7B, PHONE, "fp16")
    cap_i4 = hot_io_cap(BAMBOO_7B, PHONE, "int4-mixed")
    # exactly 3x up to the caps' own floor rounding
    assert 3 * cap_fp <= cap_i4 <= 3 * (cap_fp + 1)


# --------------------------------------------------- prepare_params ----

@pytest.mark.parametrize("sd", ["int8", "int4-mixed"])
def test_prepare_quantizes_cold_rows_only(sd):
    cfg, fam, model, params, plan = _setup("smollm-135m", sd)
    plan_fp = fam.build_plan(cfg)
    p_fp = fam.prepare_params(params, plan_fp)
    p_q = fam.prepare_params(params, plan)
    w_fp = np.asarray(p_fp["layers"]["ffn"]["w"])
    ffn_q = p_q["layers"]["ffn"]
    n_q = quant_boundary(plan)
    # hot/pinned prefix stays fp, byte-identical
    np.testing.assert_array_equal(w_fp[:, :n_q],
                                  np.asarray(ffn_q["w"][:, :n_q]))
    # cold rows hold the container roundtrip exactly
    qd = {k: ffn_q[k] for k in ("wq", "wsc", "wout") if k in ffn_q}
    assert ("wout" in qd) == (sd == "int4-mixed")
    deq = np.asarray(dequantize_bundles(qd).astype(ffn_q["w"].dtype))
    np.testing.assert_array_equal(deq[:, n_q:],
                                  np.asarray(ffn_q["w"][:, n_q:]))
    # and differ from fp (quantization actually happened)
    assert not np.array_equal(w_fp[:, n_q:], np.asarray(ffn_q["w"][:, n_q:]))


def test_moe_prepare_quantizes_routed_keeps_shared():
    cfg, fam, model, params, plan = _setup("deepseek-moe-16b",
                                           "int4-mixed")
    p_fp = fam.prepare_params(params, fam.build_plan(cfg))
    p_q = fam.prepare_params(params, plan)
    moe_fp, moe_q = p_fp["layers"]["moe"], p_q["layers"]["moe"]
    # shared experts and router are untouched
    for k in moe_fp:
        if k != "experts":
            np.testing.assert_array_equal(
                np.asarray(jax.tree.leaves(moe_fp[k])[0]),
                np.asarray(jax.tree.leaves(moe_q[k])[0]))
    # routed experts changed in place (simulated quantization)
    assert not np.array_equal(np.asarray(moe_fp["experts"]),
                              np.asarray(moe_q["experts"]))
    # per-expert roundtrip matches an independent quantize of the
    # same cold slice
    n_q_e = min(getattr(p, "n_expert_hot", 0)
                for p in plan.plans.values())
    ex = np.asarray(moe_fp["experts"])
    L, E, f = ex.shape[:3]
    cold = ex[:, :, n_q_e:].reshape(L * E, f - n_q_e, *ex.shape[3:])
    ref = dequantize_bundles(quantize_bundles(
        cold, "int4-mixed", outlier_frac=OUTLIER_FRAC, batch_dims=1))
    np.testing.assert_array_equal(
        np.asarray(ref.astype(moe_q["experts"].dtype)).reshape(
            L, E, f - n_q_e, *ex.shape[3:]),
        np.asarray(moe_q["experts"])[:, :, n_q_e:])


# ------------------------------------------------- quality gates ----

def _teacher_forced_agreement(arch, sd):
    cfg, fam, model, params, plan_q = _setup(arch, sd, seed=0)
    plan_fp = fam.build_plan(cfg)
    p_fp = fam.prepare_params(params, plan_fp)
    p_q = fam.prepare_params(params, plan_q)
    toks = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 48)).astype(np.int32)
    batch = {"tokens": toks}
    a_fp = np.asarray(jax.numpy.argmax(
        model.forward(p_fp, batch, plan_fp.plan_for_batch(1)), -1))
    a_q = np.asarray(jax.numpy.argmax(
        model.forward(p_q, batch, plan_q.plan_for_batch(1)), -1))
    return float((a_fp == a_q).mean())


@pytest.mark.parametrize("arch", BATTERY_ARCHS)
def test_int4_divergence_within_declared_bound(arch):
    """The acceptance gate: int4-mixed teacher-forced argmax agreement
    on every battery arch stays above the declared floor (random-init
    reduced models — the worst case for per-channel int4)."""
    agree = _teacher_forced_agreement(arch, "int4-mixed")
    assert agree >= TOKEN_AGREEMENT_BOUND["int4-mixed"], \
        f"{arch}: int4-mixed agreement {agree:.3f} below declared bound"


def test_int8_divergence_within_declared_bound():
    agree = _teacher_forced_agreement("smollm-135m", "int8")
    assert agree >= TOKEN_AGREEMENT_BOUND["int8"]


def test_quantized_decode_jnp_pallas_token_identical():
    """Both cold-path backends dequantize the same stored codes at the
    gather boundary, so quantized decode is token-identical across
    backends (DESIGN.md §10's contract, extended to §7.6)."""
    from repro.launch.serve import build_engine
    toks = {}
    for backend in ("jnp", "pallas"):
        eng, cfg = build_engine("smollm-135m", offload=0.875,
                                profile=False, backend=backend,
                                storage_dtype="int4-mixed")
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 16)).astype(np.int32)
        toks[backend] = np.asarray(
            eng.generate(prompt, max_new=8, temperature=0.0).tokens)
        eng.close()
    np.testing.assert_array_equal(toks["jnp"], toks["pallas"])


def test_quantized_params_shard_on_mesh():
    """The engine's param placement grafts specs for the quant
    containers (wq/wsc/wout shard over 'model' like w) — a tp=2 engine
    must decode the same tokens as tp=1 on quantized params."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=2"
        import numpy as np
        from repro.launch.serve import build_engine
        toks = {}
        for tp in (1, 2):
            eng, cfg = build_engine("smollm-135m", offload=0.875,
                                    profile=False, tp=tp,
                                    storage_dtype="int4-mixed")
            prompt = np.random.default_rng(0).integers(
                0, cfg.vocab_size, (2, 16)).astype(np.int32)
            toks[tp] = np.asarray(
                eng.generate(prompt, max_new=6, temperature=0.0).tokens)
            eng.close()
        assert np.array_equal(toks[1], toks[2]), (toks[1], toks[2])
        print("TP_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "TP_OK" in r.stdout


# ------------------------------------------- storage-plane pricing ----

def _deploy_plane(sd, offload=0.875):
    from repro.core.baselines import POWERINFER2
    from repro.serving.storage_plane import StoragePlane, TimingProfile
    from repro.configs.paper_models import BAMBOO_7B
    cfg, fam, model, params, plan = _setup("smollm-135m", sd)
    params = fam.prepare_params(params, plan)
    timing = TimingProfile.from_config(BAMBOO_7B, 3)
    return StoragePlane(cfg, params, plan, spec=POWERINFER2,
                        offload_ratio=offload, timing=timing,
                        prefetch=False), timing


def test_plane_prices_declared_bundle_bytes():
    plane_fp, timing = _deploy_plane("fp16")
    plane_i4, _ = _deploy_plane("int4-mixed")
    plane_i8, _ = _deploy_plane("int8")
    # fp16 keeps the legacy unpadded accounting byte-identical
    assert plane_fp.bundle_bytes == timing.bundle_bytes == 24576
    assert plane_i4.bundle_bytes == bundle_nbytes(4096, "int4-mixed") == 8192
    assert plane_i8.bundle_bytes == bundle_nbytes(4096, "int8")
    assert plane_fp.bundle_bytes == 3 * plane_i4.bundle_bytes
    for plane in (plane_fp, plane_i4, plane_i8):
        assert plane.coldstore.bundle_bytes() == plane.bundle_bytes
        plane.close()


def test_plane_residency_scales_with_dtype():
    """The same host-byte budget holds fp/q x more cold neurons when
    bundles shrink — capped at the neurons that exist; the pinned hot
    prefix (fp on the NPU) does not scale."""
    plane_fp, _ = _deploy_plane("fp16")
    plane_i4, _ = _deploy_plane("int4-mixed")
    assert plane_i4.n_hot == plane_fp.n_hot
    cap_fp = sum(c.capacity for c in plane_fp.caches)
    cap_i4 = sum(c.capacity for c in plane_i4.caches)
    L, N = plane_fp.cfg.num_layers, plane_fp.N
    expect = min(3 * (cap_fp // L), N - plane_fp.n_hot) * L
    assert cap_i4 == expect > cap_fp
    plane_fp.close()
    plane_i4.close()


def test_plane_prefill_priced_at_declared_bytes():
    """Prefill streams every offloaded bundle once — 3x fewer bytes at
    int4-mixed, so the I/O-bound prefill cost drops."""
    plane_fp, _ = _deploy_plane("fp16")
    plane_i4, _ = _deploy_plane("int4-mixed")
    c_fp = plane_fp.prefill_cost(1)
    c_i4 = plane_i4.prefill_cost(1)
    assert c_i4 < c_fp
    plane_fp.close()
    plane_i4.close()


def test_quantized_coldstore_bytes_per_token_3x_lower():
    """The PR's acceptance criterion, in-test: same trace, deployment
    pricing — int4-mixed models >=3x fewer cold-store bytes/token than
    fp16 (24KB vs 8KB bundles; residency gains only widen the gap)."""
    from repro.launch.serve import build_engine
    from repro.serving.storage_plane import TimingProfile
    from repro.configs.paper_models import BAMBOO_7B
    timing = TimingProfile.from_config(BAMBOO_7B, 3)
    bytes_tok = {}
    for sd in ("fp16", "int4-mixed"):
        eng, cfg = build_engine("smollm-135m", offload=0.875,
                                profile=False, storage_dtype=sd,
                                timing=timing)
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 16)).astype(np.int32)
        res = eng.generate(prompt, max_new=8, temperature=0.0)
        n_tok = res.tokens.shape[0] * res.tokens.shape[1]
        bytes_tok[sd] = eng.storage.coldstore.total_bytes / n_tok
        eng.close()
    assert bytes_tok["fp16"] >= 3.0 * bytes_tok["int4-mixed"], bytes_tok


# ------------------------------------------- analysis discipline ----

def test_quant_cold_paths_keep_collective_discipline():
    """The storage-dtype branches in the shard_map cold path must keep
    the fp32-psum / one-psum-per-path discipline the repro-analyze
    collective rules enforce — run the full rule battery over the
    touched modules and require zero findings (no allowlist)."""
    from repro.analysis import analyze_files
    files = {}
    for rel in ("src/repro/core/sparse_ffn.py",
                "src/repro/kernels/cluster_gather_ffn.py",
                "src/repro/kernels/ops.py",
                "src/repro/quant/storage.py"):
        with open(os.path.join(REPO, rel)) as f:
            files[rel] = f.read()
    findings = analyze_files(files)
    assert not findings, [f"{f.path}:{f.line} {f.rule}" for f in findings]
