"""Unit tests for the semantic analysis tier (DESIGN.md §14): jaxpr
invariant rules, the trace registry, the pallas DMA race sanitizer and
its seeded mutant kernels, the trace-registry-drift AST rule, and the
CLI `--tier semantic` surface.

Everything in-process here runs on one host device; the shard_map
grid (tp/ep=2 entries, the double-psum fixture) is exercised through
the CLI subprocess, which forces 8 host devices before importing jax.
"""
import inspect
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import AnalysisConfig, analyze_files
from repro.analysis import dma_sanitizer, jaxpr_rules, semantic_selftest
from repro.analysis.trace_registry import (KERNEL_ENTRY_POINTS,
                                           TraceEntry, entries,
                                           entry_names)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CLI = os.path.join(REPO, "scripts", "repro_analyze.py")


def run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # the CLI setdefaults this itself; force it here so an outer
    # XLA_FLAGS can't shrink the subprocess below the shard_map grid
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run([sys.executable, CLI, *args], cwd=REPO,
                          env=env, capture_output=True, text=True)


def rules_of(findings):
    return {f.rule for f in findings}


# ------------------------------------------------- jaxpr rule units ----

def _entry(fn, args, **kw):
    return TraceEntry("unit/fn", lambda: (fn, args), **kw)


def test_collective_count_mismatch_fires_without_mesh():
    # declared one psum, trace has none: exact-count rule must fire
    e = _entry(lambda x: x * 2.0, (jnp.zeros((4,), jnp.float32),),
               psums=1)
    assert "jaxpr-collective-count" in rules_of(jaxpr_rules.run_entries([e]))


def test_callback_fires_only_when_clock_driven():
    import jax

    def fn(x):
        jax.debug.print("x {v}", v=x[0])
        return x
    args = (jnp.zeros((4,), jnp.float32),)
    assert "jaxpr-callback" in rules_of(
        jaxpr_rules.run_entries([_entry(fn, args)]))
    assert not jaxpr_rules.run_entries(
        [_entry(fn, args, clock_driven=False)])


def test_const_capture_fires_over_cap():
    baked = jnp.zeros((1024,), jnp.float32)        # 4 KiB closure
    e = _entry(lambda x: x + baked, (jnp.zeros((1024,), jnp.float32),),
               const_cap_bytes=1024)
    assert "jaxpr-const-capture" in rules_of(jaxpr_rules.run_entries([e]))


def test_f64_fires_under_x64_ctx():
    from jax.experimental import enable_x64
    e = _entry(lambda x: x.astype(jnp.float64),
               (jnp.zeros((4,), jnp.float32),), trace_ctx=enable_x64)
    assert "jaxpr-f64" in rules_of(jaxpr_rules.run_entries([e]))


def test_broken_build_surfaces_as_trace_error():
    def build():
        raise RuntimeError("boom")
    fs = jaxpr_rules.run_entries([TraceEntry("unit/broken", build)])
    assert rules_of(fs) == {"jaxpr-trace-error"}
    assert "boom" in fs[0].message


def test_clean_entry_has_no_findings():
    e = _entry(lambda x: jnp.tanh(x), (jnp.zeros((4,), jnp.float32),))
    assert jaxpr_rules.run_entries([e]) == []


# -------------------------------------------------- trace registry ----

def test_registry_names_are_unique_and_scoped():
    names = entry_names(max_devices=8)
    assert len(names) == len(set(names))
    assert all(n.split("/")[0] in ("kernel", "cold", "decode")
               for n in names)


def test_registry_covers_every_ops_export():
    # the live counterpart of the trace-registry-drift AST rule
    from repro.kernels import ops
    assert set(KERNEL_ENTRY_POINTS) == set(ops.__all__)
    names = " ".join(entry_names(max_devices=8))
    for kernel in ops.__all__:
        assert f"kernel/{kernel}" in names


def test_single_device_entries_trace_clean():
    one_dev = entries(max_devices=1)
    assert one_dev, "registry has no single-device entries"
    assert all(e.n_devices == 1 for e in one_dev)
    assert jaxpr_rules.run_entries(one_dev) == []


# ---------------------------------------------------- DMA sanitizer ----

def test_clean_mini_kernel_is_silent_and_faithful():
    fs, y, x, w = dma_sanitizer.run_mini_shadow(
        semantic_selftest.CLEAN_MINI, case="clean")
    assert fs == []
    want = sum(x @ w[k * 8:(k + 1) * 8] for k in range(4))
    assert dma_sanitizer.fidelity_findings("clean", y, want) == []


@pytest.mark.parametrize("name", sorted(semantic_selftest.MUTANTS))
def test_mutant_trips_its_race_classes(name):
    kernel, expected = semantic_selftest.MUTANTS[name]
    fs, _, _, _ = dma_sanitizer.run_mini_shadow(kernel, case=name)
    assert expected <= rules_of(fs), (name, fs)


def test_fidelity_comparator_reports_drift():
    fs = dma_sanitizer.fidelity_findings(
        "drift", np.ones((2, 2)), np.zeros((2, 2)))
    assert rules_of(fs) == {"dma-shadow-fidelity"}
    assert dma_sanitizer.fidelity_findings(
        "same", np.ones((2, 2)), np.ones((2, 2))) == []


def test_real_fused_kernel_sweep_is_race_free():
    assert dma_sanitizer.sweep_fused_cold_ffn() == []


# -------------------------------------- trace-registry-drift (AST) ----

_OPS_BAD = '__all__ = ["a_kernel", "b_kernel"]\n'
_REG_A_ONLY = 'KERNEL_ENTRY_POINTS = ("a_kernel",)\n'


def _drift_config():
    return AnalysisConfig(kernels_ops_path="x/ops.py",
                          trace_registry_path="x/reg.py")


def test_unregistered_kernel_export_fires():
    fs = analyze_files({"x/ops.py": _OPS_BAD, "x/reg.py": _REG_A_ONLY},
                       _drift_config())
    drift = [f for f in fs if f.rule == "trace-registry-drift"]
    assert len(drift) == 1
    assert "b_kernel" in drift[0].message
    assert drift[0].path == "x/ops.py"


def test_fully_registered_exports_are_clean():
    reg = 'KERNEL_ENTRY_POINTS = ("a_kernel", "b_kernel")\n'
    fs = analyze_files({"x/ops.py": _OPS_BAD, "x/reg.py": reg},
                       _drift_config())
    assert not [f for f in fs if f.rule == "trace-registry-drift"]


# -------------------------------------------- interpret unification ----

def test_kernel_wrappers_share_the_tpu_detection_default():
    from repro.kernels import default_interpret, ops
    from repro.kernels.cluster_gather_ffn import (cluster_gather_ffn,
                                                  fused_cold_ffn)
    from repro.kernels.dense_ffn import dense_ffn
    for fn in (dense_ffn, cluster_gather_ffn, fused_cold_ffn,
               ops.fused_cold_ffn, ops.cluster_gather_ffn_grouped):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn
    import jax
    assert default_interpret() == (jax.default_backend() != "tpu")


# --------------------------------------------------------- CLI gate ----

def test_cli_semantic_self_test_proves_every_rule():
    r = run_cli("--tier", "semantic", "--self-test")
    assert r.returncode == 0, r.stdout + r.stderr
    from repro.analysis.semantic import semantic_rules
    for rule in semantic_rules():
        assert f"ok   {rule}" in r.stdout, rule


def test_cli_semantic_gate_is_clean(tmp_path):
    report = tmp_path / "report.json"
    r = run_cli("--tier", "semantic", "--json", str(report))
    assert r.returncode == 0, r.stdout + r.stderr
    import json
    data = json.loads(report.read_text())
    assert data["tier"] == "semantic"
    assert data["findings"] == data["kept"] == []
