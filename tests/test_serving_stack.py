"""Layered serving stack: continuous batching (mid-stream admission,
bucket-boundary retrace discipline), slot-based KV recycling, the
generate() compatibility wrapper vs the seed decode loop,
StoragePlane.step determinism with/without the prefetch thread, and
data-parallel replica routing (meshless dp — the scheduler-level
mechanism; the meshed goldens live in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptation import BucketedDecoder
from repro.core.baselines import POWERINFER2
from repro.core.planner import build_plan, permute_ffn_params
from repro.models import dense
from repro.serving.engine import GenerationResult, ServeEngine, ServeReport
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import BatchScheduler, ReplicaRouter
from repro.serving.storage_plane import StoragePlane


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    model = dense.make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = build_plan(cfg)
    params = permute_ffn_params(params, plan.neuron_order)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    return cfg, params, plan, prompt


# ------------------------------------------------- continuous batching ----

def test_midstream_admission_grows_then_decays(setup):
    """A request admitted at step k>0 joins the running batch, crosses
    a bucket boundary with at most one decoder retrace, and completes;
    batch_history shows growth then decay."""
    cfg, params, plan, _ = setup
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2, 4, 8),
                      ctx_budget=40, temperature=0.8)
    rng = np.random.default_rng(1)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=8)
    r = eng.step()
    assert r.stats.batch == 2
    eng.step()

    # mid-stream admission: 2 -> 3 crosses the 2->4 bucket boundary
    switches0 = eng.decoder.switches
    traces0 = len(eng.decoder._cache)
    resizes0 = eng.arena.resizes
    uid = eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=4)
    r = eng.step()
    assert uid in r.admitted
    assert r.stats.batch == 3
    assert eng.arena.n_slots == 4                      # next bucket
    assert eng.decoder.switches - switches0 == 1       # one swap
    assert len(eng.decoder._cache) - traces0 == 1      # one new trace
    assert eng.arena.resizes - resizes0 == 1           # one reshape

    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    assert eng.sched.sequences[uid].finished
    hist = eng.sched.batch_history
    assert max(hist) == 3 and hist[0] == 2 and hist[-1] == 0
    grow = hist.index(3)
    assert any(b < 3 for b in hist[grow:])             # decay after growth
    # the joiner generated its full budget
    assert len(eng.sched.sequences[uid].generated) == 4
    assert rep.total_tokens == sum(s.batch for s in rep.stats)


def test_kv_slots_recycled_after_completion(setup):
    """A completed request's slot returns to the free list and is
    reused by the next admission without any arena reshape."""
    cfg, params, plan, _ = setup
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2, 4),
                      ctx_budget=40, temperature=0.8)
    rng = np.random.default_rng(2)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=n)
            for n in (2, 6, 6, 6)]
    eng.step()
    r = eng.step()                                     # uid 0 completes here
    assert uids[0] in r.finished
    freed_slot = 0
    assert freed_slot in eng.arena.free
    resizes0 = eng.arena.resizes

    new_uid = eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=2)
    r = eng.step()
    assert new_uid in r.admitted
    assert eng.arena.slot_of[new_uid] == freed_slot    # recycled
    assert eng.arena.resizes == resizes0               # no reshape
    assert eng.arena.n_slots == 4
    eng.run_until_drained()
    assert not eng.sched.has_work
    assert eng.arena.n_free == eng.arena.n_slots


def test_finish_records_batch_decay_on_timeline():
    """Force-finishing a running request between step() calls is a
    batch-decay event the adaptation timeline must see; dequeuing a
    still-queued request is not (no live batch changed)."""
    sched = BatchScheduler()
    r1 = sched.add(4, 8)
    r2 = sched.add(4, 8)
    sched.step({r1.uid: 1, r2.uid: 2})
    assert sched.batch_history == [2]
    sched.finish(r1.uid, now=1.0)                      # running -> decay
    assert sched.batch_history == [2, 1]
    assert r1.finished and r1.finish_time == 1.0
    r3 = sched.submit(np.arange(4), 8, arrival_time=9.0)
    sched.finish(r3.uid)                               # queued -> no entry
    assert sched.batch_history == [2, 1]
    assert r3.finished and r3.uid not in sched.queue


def test_scheduler_admission_queue_fifo():
    sched = BatchScheduler()
    r1 = sched.submit(np.arange(4), 8, arrival_time=0.0)
    r2 = sched.submit(np.arange(4), 8, arrival_time=5.0)
    r3 = sched.submit(np.arange(4), 8, arrival_time=1.0)
    # r2 blocks the head at t=2 even though r3 has arrived (FIFO)
    assert [r.uid for r in sched.pop_admissible(2.0, 10)] == [r1.uid]
    assert sched.next_arrival() == 5.0
    got = sched.pop_admissible(6.0, 10)
    assert [r.uid for r in got] == [r2.uid, r3.uid]
    assert sched.pop_admissible(100.0, 10) == []


# -------------------------------------------------- compat wrapper ----

def _reference_generate(cfg, params, plan, prompt, max_new, temperature,
                        seed=0):
    """The seed engine's decode loop (static batch, compaction-by-take),
    data plane only — the behavioral contract generate() must keep."""
    model = dense.make_model(cfg)
    step_traced = dense.make_decode_step(cfg, collect_indices=True)
    decoder = BucketedDecoder(
        plan_source=plan,
        make_step=lambda p: (lambda pr, t, c: step_traced(pr, t, c, p)),
        buckets=tuple(range(1, 65)))
    key = jax.random.key(seed)
    prompt = jnp.asarray(prompt)
    B, S = prompt.shape
    logits, cache = jax.jit(lambda p, b: model.prefill(
        p, b, max_len=S + max_new))(params, {"tokens": prompt})
    out = np.full((B, max_new), -1, np.int32)
    active = list(range(B))
    n_gen = {i: 0 for i in active}
    last = logits[:, -1]
    for _step in range(max_new):
        if not active:
            break
        _, step_fn = decoder.executable_for(len(active))
        key, sk = jax.random.split(key)
        toks = sample_tokens(sk, last, temperature)
        logits, cache, _ = step_fn(params, toks[:, None], cache)
        last = logits[:, 0]
        finish = []
        for row, uid in enumerate(active):
            out[uid, n_gen[uid]] = int(toks[row])
            n_gen[uid] += 1
            if n_gen[uid] >= max_new:
                finish.append(uid)
        if finish:
            keep = [r for r, u in enumerate(active) if u not in finish]
            active = [u for u in active if u not in finish]
            if keep and len(keep) < len(n_gen):
                rows = jnp.asarray(keep)
                cache = {"k": cache["k"].take(rows, axis=1),
                         "v": cache["v"].take(rows, axis=1),
                         "kv_pos": cache["kv_pos"].take(rows, axis=0),
                         "length": cache["length"].take(rows, axis=0)}
                last = last.take(rows, axis=0)
    return out


def test_generate_matches_seed_loop(setup):
    """generate() (continuous loop + slot arena + active-mask union)
    reproduces the seed static-batch path token-for-token."""
    cfg, params, plan, prompt = setup
    ref = _reference_generate(cfg, params, plan, prompt, max_new=6,
                              temperature=0.8, seed=0)
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, seed=0)
    res = eng.generate(prompt, max_new=6, temperature=0.8)
    assert np.array_equal(res.tokens, ref)


def test_generate_deterministic_and_stats_shape(setup):
    cfg, params, plan, prompt = setup
    r1 = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                     offload_ratio=0.5).generate(prompt, max_new=4,
                                                 temperature=0.0)
    r2 = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                     offload_ratio=0.5).generate(prompt, max_new=4,
                                                 temperature=0.0)
    assert np.array_equal(r1.tokens, r2.tokens)
    assert [s.batch for s in r1.stats] == [4, 4, 4, 4]


# ------------------------------------------------------ storage plane ----

def test_storage_plane_stats_prefetch_invariant(setup):
    """The prefetch thread moves real bytes but must not change any
    modeled number: step() stats with the I/O thread on equal the
    sequential (pre-refactor _storage_step) pricing exactly."""
    cfg, params, plan, prompt = setup

    def run(prefetch):
        eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                          offload_ratio=0.5, prefetch=prefetch, seed=0)
        res = eng.generate(prompt, max_new=5, temperature=0.0)
        return eng, res

    eng_p, res_p = run(True)
    eng_s, res_s = run(False)
    assert eng_p.storage.prefetcher is not None
    assert eng_p.storage.prefetcher.submitted > 0
    assert eng_s.storage.prefetcher is None
    assert np.array_equal(res_p.tokens, res_s.tokens)
    for a, b in zip(res_p.stats, res_s.stats):
        assert a == b                      # dataclass field-wise equality
    assert eng_p.coldstore.total_bytes == eng_s.coldstore.total_bytes
    assert eng_p.coldstore.total_io_time == eng_s.coldstore.total_io_time
    assert eng_p.cache.stats.hits == eng_s.cache.stats.hits
    assert eng_p.cache.stats.misses == eng_s.cache.stats.misses


def test_engine_has_no_storage_pricing(setup):
    """Acceptance: the orchestrator no longer owns storage-plane
    pricing; cache/coldstore construction lives in StoragePlane."""
    cfg, params, plan, _ = setup
    import inspect
    from repro.serving import engine as engine_mod
    src = inspect.getsource(engine_mod)
    assert "_storage_step" not in src
    assert "NeuronCache(" not in src
    assert "ColdStore(" not in src
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5)
    assert isinstance(eng.storage, StoragePlane)
    # legacy read access still works
    assert eng.cache is eng.storage.cache
    assert eng.coldstore is eng.storage.coldstore


# ------------------------------------------------- replica routing (dp) ----

def _dp_engine(cfg, params, plan, dp=None, seed=0):
    return ServeEngine(cfg, params, plan, spec=POWERINFER2,
                       offload_ratio=0.5, buckets=(1, 2, 4),
                       ctx_budget=40, temperature=0.8, seed=seed, dp=dp)


def test_replica_router_least_loaded_fifo_tiebreak():
    """Equal loads round-robin (FIFO over replicas); an unbalanced
    replica is skipped until loads equalize."""
    scheds = [BatchScheduler(), BatchScheduler()]
    router = ReplicaRouter(scheds)
    picks = []
    for _i in range(4):
        r = router.pick_replica()
        picks.append(r)
        local = scheds[r].submit(np.arange(4), 8).uid
        assert router.locate(router.bind(r, local)) == (r, local)
    assert picks == [0, 1, 0, 1]
    # load replica 1 twice more: next two picks must go to replica 0
    for _ in range(2):
        scheds[1].submit(np.arange(4), 8)
    assert router.pick_replica() == 0
    scheds[0].submit(np.arange(4), 8)
    assert router.pick_replica() == 0
    # global-uid view covers every routed request in submission order
    assert list(router.sequences) == [0, 1, 2, 3]
    assert router.has_work


def test_fifo_head_of_line_is_per_replica():
    """Satellite regression: FIFO admission blocks behind the queue
    head *within* a replica only — a not-yet-arrived head routed to
    one replica must not starve an already-arrived request on the
    other (pop_admissible is per-scheduler under the router)."""
    scheds = [BatchScheduler(), BatchScheduler()]
    router = ReplicaRouter(scheds)
    ra = router.pick_replica()                 # A -> replica 0 (far future)
    a = scheds[ra].submit(np.arange(4), 8, arrival_time=50.0)
    router.bind(ra, a.uid)
    rb = router.pick_replica()                 # B -> replica 1 (arrived)
    assert rb != ra
    b = scheds[rb].submit(np.arange(4), 8, arrival_time=0.0)
    router.bind(rb, b.uid)
    # at t=1: A's replica is head-blocked, B's replica admits B
    assert scheds[ra].pop_admissible(1.0, 10) == []
    assert [r.uid for r in scheds[rb].pop_admissible(1.0, 10)] == [b.uid]


def test_dp_head_of_line_engine_vs_single(setup):
    """End to end: the same two-request stream head-blocks a dp=1
    engine until the late head arrives, while a dp=2 engine serves the
    early request immediately on the other replica."""
    cfg, params, plan, _ = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 16) for _ in range(2)]

    eng1 = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                       offload_ratio=0.5, buckets=(1, 2),
                       ctx_budget=40, temperature=0.8)
    eng1.submit(prompts[0], max_new=4, arrival_time=50.0)
    b1 = eng1.submit(prompts[1], max_new=4, arrival_time=0.0)
    eng1.run_until_drained()
    # single replica: FIFO head A blocks B past A's arrival
    assert eng1.sched.sequences[b1].first_token_time > 50.0

    eng2 = _dp_engine(cfg, params, plan, dp=2)
    a2 = eng2.submit(prompts[0], max_new=4, arrival_time=50.0)
    b2 = eng2.submit(prompts[1], max_new=4, arrival_time=0.0)
    rep = eng2.run_until_drained()
    reqs = eng2.sched.sequences
    assert reqs[b2].finish_time < 50.0         # served while A in flight
    assert reqs[a2].first_token_time > 50.0
    assert len(rep.requests) == 2
    eng1.close(), eng2.close()


def test_dp_engine_token_identical_to_routed_dp1(setup):
    """Tentpole golden (meshless): a dp=2 engine decodes
    token-identical to two independent dp=1 engines fed the routed
    sub-streams, and the merged report aggregates both replica
    timelines."""
    cfg, params, plan, _ = setup
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, 16),
             int(rng.integers(3, 7)), i * 1e-3) for i in range(5)]

    eng = _dp_engine(cfg, params, plan, dp=2)
    # meshless replicas share jit caches (identical executables on the
    # same params) — dp must not multiply trace time
    assert eng.replicas[1].decoder._cache is eng.replicas[0].decoder._cache
    for p, m, t in reqs:
        eng.submit(p, m, arrival_time=t)
    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    toks_dp = {u: list(r.generated) for u, r in eng.sched.sequences.items()}
    assignment = dict(eng.router.assignment)
    # merged report: every replica contributed, span is the slowest
    # replica's clock, timeline length covers every step
    assert {s.replica for s in rep.stats} == {0, 1}
    assert rep.span_s == max(r.clock_s for r in eng.replicas)
    assert rep.span_s == eng.clock_s
    # one merged entry per replica step (no cancels -> batch_history
    # is exactly one append per step)
    assert len(rep.stats) == sum(len(r.sched.batch_history)
                                 for r in eng.replicas)
    assert rep.throughput_tok_s > 0 and rep.total_tokens == \
        sum(len(t) for t in toks_dp.values())
    assert len(eng.sched.batch_history) == len(rep.stats)
    eng.close()

    toks_ref = {}
    for r in (0, 1):
        sub = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                          offload_ratio=0.5, buckets=(1, 2, 4),
                          ctx_budget=40, temperature=0.8, seed=0)
        local_to_global = {}
        for g, (rep_idx, _) in assignment.items():
            if rep_idx != r:
                continue
            p, m, t = reqs[g]
            local_to_global[sub.submit(p, m, arrival_time=t)] = g
        sub.run_until_drained()
        for lu, g in local_to_global.items():
            toks_ref[g] = list(sub.sched.sequences[lu].generated)
        sub.close()
    assert toks_dp == toks_ref


def test_dp_cancel_routes_and_report_survives(setup):
    """Satellite regression via ServeEngine.cancel(): requests
    cancelled before their first token (still queued, or the whole
    stream) must neither crash the report nor leak into TTFT."""
    cfg, params, plan, _ = setup
    rng = np.random.default_rng(4)

    # whole stream cancelled before any step: empty-report edge
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2),
                      ctx_budget=40, temperature=0.8)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=4)
            for _ in range(2)]
    eng.cancel(uids)
    rep = eng.run_until_drained()
    assert rep.stats == [] and len(rep.requests) == 2
    assert rep.ttft().size == 0                        # None never coerced
    assert rep.tokens_per_s == 0.0 and rep.throughput_tok_s == 0.0
    pct = rep.latency_percentiles()                    # must not raise
    assert pct["p99"] == 0.0
    eng.close()

    # dp engine: cancel routes to the owning replica; a queued cancel
    # finishes tokenless while the rest of the stream completes
    eng = _dp_engine(cfg, params, plan, dp=2)
    keep, drop = [], None
    for i in range(3):
        u = eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=3,
                       arrival_time=0.0)
        (keep.append(u) if i < 2 else (drop := u))
    eng.cancel([drop])
    rep = eng.run_until_drained()
    reqs = eng.sched.sequences
    assert reqs[drop].finished and reqs[drop].generated == []
    assert reqs[drop].first_token_time is None
    assert all(len(reqs[u].generated) == 3 for u in keep)
    assert rep.ttft().size == 2                        # cancelled filtered
    rep.latency_percentiles()
    eng.close()


def test_dp_failed_submit_does_not_perturb_routing(setup):
    """A submit that fails validation must leave the FIFO tiebreak
    order untouched — the deterministic round-robin resumes as if the
    bad call never happened."""
    cfg, params, plan, _ = setup
    eng = _dp_engine(cfg, params, plan, dp=2)
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32), max_new=2)
    u0 = eng.submit(np.arange(4), 2, arrival_time=0.0)
    u1 = eng.submit(np.arange(4), 2, arrival_time=0.0)
    assert eng.router.locate(u0)[0] == 0
    assert eng.router.locate(u1)[0] == 1
    eng.run_until_drained()
    eng.close()


def test_dp_cancel_running_records_merged_decay(setup):
    """A between-step cancel of a running request is a decay event on
    the *merged* batch timeline too, mirroring the per-scheduler
    BatchScheduler.finish fix."""
    cfg, params, plan, _ = setup
    rng = np.random.default_rng(5)
    eng = _dp_engine(cfg, params, plan, dp=2)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=6,
                       arrival_time=0.0) for _ in range(2)]
    eng.step(), eng.step()                     # both replicas running
    total0 = eng.sched.batch_size
    assert total0 == 2
    hist0 = len(eng.sched.batch_history)
    eng.cancel([uids[0]])
    assert eng.sched.batch_history[hist0:] == [total0 - 1]
    eng.run_until_drained()
    eng.close()


def test_zero_token_reports_return_zero():
    """Satellite: empty stats must read as 0.0 tok/s (was inf) in both
    report classes, and percentile summaries must not crash."""
    g = GenerationResult(tokens=np.zeros((1, 0), np.int32), stats=[])
    assert g.tokens_per_s == 0.0
    assert g.latency_percentiles()["mean"] == 0.0
    r = ServeReport(stats=[], requests=[])
    assert r.tokens_per_s == 0.0
    assert r.throughput_tok_s == 0.0
    assert r.total_tokens == 0
    assert r.ttft().size == 0
    assert r.latency_percentiles()["p50"] == 0.0
