"""Layered serving stack: continuous batching (mid-stream admission,
bucket-boundary retrace discipline), slot-based KV recycling, the
generate() compatibility wrapper vs the seed decode loop, and
StoragePlane.step determinism with/without the prefetch thread."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptation import BucketedDecoder
from repro.core.baselines import POWERINFER2
from repro.core.planner import build_plan, permute_ffn_params
from repro.models import dense
from repro.serving.engine import ServeEngine
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import BatchScheduler
from repro.serving.storage_plane import StoragePlane


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    model = dense.make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = build_plan(cfg)
    params = permute_ffn_params(params, plan.neuron_order)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    return cfg, params, plan, prompt


# ------------------------------------------------- continuous batching ----

def test_midstream_admission_grows_then_decays(setup):
    """A request admitted at step k>0 joins the running batch, crosses
    a bucket boundary with at most one decoder retrace, and completes;
    batch_history shows growth then decay."""
    cfg, params, plan, _ = setup
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2, 4, 8),
                      ctx_budget=40, temperature=0.8)
    rng = np.random.default_rng(1)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=8)
    r = eng.step()
    assert r.stats.batch == 2
    eng.step()

    # mid-stream admission: 2 -> 3 crosses the 2->4 bucket boundary
    switches0 = eng.decoder.switches
    traces0 = len(eng.decoder._cache)
    resizes0 = eng.arena.resizes
    uid = eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=4)
    r = eng.step()
    assert uid in r.admitted
    assert r.stats.batch == 3
    assert eng.arena.n_slots == 4                      # next bucket
    assert eng.decoder.switches - switches0 == 1       # one swap
    assert len(eng.decoder._cache) - traces0 == 1      # one new trace
    assert eng.arena.resizes - resizes0 == 1           # one reshape

    rep = eng.run_until_drained()
    assert not eng.sched.has_work
    assert eng.sched.sequences[uid].finished
    hist = eng.sched.batch_history
    assert max(hist) == 3 and hist[0] == 2 and hist[-1] == 0
    grow = hist.index(3)
    assert any(b < 3 for b in hist[grow:])             # decay after growth
    # the joiner generated its full budget
    assert len(eng.sched.sequences[uid].generated) == 4
    assert rep.total_tokens == sum(s.batch for s in rep.stats)


def test_kv_slots_recycled_after_completion(setup):
    """A completed request's slot returns to the free list and is
    reused by the next admission without any arena reshape."""
    cfg, params, plan, _ = setup
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, buckets=(1, 2, 4),
                      ctx_budget=40, temperature=0.8)
    rng = np.random.default_rng(2)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=n)
            for n in (2, 6, 6, 6)]
    eng.step()
    r = eng.step()                                     # uid 0 completes here
    assert uids[0] in r.finished
    freed_slot = 0
    assert freed_slot in eng.arena.free
    resizes0 = eng.arena.resizes

    new_uid = eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=2)
    r = eng.step()
    assert new_uid in r.admitted
    assert eng.arena.slot_of[new_uid] == freed_slot    # recycled
    assert eng.arena.resizes == resizes0               # no reshape
    assert eng.arena.n_slots == 4
    eng.run_until_drained()
    assert not eng.sched.has_work
    assert eng.arena.n_free == eng.arena.n_slots


def test_scheduler_admission_queue_fifo():
    sched = BatchScheduler()
    r1 = sched.submit(np.arange(4), 8, arrival_time=0.0)
    r2 = sched.submit(np.arange(4), 8, arrival_time=5.0)
    r3 = sched.submit(np.arange(4), 8, arrival_time=1.0)
    # r2 blocks the head at t=2 even though r3 has arrived (FIFO)
    assert [r.uid for r in sched.pop_admissible(2.0, 10)] == [r1.uid]
    assert sched.next_arrival() == 5.0
    got = sched.pop_admissible(6.0, 10)
    assert [r.uid for r in got] == [r2.uid, r3.uid]
    assert sched.pop_admissible(100.0, 10) == []


# -------------------------------------------------- compat wrapper ----

def _reference_generate(cfg, params, plan, prompt, max_new, temperature,
                        seed=0):
    """The seed engine's decode loop (static batch, compaction-by-take),
    data plane only — the behavioral contract generate() must keep."""
    model = dense.make_model(cfg)
    step_traced = dense.make_decode_step(cfg, collect_indices=True)
    decoder = BucketedDecoder(
        plan_source=plan,
        make_step=lambda p: (lambda pr, t, c: step_traced(pr, t, c, p)),
        buckets=tuple(range(1, 65)))
    key = jax.random.key(seed)
    prompt = jnp.asarray(prompt)
    B, S = prompt.shape
    logits, cache = jax.jit(lambda p, b: model.prefill(
        p, b, max_len=S + max_new))(params, {"tokens": prompt})
    out = np.full((B, max_new), -1, np.int32)
    active = list(range(B))
    n_gen = {i: 0 for i in active}
    last = logits[:, -1]
    for step in range(max_new):
        if not active:
            break
        _, step_fn = decoder.executable_for(len(active))
        key, sk = jax.random.split(key)
        toks = sample_tokens(sk, last, temperature)
        logits, cache, _ = step_fn(params, toks[:, None], cache)
        last = logits[:, 0]
        finish = []
        for row, uid in enumerate(active):
            out[uid, n_gen[uid]] = int(toks[row])
            n_gen[uid] += 1
            if n_gen[uid] >= max_new:
                finish.append(uid)
        if finish:
            keep = [r for r, u in enumerate(active) if u not in finish]
            active = [u for u in active if u not in finish]
            if keep and len(keep) < len(n_gen):
                rows = jnp.asarray(keep)
                cache = {"k": cache["k"].take(rows, axis=1),
                         "v": cache["v"].take(rows, axis=1),
                         "kv_pos": cache["kv_pos"].take(rows, axis=0),
                         "length": cache["length"].take(rows, axis=0)}
                last = last.take(rows, axis=0)
    return out


def test_generate_matches_seed_loop(setup):
    """generate() (continuous loop + slot arena + active-mask union)
    reproduces the seed static-batch path token-for-token."""
    cfg, params, plan, prompt = setup
    ref = _reference_generate(cfg, params, plan, prompt, max_new=6,
                              temperature=0.8, seed=0)
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5, seed=0)
    res = eng.generate(prompt, max_new=6, temperature=0.8)
    assert np.array_equal(res.tokens, ref)


def test_generate_deterministic_and_stats_shape(setup):
    cfg, params, plan, prompt = setup
    r1 = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                     offload_ratio=0.5).generate(prompt, max_new=4,
                                                 temperature=0.0)
    r2 = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                     offload_ratio=0.5).generate(prompt, max_new=4,
                                                 temperature=0.0)
    assert np.array_equal(r1.tokens, r2.tokens)
    assert [s.batch for s in r1.stats] == [4, 4, 4, 4]


# ------------------------------------------------------ storage plane ----

def test_storage_plane_stats_prefetch_invariant(setup):
    """The prefetch thread moves real bytes but must not change any
    modeled number: step() stats with the I/O thread on equal the
    sequential (pre-refactor _storage_step) pricing exactly."""
    cfg, params, plan, prompt = setup

    def run(prefetch):
        eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                          offload_ratio=0.5, prefetch=prefetch, seed=0)
        res = eng.generate(prompt, max_new=5, temperature=0.0)
        return eng, res

    eng_p, res_p = run(True)
    eng_s, res_s = run(False)
    assert eng_p.storage.prefetcher is not None
    assert eng_p.storage.prefetcher.submitted > 0
    assert eng_s.storage.prefetcher is None
    assert np.array_equal(res_p.tokens, res_s.tokens)
    for a, b in zip(res_p.stats, res_s.stats):
        assert a == b                      # dataclass field-wise equality
    assert eng_p.coldstore.total_bytes == eng_s.coldstore.total_bytes
    assert eng_p.coldstore.total_io_time == eng_s.coldstore.total_io_time
    assert eng_p.cache.stats.hits == eng_s.cache.stats.hits
    assert eng_p.cache.stats.misses == eng_s.cache.stats.misses


def test_engine_has_no_storage_pricing(setup):
    """Acceptance: the orchestrator no longer owns storage-plane
    pricing; cache/coldstore construction lives in StoragePlane."""
    cfg, params, plan, _ = setup
    import inspect
    from repro.serving import engine as engine_mod
    src = inspect.getsource(engine_mod)
    assert "_storage_step" not in src
    assert "NeuronCache(" not in src
    assert "ColdStore(" not in src
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5)
    assert isinstance(eng.storage, StoragePlane)
    # legacy read access still works
    assert eng.cache is eng.storage.cache
    assert eng.coldstore is eng.storage.coldstore
