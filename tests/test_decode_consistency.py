"""Decode-vs-teacher-forced consistency — the strongest correctness
check: prefill(S) + N single-token decode steps must reproduce the
full-sequence forward logits for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.configs import get_config
from repro.models.model import build_model

CASES = ["smollm-135m", "qwen3-14b", "mamba2-130m", "recurrentgemma-9b",
         "seamless-m4t-large-v2", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = cfg.replace(moe_capacity_factor=8.0)   # no capacity drops
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    batch = tiny_batch(cfg, B, S)
    full = jax.jit(lambda p, b: model.forward(p, b))(params, batch)

    half = dict(batch, tokens=batch["tokens"][:, :32])
    T = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=T))(
        params, half)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(32, 64):
        lg, cache = step(params, jnp.asarray(batch["tokens"][:, t:t + 1]),
                         cache)
        outs.append(lg)
    dec = jnp.concatenate(outs[:-1], axis=1)     # logits at positions 32..62
    ref = full[:, -32:-1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "grok-1-314b"])
def test_moe_decode_matches_forward_one_step(arch):
    cfg = get_config(arch).reduced().replace(moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = tiny_batch(cfg, B, S)
    tokens2 = np.concatenate([batch["tokens"], batch["tokens"][:, -1:]], 1)
    full = jax.jit(lambda p, b: model.forward(p, b))(
        params, dict(batch, tokens=tokens2))
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=S + 4))(
        params, batch)
    lg, _ = jax.jit(model.decode_step)(
        params, jnp.asarray(batch["tokens"][:, -1:]), cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-3, rtol=1e-3)
