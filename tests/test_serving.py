"""Serving engine integration: system ordering (paper Fig 7/14),
cache behavior (Fig 10), Best-of-N batch adaptation (Fig 13),
bucketed-executable swaps (§4.1.3)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptation import BatchTracker, bucket_for
from repro.core.baselines import (ABLATION_LADDER, LLAMACPP, LLMFLASH,
                                  POWERINFER2)
from repro.core.planner import build_plan, permute_ffn_params
from repro.models.dense import make_model
from repro.serving.engine import ServeEngine
from repro.serving.sampler import sample_tokens, sequence_logprob


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    plan = build_plan(cfg)
    params = permute_ffn_params(params, plan.neuron_order)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    return cfg, params, plan, prompt


def _run(cfg, params, plan, prompt, spec, offload=0.5, **kw):
    eng = ServeEngine(cfg, params, plan, spec=spec, offload_ratio=offload)
    return eng.generate(prompt, max_new=8, **kw), eng


def test_system_ordering(setup):
    """PowerInfer-2 >= LLMFlash-analogue >> llama.cpp-analogue."""
    cfg, params, plan, prompt = setup
    r_pi2, _ = _run(cfg, params, plan, prompt, POWERINFER2)
    r_lf, _ = _run(cfg, params, plan, prompt, LLMFLASH)
    r_lc, _ = _run(cfg, params, plan, prompt, LLAMACPP)
    assert r_pi2.tokens_per_s >= r_lf.tokens_per_s
    assert r_lf.tokens_per_s > r_lc.tokens_per_s
    assert r_pi2.tokens_per_s / r_lc.tokens_per_s > 3.0


def test_ablation_ladder_monotone(setup):
    """Fig 14: each added mechanism must not hurt throughput."""
    cfg, params, plan, prompt = setup
    speeds = []
    for spec in ABLATION_LADDER:
        r, _ = _run(cfg, params, plan, prompt, spec)
        speeds.append(r.tokens_per_s)
    # allow small non-monotonicity only between adjacent rungs
    assert speeds[-1] > speeds[0] * 2
    for a, b in zip(speeds, speeds[1:]):
        assert b >= a * 0.9


def test_cache_size_scaling(setup):
    """Fig 10: more resident memory -> faster decode (less I/O)."""
    cfg, params, plan, prompt = setup
    speeds = []
    for offload in (0.9, 0.5, 0.1):
        r, _ = _run(cfg, params, plan, prompt, POWERINFER2, offload=offload)
        speeds.append(r.tokens_per_s)
    assert speeds == sorted(speeds), speeds


def test_generated_tokens_valid(setup):
    cfg, params, plan, prompt = setup
    r, _ = _run(cfg, params, plan, prompt, POWERINFER2)
    toks = r.tokens[r.tokens >= 0]
    assert toks.size > 0
    assert (toks < cfg.vocab_size).all()


def test_bon_batch_decay_swaps_executables(setup):
    """Fig 13: sequences completing -> smaller batches -> executable
    swaps (the pre-built NPU graph analogue)."""
    cfg, params, plan, prompt = setup
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5)
    res = eng.generate(prompt, max_new=12,
                       completion_schedule={3: 1, 6: 1, 9: 1})
    batches = [s.batch for s in res.stats]
    assert batches[0] == 4
    assert batches[-1] == 1
    assert eng.decoder.switches >= 4


def test_deterministic_greedy(setup):
    cfg, params, plan, prompt = setup
    r1, _ = _run(cfg, params, plan, prompt, POWERINFER2, temperature=0.0)
    r2, _ = _run(cfg, params, plan, prompt, POWERINFER2, temperature=0.0)
    assert np.array_equal(r1.tokens, r2.tokens)


def test_bucket_for():
    assert bucket_for(1) == 1
    assert bucket_for(3) == 4
    assert bucket_for(33) == 32     # capped at largest bucket


def test_batch_tracker():
    t = BatchTracker()
    t.start(4)
    t.finish(1)
    t.finish(1)
    assert t.active == 2
    assert t.history == [4, 3, 2]


def test_sampler_topk_restricts():
    import jax.numpy as jnp
    logits = jnp.asarray(np.array([[0.0, 5.0, 4.0, -3.0]]))
    for seed in range(10):
        t = sample_tokens(jax.random.key(seed), logits, temperature=1.0,
                          top_k=2)
        assert int(t[0]) in (1, 2)


def test_sequence_logprob_ranks_confident_sequences_higher():
    import jax.numpy as jnp
    V = 8
    conf = jnp.full((1, 4, V), -10.0).at[:, :, 3].set(10.0)
    unif = jnp.zeros((1, 4, V))
    toks = jnp.full((1, 4), 3, jnp.int32)
    assert float(sequence_logprob(conf, toks)[0]) > \
        float(sequence_logprob(unif, toks)[0])
