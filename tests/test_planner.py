"""Offline planner (paper §5): profiling, classification, permutation."""
import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.core.planner import (
    ExecutionPlan, HardwareProfile, build_plan, classify_neurons,
    permute_ffn_params, profile_activations, synthetic_frequencies)
from repro.core.sparse_ffn import ffn_dense
from repro.models.dense import make_model


@pytest.fixture(scope="module")
def relu_model():
    cfg = get_config("smollm-135m").reduced().replace(activation="relu2")
    cfg = cfg.replace(sparse_ffn=dataclasses.replace(cfg.sparse_ffn,
                                                     mode="relu"))
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


def test_profile_counts_bounded(relu_model):
    cfg, m, params = relu_model
    batches = [jax.random.randint(jax.random.key(i), (2, 32), 0,
                                  cfg.vocab_size) for i in range(2)]
    counts, n_tok = profile_activations(params, cfg, batches)
    assert counts.shape == (cfg.num_layers, cfg.d_ff)
    assert n_tok == 2 * 2 * 32
    assert (counts >= 0).all() and (counts <= n_tok).all()


def test_classification_hot_grows_with_batch():
    cfg = get_config("smollm-135m").reduced()
    freqs = synthetic_frequencies(cfg, seed=1)
    order, sf, plans = classify_neurons(freqs, cfg, HardwareProfile())
    hots = [plans[b].n_hot for b in sorted(plans)]
    assert hots == sorted(hots), "hot prefix must grow with batch size"
    # permutation is a bijection per layer
    for l in range(order.shape[0]):
        assert sorted(order[l].tolist()) == list(range(order.shape[1]))
    # frequencies sorted descending after permutation
    assert (np.diff(sf, axis=1) <= 1e-9).all()


def test_io_cap_limits_hot_set():
    cfg = get_config("smollm-135m").reduced()
    freqs = np.full((cfg.num_layers, cfg.d_ff), 0.9, np.float32)
    slow = HardwareProfile(seq_bw=1e4, attn_time_s=1e-6)   # ~0 capacity
    _, _, plans = classify_neurons(freqs, cfg, slow)
    fast = HardwareProfile(seq_bw=1e12, attn_time_s=1.0)
    _, _, plans_fast = classify_neurons(freqs, cfg, fast)
    assert plans[32].n_hot <= plans_fast[32].n_hot


def test_permutation_preserves_dense_ffn(relu_model):
    cfg, m, params = relu_model
    plan = build_plan(cfg)
    p2 = permute_ffn_params(params, plan.neuron_order)
    x = jax.random.normal(jax.random.key(5), (2, 8, cfg.d_model)) * 0.1
    for l in range(cfg.num_layers):
        l0 = jax.tree.map(lambda a, l=l: a[l], params["layers"]["ffn"])
        l1 = jax.tree.map(lambda a, l=l: a[l], p2["layers"]["ffn"])
        y0 = ffn_dense(l0, x, cfg.activation)
        y1 = ffn_dense(l1, x, cfg.activation)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-5, rtol=1e-5)


def test_permutation_aligns_predictor(relu_model):
    """After permutation, predictor scores must follow neurons."""
    cfg, m, params = relu_model
    from repro.core.predictor import predict_scores
    plan = build_plan(cfg)
    p2 = permute_ffn_params(params, plan.neuron_order)
    x = jax.random.normal(jax.random.key(6), (3, cfg.d_model)) * 0.1
    s0 = np.asarray(predict_scores(
        jax.tree.map(lambda a: a[0], params["layers"]["ffn"])["pred"], x))
    s1 = np.asarray(predict_scores(
        jax.tree.map(lambda a: a[0], p2["layers"]["ffn"])["pred"], x))
    np.testing.assert_allclose(s1, s0[:, plan.neuron_order[0]],
                               atol=1e-5, rtol=1e-5)


def test_plan_save_load_roundtrip(tmp_path):
    cfg = get_config("smollm-135m").reduced()
    plan = build_plan(cfg)
    f = tmp_path / "plan.json"
    plan.save(f)
    plan2 = ExecutionPlan.load(f)
    assert plan2.plans == plan.plans
    assert np.array_equal(plan2.neuron_order, plan.neuron_order)
    assert plan2.hardware == plan.hardware


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64))
def test_plan_for_batch_monotone(b):
    cfg = get_config("smollm-135m").reduced()
    plan = build_plan(cfg)
    p = plan.plan_for_batch(b)
    p2 = plan.plan_for_batch(min(b * 2, 64))
    assert p2.n_hot >= p.n_hot
