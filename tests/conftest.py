"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py forces 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_batch(cfg, B=2, S=32, seed=0, with_labels=False):
    """Family-correct input batch for a reduced config."""
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.num_frames, cfg.d_model)).astype(np.float32) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32) * 0.1
    if with_labels:
        batch["labels"] = rng.integers(0, cfg.vocab_size,
                                       (B, S)).astype(np.int32)
    return batch
