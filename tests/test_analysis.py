"""Unit tests for the repro-analyze checker battery (DESIGN.md §12).

Each checker gets a violating and a clean inline snippet; the ratchet
(inline ignores, allowlist, stale-entry failure) is exercised through
both the library API and the CLI; and the repo-self-check asserts the
committed tree is clean under the committed allowlist — the same gate
CI's static-analysis job runs.
"""
import os
import subprocess
import sys

import pytest

from repro.analysis import (AnalysisConfig, Finding, all_rules,
                            analyze_files, analyze_source,
                            apply_allowlist)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CLI = os.path.join(REPO, "scripts", "repro_analyze.py")

sys.path.insert(0, os.path.join(REPO, "scripts"))
from _ratchet import diff_ratchet, dump_json, load_json  # noqa: E402


def rules_of(findings):
    return {f.rule for f in findings}


def run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run([sys.executable, CLI, *args], cwd=REPO,
                          env=env, capture_output=True, text=True)


# ------------------------------------------------------ collectives ----

SHARD_MAP_TAIL = """
def build(mesh, shard_map):
    return shard_map(local, mesh=mesh, in_specs=("model",),
                     out_specs=("model",), axis_names={"model"})
"""


def test_collective_wrong_axis_fires():
    src = """
import jax
import jax.numpy as jnp

def local(x):
    return jax.lax.psum(x.astype(jnp.float32), "data")
""" + SHARD_MAP_TAIL
    assert "collective-axis" in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_outside_shard_map_fires():
    src = """
import jax

def free(x):
    return jax.lax.all_gather(x, "model")
"""
    assert "collective-axis" in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_budget_sequential_psums_fire():
    src = """
import jax
import jax.numpy as jnp

def local(x):
    a = jax.lax.psum(x.astype(jnp.float32), "model")
    b = jax.lax.psum(a.astype(jnp.float32), "model")
    return b
""" + SHARD_MAP_TAIL
    assert "collective-budget" in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_budget_exclusive_branches_pass():
    # the sparse_ffn pattern: one psum per backend arm, never both
    src = """
import jax
import jax.numpy as jnp

def local(x, flag):
    if flag:
        return jax.lax.psum(x.astype(jnp.float32), "model")
    return jax.lax.psum((x * 2).astype(jnp.float32), "model")
""" + SHARD_MAP_TAIL
    assert "collective-budget" not in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_budget_looped_psum_fires():
    src = """
import jax
import jax.numpy as jnp

def local(x):
    for _ in range(3):
        x = jax.lax.psum(x.astype(jnp.float32), "model")
    return x
""" + SHARD_MAP_TAIL
    assert "collective-budget" in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_fp32_required():
    src = """
import jax

def local(x):
    return jax.lax.psum(x, "model")
""" + SHARD_MAP_TAIL
    assert "collective-fp32" in rules_of(
        analyze_source(src, "src/repro/x.py"))


def test_collective_clean_body_passes():
    src = """
import jax
import jax.numpy as jnp

def local(x):
    y = jax.lax.psum(x.astype(jnp.float32), "model")
    idx = jax.lax.all_gather(y, "model")
    return y, idx
""" + SHARD_MAP_TAIL
    assert analyze_source(src, "src/repro/x.py") == []


# --------------------------------------------------- kernel hygiene ----

def test_dma_start_without_wait_fires():
    src = """
from jax.experimental.pallas import tpu as pltpu

def kernel(x_ref, o_ref, sem):
    cp = pltpu.make_async_copy(x_ref, o_ref, sem)
    cp.start()
"""
    found = analyze_source(src, "src/repro/kernels/x.py")
    assert "dma-pairing" in rules_of(found)
    assert any("races" in f.message for f in found)


def test_dma_wait_without_start_fires():
    src = """
from jax.experimental.pallas import tpu as pltpu

def kernel(x_ref, o_ref, sem):
    pltpu.make_async_copy(x_ref, o_ref, sem).wait()
"""
    found = analyze_source(src, "src/repro/kernels/x.py")
    assert "dma-pairing" in rules_of(found)
    assert any("deadlock" in f.message for f in found)


def test_dma_nested_helper_pattern_passes():
    # the fused kernel's shape: constructor helper nested inside the
    # run_scoped body, started and waited through separate call sites
    src = """
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

def kernel(w_hbm, o_ref):
    def body(buf, sem):
        def cluster_dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[k], buf.at[slot], sem.at[slot])
        cluster_dma(0, 0).start()

        def consume(k, slot):
            cluster_dma(slot, k).wait()
        consume(0, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, 8), jnp.float32),
                  sem=pltpu.SemaphoreType.DMA((2,)))
"""
    assert analyze_source(src, "src/repro/kernels/x.py") == []


def test_semaphore_outside_run_scoped_fires():
    src = """
from jax.experimental.pallas import tpu as pltpu

def kernel():
    return pltpu.SemaphoreType.DMA((2,))
"""
    assert "semaphore-scope" in rules_of(
        analyze_source(src, "src/repro/kernels/x.py"))


def test_vmem_budget_cap_fires_and_scales():
    src = """
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

def kernel(body):
    pl.run_scoped(body, buf=pltpu.VMEM((4, 1024, 1024), jnp.float32))
"""
    # 16MiB of scratch: over a 8MiB cap, under a 32MiB one
    tight = AnalysisConfig(vmem_cap_bytes=8 * 2**20)
    roomy = AnalysisConfig(vmem_cap_bytes=32 * 2**20)
    assert "vmem-budget" in rules_of(
        analyze_source(src, "src/repro/kernels/x.py", tight))
    assert "vmem-budget" not in rules_of(
        analyze_source(src, "src/repro/kernels/x.py", roomy))


def test_vmem_symbolic_dims_use_assumptions():
    src = """
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

def kernel(body, w_hbm, cs):
    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], jnp.float32))
"""
    # cs=256 (assumed), trailing dims default to 128: 2*256*128*4 =
    # 256KiB — under any sane cap
    assert "vmem-budget" not in rules_of(
        analyze_source(src, "src/repro/kernels/x.py"))


# ---------------------------------------------------- trace hazards ----

def test_wall_clock_fires_in_serving():
    src = """
import time

def tick():
    return time.monotonic()
"""
    assert "wall-clock" in rules_of(
        analyze_source(src, "src/repro/serving/x.py"))


def test_wall_clock_scope_excludes_models():
    src = """
import time

def tick():
    return time.monotonic()
"""
    assert analyze_source(src, "src/repro/models/x.py") == []


def test_py_random_global_state_fires():
    src = """
import random
import numpy as np

def draw():
    return random.random() + np.random.rand()
"""
    found = analyze_source(src, "src/repro/serving/x.py")
    assert sum(f.rule == "py-random" for f in found) == 2


def test_py_random_seeded_default_rng_passes():
    src = """
import numpy as np

def draw(seed):
    return np.random.default_rng(seed).random(4)
"""
    assert analyze_source(src, "src/repro/serving/x.py") == []


def test_py_random_unseeded_default_rng_fires():
    src = """
import numpy as np

def draw():
    return np.random.default_rng().random(4)
"""
    assert "py-random" in rules_of(
        analyze_source(src, "src/repro/serving/x.py"))


def test_local_variable_named_random_is_not_flagged():
    src = """
def pick(random):
    return random.choice()
"""
    assert analyze_source(src, "src/repro/serving/x.py") == []


def test_tracer_branch_fires_in_jit():
    src = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    y = jnp.tanh(x)
    if y:
        y = y + 1.0
    return y
"""
    assert "tracer-branch" in rules_of(
        analyze_source(src, "src/repro/serving/x.py"))


def test_tracer_branch_static_values_pass():
    src = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x, n):
    y = jnp.tanh(x)
    if n > 2:
        y = y + 1.0
    return y
"""
    assert analyze_source(src, "src/repro/serving/x.py") == []


def test_jit_static_argnames_must_exist():
    src = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("mode", "ghost"))
def f(x, mode="a"):
    return x
"""
    found = analyze_source(src, "src/repro/serving/x.py")
    assert [f.rule for f in found] == ["jit-static-args"]
    assert "ghost" in found[0].message


def test_jit_static_arg_nonhashable_default_fires():
    src = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("opts",))
def f(x, opts=[]):
    return x
"""
    assert "jit-static-args" in rules_of(
        analyze_source(src, "src/repro/serving/x.py"))


def test_jit_static_argnums_range():
    src = """
import functools
import jax

@functools.partial(jax.jit, static_argnums=(5,))
def f(x, y):
    return x + y
"""
    assert "jit-static-args" in rules_of(
        analyze_source(src, "src/repro/serving/x.py"))


# --------------------------------------------- protocol conformance ----

HANDLE_SRC = """
class FooHandle:
    def submit(self, prompt, max_new):
        raise NotImplementedError

    @property
    def load(self):
        raise NotImplementedError

    def close(self):
        return None


class GoodImpl(FooHandle):
    def submit(self, prompt, max_new, extra=None):
        return prompt

    @property
    def load(self):
        return 0.0


class BadImpl(FooHandle):
    def submit(self, prompt):
        return prompt

    def load(self):
        return 0.0
"""


def test_protocol_method_drift_fires_only_for_bad_impl():
    found = analyze_files({"src/handles.py": HANDLE_SRC})
    assert rules_of(found) == {"protocol-method"}
    # BadImpl: submit arity + load property-ness; GoodImpl clean
    assert len(found) == 2
    assert all("BadImpl" in f.message for f in found)


def test_family_fields_missing_and_shape():
    src = """
from dataclasses import dataclass


@dataclass(frozen=True)
class ServingFamily:
    family: str
    make_model: object
    build_plan: object
    default_arch: str = ""


def _mk(cfg):
    return cfg


def _plan(cfg, freqs=None, hw=None, backend=None):
    return cfg


def _bad_plan(cfg, extra):
    return cfg


ok = ServingFamily(family="a", make_model=_mk, build_plan=_plan)
missing = ServingFamily(family="b", make_model=_mk)
shape = ServingFamily(family="c", make_model=_mk, build_plan=_bad_plan)
"""
    config = AnalysisConfig(families_path="fam.py")
    found = analyze_files({"fam.py": src}, config)
    assert sum(f.rule == "family-fields" for f in found) == 2


# --------------------------------------------------- registry drift ----

FAMILIES_SRC = """
def register_family(fam):
    return fam


def _mk(name, arch):
    return ServingFamily(family=name, arch=arch)


class ServingFamily:
    pass


register_family(_mk("dense", "tiny"))
register_family(ServingFamily(family="moe"))
"""


def _drift_files(conformance):
    return {
        "fam.py": FAMILIES_SRC,
        "conf.py": conformance,
        "gate.py": "EXTRACTORS = {'serving': None}\n",
        "bench/emit.py": "DOC = {'bench': 'serving'}\n",
    }


def _drift_config():
    return AnalysisConfig(families_path="fam.py",
                          conformance_path="conf.py",
                          bench_gate_path="gate.py",
                          bench_emitter_prefix="bench/")


def test_registry_drift_fires_per_missing_family():
    found = analyze_files(
        _drift_files("ARCHS = {'dense': 'tiny'}\n"), _drift_config())
    drifts = [f for f in found if f.rule == "registry-drift"]
    assert len(drifts) == 1 and "moe" in drifts[0].message


def test_registry_drift_clean_when_covered():
    found = analyze_files(
        _drift_files("ARCHS = {'dense': 1, 'moe': 2}\n"),
        _drift_config())
    assert "registry-drift" not in rules_of(found)


def test_bench_gate_drift_fires_for_ungated_kind():
    files = _drift_files("ARCHS = {'dense': 1, 'moe': 2}\n")
    files["bench/emit.py"] = "DOC = {'bench': 'rogue'}\n"
    found = analyze_files(files, _drift_config())
    drifts = [f for f in found if f.rule == "bench-gate-drift"]
    assert len(drifts) == 1 and "rogue" in drifts[0].message


# ------------------------------------------- suppression + ratchet ----

def test_inline_ignore_same_line_and_line_above():
    src = """
import time


def a():
    return time.time()  # repro: ignore[wall-clock] justified


def b():
    # repro: ignore[wall-clock] justified
    return time.time()


def c():
    return time.time()  # repro: ignore[py-random] wrong rule
"""
    found = analyze_source(src, "src/repro/serving/x.py")
    assert [f.rule for f in found] == ["wall-clock"]
    assert found[0].line == 15


def test_inline_ignore_wildcard():
    src = """
import time


def a():
    return time.time()  # repro: ignore[*] kill everything here
"""
    assert analyze_source(src, "src/repro/serving/x.py") == []


def test_apply_allowlist_splits_kept_allowed_stale():
    f1 = Finding("wall-clock", "src/a.py", 3, "m")
    f2 = Finding("py-random", "src/b.py", 7, "m")
    allow = {"src/a.py:wall-clock": "legacy", "src/gone.py:dma-pairing": "?"}
    kept, allowed, stale = apply_allowlist([f1, f2], allow)
    assert kept == [f2]
    assert allowed == [f1]
    assert stale == ["src/gone.py:dma-pairing"]


def test_syntax_error_is_a_finding_not_a_crash():
    found = analyze_files({"src/broken.py": "def broken(:\n"})
    assert rules_of(found) == {"syntax-error"}


def test_ratchet_helpers_roundtrip(tmp_path):
    p = str(tmp_path / "base.json")
    assert load_json(p, default={}) == {}
    with pytest.raises(FileNotFoundError):
        load_json(p)
    dump_json(p, {"b": 2, "a": 1})
    assert load_json(p) == {"a": 1, "b": 2}
    new, stale = diff_ratchet({"x", "y"}, {"y", "z"})
    assert new == ["x"] and stale == ["z"]


# --------------------------------------------------- CLI + repo gate ----

def test_cli_self_test_proves_every_rule_fires():
    r = run_cli("--self-test")
    assert r.returncode == 0, r.stdout + r.stderr
    for rule in all_rules():
        assert f"ok   {rule}" in r.stdout


def test_repo_tree_clean_under_committed_allowlist():
    r = run_cli()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_cli_stale_allowlist_entry_fails_gate(tmp_path):
    allow = tmp_path / "allow.json"
    allow.write_text('{"src/gone.py:wall-clock": "fixed ages ago"}\n')
    r = run_cli("--allowlist", str(allow), "scripts")
    assert r.returncode == 1
    assert "stale" in r.stdout
    r2 = run_cli("--allowlist", str(allow), "--allow-stale", "scripts")
    assert r2.returncode == 0, r2.stdout + r2.stderr


def test_cli_update_prunes_stale_and_records_current(tmp_path):
    allow = tmp_path / "allow.json"
    allow.write_text('{"src/gone.py:wall-clock": "stale"}\n')
    r = run_cli("--allowlist", str(allow), "--update", "scripts")
    assert r.returncode == 0, r.stdout + r.stderr
    assert load_json(str(allow)) == {}   # scripts/ is clean, stale pruned
