"""End-to-end behaviour tests for the paper's system:
train -> plan -> permute -> serve, on one reduced model."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.baselines import POWERINFER2
from repro.core.planner import build_plan, permute_ffn_params, \
    profile_activations
from repro.models.dense import make_model
from repro.serving.engine import ServeEngine
from repro.train.steps import make_train_step
from repro.optim.adamw import AdamW
from repro.data.pipeline import DataConfig, SyntheticTokens


def test_train_plan_serve_end_to_end():
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))

    # 1. a few training steps must reduce loss
    opt = AdamW(lr=2e-3)
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticTokens(DataConfig(cfg.vocab_size, 32, 4, seed=0))
    state = opt.init(params)
    losses = []
    for _ in range(15):
        b = data.batch()
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    # 2. offline planning from REAL activations of the trained model
    batches = [jax.random.randint(jax.random.key(i), (2, 32), 0,
                                  cfg.vocab_size) for i in range(2)]
    counts, n_tok = profile_activations(params, cfg, batches)
    plan = build_plan(cfg, (counts / n_tok).astype(np.float32))
    params = permute_ffn_params(params, plan.neuron_order)

    # 3. serve with offloading; tokens valid; pipeline hides I/O
    eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                      offload_ratio=0.5)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32)
    res = eng.generate(prompt, max_new=8, temperature=0.0)
    toks = res.tokens[res.tokens >= 0]
    assert toks.size == 16
    assert (toks < cfg.vocab_size).all()
    assert res.tokens_per_s > 0
