"""Property-based tests (hypothesis) for the cache, pipeline, planner,
MoE dispatch, and I/O model invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache import LRUSet, NeuronCache
from repro.core.io_model import UFS40, UFS31, HOST_DMA, with_core, \
    with_queue_contention
from repro.core.pipeline import make_decode_tasks, simulate_pipeline


# ------------------------------------------------------------ LRU/cache ----

@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=200),
       st.integers(1, 16))
def test_lru_capacity_never_exceeded(keys, cap):
    lru = LRUSet(cap)
    for k in keys:
        lru.admit(k)
        assert len(lru) <= cap


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10), min_size=2, max_size=100))
def test_lru_most_recent_always_present(keys):
    lru = LRUSet(3)
    for k in keys:
        lru.admit(k)
        assert k in lru


def test_lru_evicts_least_recently_used():
    lru = LRUSet(2)
    lru.admit(1)
    lru.admit(2)
    lru.touch(1)          # 2 is now LRU
    ev = lru.admit(3)
    assert ev == [2]
    assert 1 in lru and 3 in lru


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(32, 256), st.integers(1, 32))
def test_neuron_cache_hit_rate_bounds(layers, cap, reqs):
    nc = NeuronCache(layers, 1024, 16, capacity_neurons=cap,
                     bytes_per_neuron=128)
    rng = np.random.default_rng(0)
    for _ in range(reqs):
        ids = rng.integers(0, 1024, size=8)
        h, m = nc.lookup_cold(0, ids)
        nc.admit_cold(0, m)
        assert len(h) + len(m) == len(ids)
    assert 0.0 <= nc.stats.hit_rate <= 1.0
    assert nc.resident_neurons >= 0


def test_neuron_cache_repeat_requests_hit():
    nc = NeuronCache(1, 256, 16, capacity_neurons=64, bytes_per_neuron=1)
    ids = list(range(32))
    _, m1 = nc.lookup_cold(0, ids)
    nc.admit_cold(0, m1)
    h2, m2 = nc.lookup_cold(0, ids)
    assert m2 == [] and len(h2) == 32


# -------------------------------------------------------------- pipeline ----

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8),
       st.floats(0.0, 1.0), st.integers(1, 6))
def test_cluster_pipeline_never_slower_than_matrix(nm, nc, frac, workers):
    tasks = make_decode_tasks(nm, nc, frac, comp_time=1.0, io_time=1.5,
                              seed=3)
    rm = simulate_pipeline(tasks, n_compute=workers, policy="matrix")
    rc = simulate_pipeline(tasks, n_compute=workers, policy="cluster")
    assert rc.makespan <= rm.makespan + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 5), st.integers(1, 6), st.floats(0.0, 1.0))
def test_pipeline_lower_bounds(nm, nc, frac):
    tasks = make_decode_tasks(nm, nc, frac, comp_time=0.7, io_time=1.1,
                              seed=4)
    for pol in ("matrix", "cluster"):
        r = simulate_pipeline(tasks, n_compute=2, policy=pol)
        io_total = sum(t.io_time for t in tasks)
        comp_total = sum(t.comp_time for t in tasks)
        assert r.makespan >= io_total - 1e-9          # single I/O queue
        assert r.makespan >= comp_total / 2 - 1e-9    # 2 workers
        assert 0.0 <= r.compute_util <= 1.0 + 1e-9
        assert 0.0 <= r.io_fraction <= 1.0


def test_pipeline_all_cached_has_no_io():
    tasks = make_decode_tasks(4, 4, 1.0, comp_time=1.0, io_time=9.9)
    r = simulate_pipeline(tasks, n_compute=4, policy="cluster")
    assert r.io_busy == 0.0
    assert abs(r.makespan - 4.0) < 1e-9   # 16 tasks / 4 workers * 1s


# --------------------------------------------------------------- io model ----

def test_bandwidth_monotone_in_block_size():
    for model in (UFS40, UFS31, HOST_DMA):
        bws = [model.bandwidth(bs, random=True)
               for bs in (4096, 8192, 65536, 524288)]
        assert bws == sorted(bws)


def test_paper_table1_core_ordering():
    big = with_core(UFS40, "big").bandwidth(4096, True)
    mid = with_core(UFS40, "mid").bandwidth(4096, True)
    little = with_core(UFS40, "little").bandwidth(4096, True)
    assert big > mid > little
    assert abs(big / little - 1076.10 / 761.87) < 0.15


def test_queue_contention_degrades():
    one = with_queue_contention(UFS40, 1).bandwidth(4096, True)
    four = with_queue_contention(UFS40, 4).bandwidth(4096, True)
    assert four < one
    assert four / one >= 0.6    # paper: up to 40% degradation


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10_000_000), st.sampled_from([4096, 24576, 524288]))
def test_read_time_positive_and_monotone(nbytes, bs):
    t1 = UFS40.read_time(nbytes, bs, random=True)
    t2 = UFS40.read_time(nbytes * 2, bs, random=True)
    assert t1 > 0 and t2 >= t1


# ------------------------------------------------------------ moe dispatch ----

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(2, 8), st.integers(1, 4))
def test_moe_dispatch_invariants(T, E, k):
    import jax
    import jax.numpy as jnp
    from repro.models.moe import moe_dispatch
    k = min(k, E)
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.key(T * E + k), (T, E)), -1)
    C = max(1, (T * k) // E)
    tope, topv, slot, keep = moe_dispatch(gates, k, C)
    slot_np, keep_np = np.asarray(slot), np.asarray(keep)
    kept = slot_np[keep_np]
    assert len(set(kept.tolist())) == len(kept)        # no slot collisions
    assert (kept < E * C).all() and (kept >= 0).all()
    w = np.asarray(topv)
    assert (w >= -1e-6).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)  # renormalized
    # capacity respected per expert
    e_of_slot = kept // C
    counts = np.bincount(e_of_slot, minlength=E)
    assert (counts <= C).all()
