"""Quantization (paper §7.6): scheme error ordering + roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.quantize import (
    bundle_nbytes_int4, dequantize_groupwise_int4, dequantize_mixed,
    dequantize_per_channel_int4, quant_error, quantize_groupwise_int4,
    quantize_mixed, quantize_per_channel_int4)


@pytest.fixture
def w_outliers():
    """Weights with heavy outliers — the regime where QNN-style
    per-channel INT4 collapses (paper Table 7)."""
    key = jax.random.key(0)
    w = jax.random.normal(key, (64, 256)) * 0.02
    mask = jax.random.bernoulli(jax.random.key(1), 0.005, w.shape)
    return jnp.where(mask, w * 50.0, w)


def test_error_ordering_matches_paper(w_outliers):
    """group32 (llama.cpp) and mixed (PowerInfer-2) must both beat plain
    per-channel (QNN) on outlier-heavy weights."""
    e_group = quant_error(w_outliers, "group32", group=32)
    e_chan = quant_error(w_outliers, "per_channel")
    e_mixed = quant_error(w_outliers, "mixed", outlier_frac=0.01)
    assert e_group < e_chan
    assert e_mixed < e_chan
    assert e_mixed < 0.25


def test_groupwise_roundtrip_bounded():
    w = jax.random.normal(jax.random.key(2), (32, 128)) * 0.1
    deq = dequantize_groupwise_int4(quantize_groupwise_int4(w, 32))
    err = np.abs(np.asarray(deq - w))
    scale = np.abs(np.asarray(w)).reshape(32, 4, 32).max(-1) / 7.0
    assert (err.reshape(32, 4, 32) <= scale[..., None] * 0.5 + 1e-7).all()


def test_per_channel_int8_range():
    w = jax.random.normal(jax.random.key(3), (16, 64))
    q = quantize_per_channel_int4(w)
    assert q["q"].dtype == jnp.int8
    assert int(jnp.max(q["q"])) <= 7 and int(jnp.min(q["q"])) >= -8


def test_mixed_preserves_outliers_exactly_ish(w_outliers):
    qw = quantize_mixed(w_outliers, outlier_frac=0.01)
    deq = dequantize_mixed(qw)
    mask = np.asarray(qw["outlier_mask"])
    w = np.asarray(w_outliers)
    rel = np.abs(np.asarray(deq)[mask] - w[mask]) / (np.abs(w[mask]) + 1e-9)
    assert rel.max() < 0.002      # FP16-preserved outliers: <0.2% error


def test_bundle_bytes_matches_paper():
    """§4.4: 4-bit Gate-Up-Down bundle for d=4096 is ~7.5KB -> 8KB."""
    nb = bundle_nbytes_int4(4096, gated=True)
    assert nb == 8192


def test_int8_kv_cache_roundtrip():
    """Beyond-paper: int8 KV cache halves decode cache traffic with
    sub-1% roundtrip error and near-identical attention outputs."""
    from repro.quant.quantize import quantize_kv, dequantize_kv, \
        kv_quant_error
    from repro.models.attention import decode_attention
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.key(0), 3)
    B, T, KV, dh, H = 2, 64, 2, 32, 4
    k = jax.random.normal(ks[0], (B, T, KV, dh))
    v = jax.random.normal(ks[1], (B, T, KV, dh))
    assert kv_quant_error(k) < 0.01
    q = jax.random.normal(ks[2], (B, 1, H, dh))
    kv_pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
    pos = jnp.full((B,), T - 1, jnp.int32)
    ref = decode_attention(q, k, v, kv_pos, pos)
    kq = dequantize_kv(quantize_kv(k)).astype(k.dtype)
    vq = dequantize_kv(quantize_kv(v)).astype(v.dtype)
    out = decode_attention(q, kq, vq, kv_pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-3, rtol=5e-3)


def test_int8_kv_scale_shapes():
    from repro.quant.quantize import quantize_kv
    k = jax.random.normal(jax.random.key(1), (3, 8, 2, 16))
    qkv = quantize_kv(k)
    assert qkv["q"].shape == k.shape and qkv["q"].dtype.name == "int8"
    assert qkv["scale"].shape == (3, 8, 2, 1)


# ------------------------------------------- regression: edge cases ----

def test_groupwise_rejects_nondividing_group():
    """D % group != 0 must raise a clear ValueError, not an opaque
    reshape error."""
    w = jnp.ones((4, 100))
    with pytest.raises(ValueError, match="multiple of group=32"):
        quantize_groupwise_int4(w, 32)


def test_mixed_outlier_count_exact_under_ties():
    """Tied magnitudes must not inflate the outlier set past the
    priced budget: exactly k = size * frac entries are preserved."""
    w = jnp.full((16, 64), 0.5)               # every |w| tied
    qw = quantize_mixed(w, outlier_frac=0.01)
    k = max(1, int(w.size * 0.01))
    assert int(np.asarray(qw["outlier_mask"]).sum()) == k


def test_bf16_and_fp32_inputs_quantize_identically():
    """Schemes round an fp32 copy, so a bf16 view of the same weights
    yields the same codes (storage is what's being modeled, not the
    compute dtype the caller happens to hold)."""
    w = jax.random.normal(jax.random.key(5), (8, 64)) * 0.1
    wb = w.astype(jnp.bfloat16)
    q32 = quantize_per_channel_int4(wb.astype(jnp.float32))
    qb = quantize_per_channel_int4(wb)
    np.testing.assert_array_equal(np.asarray(q32["q"]), np.asarray(qb["q"]))
    g32 = quantize_groupwise_int4(wb.astype(jnp.float32), 32)
    gb = quantize_groupwise_int4(wb, 32)
    np.testing.assert_array_equal(np.asarray(g32["q"]), np.asarray(gb["q"]))


def test_all_zero_channel_roundtrips_to_zero():
    """A dead channel (all-zero row) must not produce NaNs/infs — the
    scale floor keeps the roundtrip exactly zero."""
    w = jnp.zeros((4, 64)).at[1].set(
        jax.random.normal(jax.random.key(6), (64,)))
    for deq in (dequantize_per_channel_int4(quantize_per_channel_int4(w)),
                dequantize_groupwise_int4(quantize_groupwise_int4(w, 32)),
                dequantize_mixed(quantize_mixed(w))):
        a = np.asarray(deq)
        assert np.isfinite(a).all()
        assert (a[0] == 0).all() and (a[2] == 0).all() and (a[3] == 0).all()


def test_bundle_nbytes_int4_alignment_parameter():
    """`align` is the storage read granularity: 0 returns the raw
    size, and the padded size is the next multiple of align."""
    raw = bundle_nbytes_int4(4096, gated=True, align=0)
    assert 0 < raw <= 8192
    assert bundle_nbytes_int4(4096, gated=True, align=4096) == 8192
    assert bundle_nbytes_int4(4096, gated=True, align=1) == raw
    # the outlier sidecar adds bytes before padding
    assert bundle_nbytes_int4(4096, align=0, outlier_frac=0.01) > raw


def test_bundle_nbytes_int4_monotonic_in_d_model():
    sizes = [bundle_nbytes_int4(d, align=0) for d in
             (256, 512, 1024, 2048, 4096, 8192)]
    assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)


def test_bundle_nbytes_dispatcher():
    """One accounting for the storage plane: fp16 is the legacy
    unpadded fp bytes; quantized dtypes pad to the read granularity;
    int4-mixed at d=4096 is the paper's 3x-smaller 8KB bundle."""
    from repro.quant.quantize import bundle_nbytes
    assert bundle_nbytes(4096, "fp16") == 3 * 4096 * 2
    assert bundle_nbytes(4096, "fp16", rows=2) == 2 * 4096 * 2
    assert bundle_nbytes(4096, "int4-mixed") == 8192
    assert bundle_nbytes(4096, "fp16") == 3 * bundle_nbytes(4096, "int4-mixed")
    i8 = bundle_nbytes(4096, "int8")
    assert i8 % 4096 == 0 and 3 * (4096 + 2) <= i8 < 3 * 4096 * 2
    with pytest.raises(ValueError, match="storage dtype"):
        bundle_nbytes(4096, "int2")


# Property tests live in tests/test_quant_properties.py behind a
# module-level `pytest.importorskip("hypothesis")` so this module's
# deterministic tests always run.
