"""Quantization (paper §7.6): scheme error ordering + roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.quantize import (
    bundle_nbytes_int4, dequantize_groupwise_int4, dequantize_mixed,
    quant_error, quantize_groupwise_int4,
    quantize_mixed, quantize_per_channel_int4)


@pytest.fixture
def w_outliers():
    """Weights with heavy outliers — the regime where QNN-style
    per-channel INT4 collapses (paper Table 7)."""
    key = jax.random.key(0)
    w = jax.random.normal(key, (64, 256)) * 0.02
    mask = jax.random.bernoulli(jax.random.key(1), 0.005, w.shape)
    return jnp.where(mask, w * 50.0, w)


def test_error_ordering_matches_paper(w_outliers):
    """group32 (llama.cpp) and mixed (PowerInfer-2) must both beat plain
    per-channel (QNN) on outlier-heavy weights."""
    e_group = quant_error(w_outliers, "group32", group=32)
    e_chan = quant_error(w_outliers, "per_channel")
    e_mixed = quant_error(w_outliers, "mixed", outlier_frac=0.01)
    assert e_group < e_chan
    assert e_mixed < e_chan
    assert e_mixed < 0.25


def test_groupwise_roundtrip_bounded():
    w = jax.random.normal(jax.random.key(2), (32, 128)) * 0.1
    deq = dequantize_groupwise_int4(quantize_groupwise_int4(w, 32))
    err = np.abs(np.asarray(deq - w))
    scale = np.abs(np.asarray(w)).reshape(32, 4, 32).max(-1) / 7.0
    assert (err.reshape(32, 4, 32) <= scale[..., None] * 0.5 + 1e-7).all()


def test_per_channel_int8_range():
    w = jax.random.normal(jax.random.key(3), (16, 64))
    q = quantize_per_channel_int4(w)
    assert q["q"].dtype == jnp.int8
    assert int(jnp.max(q["q"])) <= 7 and int(jnp.min(q["q"])) >= -8


def test_mixed_preserves_outliers_exactly_ish(w_outliers):
    qw = quantize_mixed(w_outliers, outlier_frac=0.01)
    deq = dequantize_mixed(qw)
    mask = np.asarray(qw["outlier_mask"])
    w = np.asarray(w_outliers)
    rel = np.abs(np.asarray(deq)[mask] - w[mask]) / (np.abs(w[mask]) + 1e-9)
    assert rel.max() < 0.002      # FP16-preserved outliers: <0.2% error


def test_bundle_bytes_matches_paper():
    """§4.4: 4-bit Gate-Up-Down bundle for d=4096 is ~7.5KB -> 8KB."""
    nb = bundle_nbytes_int4(4096, gated=True)
    assert nb == 8192


def test_int8_kv_cache_roundtrip():
    """Beyond-paper: int8 KV cache halves decode cache traffic with
    sub-1% roundtrip error and near-identical attention outputs."""
    from repro.quant.quantize import quantize_kv, dequantize_kv, \
        kv_quant_error
    from repro.models.attention import decode_attention
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.key(0), 3)
    B, T, KV, dh, H = 2, 64, 2, 32, 4
    k = jax.random.normal(ks[0], (B, T, KV, dh))
    v = jax.random.normal(ks[1], (B, T, KV, dh))
    assert kv_quant_error(k) < 0.01
    q = jax.random.normal(ks[2], (B, 1, H, dh))
    kv_pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
    pos = jnp.full((B,), T - 1, jnp.int32)
    ref = decode_attention(q, k, v, kv_pos, pos)
    kq = dequantize_kv(quantize_kv(k)).astype(k.dtype)
    vq = dequantize_kv(quantize_kv(v)).astype(v.dtype)
    out = decode_attention(q, kq, vq, kv_pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-3, rtol=5e-3)


def test_int8_kv_scale_shapes():
    from repro.quant.quantize import quantize_kv
    k = jax.random.normal(jax.random.key(1), (3, 8, 2, 16))
    qkv = quantize_kv(k)
    assert qkv["q"].shape == k.shape and qkv["q"].dtype.name == "int8"
    assert qkv["scale"].shape == (3, 8, 2, 1)
