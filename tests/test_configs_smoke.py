"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant (2 layers, d_model<=512, <=4 experts) and run one
forward pass + one train step + one decode step on CPU, asserting
output shapes and finiteness. Full configs are exercised only via the
dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= len(cfg.block_pattern) + 2 if cfg.block_pattern \
        else cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = tiny_batch(cfg, B, S, with_labels=True)

    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    S_out = S if cfg.family != "vlm" else S + cfg.num_image_tokens
    assert logits.shape == (B, S_out, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/Inf in logits"

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    params2, _, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = tiny_batch(cfg, B, S)
    T = S + 4 + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    lg, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=T))(
        params, batch)
    assert lg.shape == (B, 1, cfg.vocab_padded)
    tok = batch["tokens"][:, -1:]
    lg2, cache2 = jax.jit(model.decode_step)(params, jnp.asarray(tok), cache)
    assert lg2.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(lg2).all())
    S_cache = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert int(cache2["length"][0]) == S_cache + 1
