"""MoE dispatch properties (satellite of the EP serving tentpole):
capacity-overflow drops are deterministic FIFO-in-token-order (ties in
gate scores included), `_combine_group` exactly inverts
`_dispatch_group` for kept tokens, `_capacity` never returns 0, and
the active-mask contract — dead rows neither consume capacity nor
perturb live rows' slots (the KV-arena zombie-lane guarantee the
serving engine relies on)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import (_capacity, _combine_group, _dispatch_group,
                              moe_dispatch)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # property still checked below
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------- _capacity ----

def _check_capacity(T, k, E, factor):
    c = _capacity(T, k, E, factor)
    assert c >= 1, (T, k, E, factor)
    assert c % 8 == 0                     # MXU-aligned slots
    assert c >= min(8, T * k)             # floor holds even when tiny


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 4096), st.integers(1, 16), st.integers(1, 128),
           st.floats(0.01, 8.0))
    def test_capacity_never_zero_for_k_ge_1(T, k, E, factor):
        _check_capacity(T, k, E, factor)
else:
    def test_capacity_never_zero_for_k_ge_1():
        rng = np.random.default_rng(0)
        for _ in range(300):
            _check_capacity(int(rng.integers(1, 4097)),
                            int(rng.integers(1, 17)),
                            int(rng.integers(1, 129)),
                            float(rng.uniform(0.01, 8.0)))
        for corner in ((1, 1, 128, 0.01), (1, 1, 1, 8.0),
                       (4096, 16, 1, 0.01)):
            _check_capacity(*corner)


# --------------------------------------------- deterministic drops ----

def test_overflow_drops_deterministic_under_tied_gates():
    """All tokens tie on every expert: capacity ranking must fall back
    to token order (stable argsort), so exactly the first C tokens per
    expert are kept — bit-identical across runs and under jit."""
    T, E, k, C = 12, 4, 2, 2
    gates = jnp.full((T, E), 1.0 / E)     # fully tied scores
    outs = [moe_dispatch(gates, k, C),
            moe_dispatch(gates, k, C),
            jax.jit(lambda g: moe_dispatch(g, k, C))(gates)]
    for a, b in zip(outs, outs[1:]):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    tope, topv, slot, keep = outs[0]
    tope, slot, keep = map(np.asarray, (tope, slot, keep))
    # tied gates -> top_k picks the lowest expert ids for every token,
    # and FIFO capacity keeps the earliest tokens per expert
    for e in range(E):
        kept_tokens = sorted(t for t in range(T)
                             for i in range(k)
                             if tope[t, i] == e and keep[t, i])
        routed_tokens = sorted(t for t in range(T)
                               for i in range(k) if tope[t, i] == e)
        assert kept_tokens == routed_tokens[:C]
    # kept slots are collision-free and within the (E*C) buffer
    kept = slot[keep]
    assert len(set(kept.tolist())) == len(kept)
    assert kept.max(initial=0) < E * C


def test_drop_count_is_exactly_overflow():
    """Kept entries per expert == min(routed, C); everything else is
    dropped — no silent extra drops, no capacity overrun."""
    T, E, k, C = 32, 4, 2, 3
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.key(0), (T, E)), -1)
    tope, _, slot, keep = moe_dispatch(gates, k, C)
    tope, keep = np.asarray(tope), np.asarray(keep)
    routed = np.bincount(tope.reshape(-1), minlength=E)
    kept = np.bincount(tope.reshape(-1), weights=keep.reshape(-1),
                       minlength=E).astype(int)
    np.testing.assert_array_equal(kept, np.minimum(routed, C))


# ------------------------------------------- dispatch <-> combine ----

def test_combine_inverts_dispatch_for_kept_tokens():
    """With ample capacity every (token, expert) entry lands in its
    own slot; feeding the dispatch buffer straight back through the
    combine must reconstruct each token exactly (combine weights are
    renormalized to sum to 1), i.e. combine o dispatch == identity on
    kept tokens."""
    cfg = get_config("deepseek-moe-16b").reduced().replace(
        moe_capacity_factor=8.0)
    T, D = 6, cfg.d_model
    C = _capacity(T, cfg.experts_per_token, cfg.num_experts,
                  cfg.moe_capacity_factor)
    x = jax.random.normal(jax.random.key(1), (T, D))
    router = jax.random.normal(jax.random.key(2),
                               (D, cfg.num_experts)) * 0.1
    buf, (slot, keep, topv), aux, counts = _dispatch_group(
        x, router, cfg, C)
    assert bool(np.asarray(keep).all())   # ample capacity: no drops
    y = _combine_group(buf.reshape(-1, D), slot, keep, topv)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               atol=1e-5, rtol=1e-5)
    # the trace counts exactly the kept entries
    assert int(np.asarray(counts).sum()) == T * cfg.experts_per_token


def test_dropped_tokens_combine_to_zero():
    """A dropped (token, expert) entry contributes nothing: with
    capacity 0-ish (floor C, all slots contested) the combine output
    for fully-dropped tokens is exactly zero."""
    cfg = get_config("deepseek-moe-16b").reduced()
    T, D = 24, cfg.d_model
    C = 1                                  # starve capacity directly
    x = jnp.ones((T, D))
    router = jnp.zeros((D, cfg.num_experts))   # uniform tied gates
    buf, (slot, keep, topv), _, counts = _dispatch_group(
        x, router, cfg, C)
    keep_np = np.asarray(keep)
    y = np.asarray(_combine_group(buf.reshape(-1, D), slot, keep, topv))
    dropped_rows = ~keep_np.any(axis=1)
    assert dropped_rows.any()              # capacity actually starved
    np.testing.assert_array_equal(y[dropped_rows], 0.0)
    assert int(np.asarray(counts).sum()) == int(keep_np.sum())


# ----------------------------------------------------- active mask ----

def test_dead_rows_never_consume_capacity():
    """The serving zombie-lane contract: dispatching (T live + T dead)
    rows with an active mask must keep/slot the live rows exactly as
    dispatching the live rows alone — dead lanes can neither evict a
    live token past capacity nor shift its buffer slot."""
    E, k, C = 4, 2, 2
    rng = jax.random.key(3)
    live = jax.nn.softmax(jax.random.normal(rng, (8, E)), -1)
    dead = jax.nn.softmax(
        jax.random.normal(jax.random.key(4), (8, E)) * 3.0, -1)
    # interleave live/dead rows so dead rows sit *before* live ones
    gates = jnp.stack([dead, live], 1).reshape(16, E)
    active = jnp.tile(jnp.array([False, True]), 8)
    tope_m, topv_m, slot_m, keep_m = moe_dispatch(gates, k, C, active)
    tope_l, topv_l, slot_l, keep_l = moe_dispatch(live, k, C)
    rows = np.arange(1, 16, 2)             # the live rows
    np.testing.assert_array_equal(np.asarray(keep_m)[rows],
                                  np.asarray(keep_l))
    np.testing.assert_array_equal(np.asarray(slot_m)[rows],
                                  np.asarray(slot_l))
    np.testing.assert_array_equal(np.asarray(tope_m)[rows],
                                  np.asarray(tope_l))
    # dead rows are fully dropped
    assert not np.asarray(keep_m)[::2].any()
