"""Substrate: optimizer, data pipeline, checkpointing, train loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim.adamw import AdamW


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_bf16_moments_dtype():
    opt = AdamW(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4))}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    p2, st2 = opt.update(g, st, params)
    assert st2["m"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == params["w"].dtype


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    st = opt.init(params)
    huge = {"w": jnp.array([1e9, -1e9])}
    p2, _ = opt.update(huge, st, params)
    assert bool(jnp.isfinite(p2["w"]).all())


def test_data_pipeline_deterministic():
    cfg = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=7)
    b1 = SyntheticTokens(cfg).batch()
    b2 = SyntheticTokens(cfg).batch()
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # labels are next-token shifted from the same stream
    assert (b1["tokens"] < 128).all() and (b1["tokens"] >= 0).all()


def test_data_pipeline_zipf_skew():
    cfg = DataConfig(vocab_size=1000, seq_len=256, batch_size=16, seed=0)
    toks = SyntheticTokens(cfg).batch()["tokens"].reshape(-1)
    counts = np.bincount(toks, minlength=1000)
    top10 = counts[np.argsort(-counts)[:10]].sum()
    assert top10 / counts.sum() > 0.3     # heavy head, like real text


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    restored, step = restore_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_loss_decreases():
    from repro.launch.train import train
    _, losses = train("smollm-135m", steps=30, batch_size=4, seq_len=32,
                      reduced=True, lr=2e-3, log_every=0)
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
