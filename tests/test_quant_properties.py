"""Property tests for the quantization schemes (paper §7.6) and the
bundle byte accounting (§4.4).

Hypothesis is an optional dev dependency: the module-level
importorskip keeps the whole file out of environments without it —
the deterministic regression tests stay in tests/test_quant.py.
"""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant.quantize import (  # noqa: E402
    bundle_nbytes, dequantize_groupwise_int4, dequantize_kv,
    dequantize_per_channel_int4, quant_error, quantize_groupwise_int4,
    quantize_kv, quantize_per_channel_int4)


def _weights(draw, rows, cols, scale):
    data = draw(st.lists(
        st.floats(-1.0, 1.0, allow_nan=False, width=32),
        min_size=rows * cols, max_size=rows * cols))
    return jnp.asarray(np.array(data, np.float32).reshape(rows, cols)) * scale


@settings(max_examples=20, deadline=None)
@given(st.data(), st.sampled_from([32, 64]),
       st.floats(0.01, 10.0, allow_nan=False))
def test_groupwise_roundtrip_error_bounded(data, group, scale):
    """|deq - w| <= scale/2 + eps elementwise, any magnitude regime."""
    w = _weights(data.draw, 8, 2 * group, scale)
    deq = dequantize_groupwise_int4(quantize_groupwise_int4(w, group))
    wg = np.asarray(w).reshape(8, (2 * group) // group, group)
    s = np.abs(wg).max(-1) / 7.0
    err = np.abs(np.asarray(deq) - np.asarray(w)).reshape(wg.shape)
    assert (err <= s[..., None] * 0.5 + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(st.data(), st.floats(0.01, 10.0, allow_nan=False))
def test_per_channel_roundtrip_error_bounded(data, scale):
    w = _weights(data.draw, 8, 64, scale)
    deq = dequantize_per_channel_int4(quantize_per_channel_int4(w))
    s = np.abs(np.asarray(w)).max(-1) / 7.0
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert (err <= s[:, None] * 0.5 + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(st.data(), st.sampled_from([0.01, 0.05]))
def test_mixed_roundtrip_never_worse_than_per_channel(data, frac):
    """The hybrid scheme's whole point (Table 7): removing outliers
    before scaling can only shrink per-channel scales."""
    w = _weights(data.draw, 8, 64, 1.0)
    e_mixed = quant_error(w, "mixed", outlier_frac=frac)
    e_chan = quant_error(w, "per_channel")
    assert e_mixed <= e_chan + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.data(), st.floats(0.01, 10.0, allow_nan=False))
def test_kv_roundtrip_error_bounded(data, scale):
    kv = _weights(data.draw, 6, 32, scale).reshape(3, 2, 1, 32)
    deq = dequantize_kv(quantize_kv(kv))
    s = np.abs(np.asarray(kv)).max(-1) / 127.0
    err = np.abs(np.asarray(deq) - np.asarray(kv))
    assert (err <= s[..., None] * 0.5 + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(0, 2))
def test_bundle_nbytes_monotone_and_aligned(d32, dt_idx):
    """Bundle bytes are monotone in d_model and respect alignment for
    every storage dtype."""
    dt = ("fp16", "int8", "int4-mixed")[dt_idx]
    d = d32 * 32
    a, b = bundle_nbytes(d, dt), bundle_nbytes(d + 32, dt)
    assert a <= b
    if dt != "fp16":
        assert a % 4096 == 0
