"""Recurrence cores: SSD chunked==sequential; RG-LRU scan==step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.ssm import causal_conv, segsum, ssd_chunked, ssd_step
from repro.models.rglru import rglru_full, rglru_step


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([16, 32, 64]),
       st.integers(1, 4), st.sampled_from([4, 8]), st.sampled_from([8, 16]))
def test_ssd_chunked_equals_sequential(b, s, h, p, n):
    ks = jax.random.split(jax.random.key(s * h + p), 4)
    X = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    A = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    B = jax.random.normal(ks[2], (b, s, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Y, fs = ssd_chunked(X, A, B, C, chunk=16 if s >= 16 else s)
    st_ = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        st_ = st_ * jnp.exp(A[:, t])[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", X[:, t], B[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", st_, C[:, t]))
    Yref = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(Y), np.asarray(Yref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(st_),
                               atol=1e-4, rtol=1e-3)


def test_ssd_chunk_size_invariance():
    b, s, h, p, n = 2, 64, 2, 8, 16
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    A = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    B = jax.random.normal(ks[2], (b, s, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Y16, f16 = ssd_chunked(X, A, B, C, 16)
    Y64, f64 = ssd_chunked(X, A, B, C, 64)
    np.testing.assert_allclose(np.asarray(Y16), np.asarray(Y64),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f16), np.asarray(f64),
                               atol=1e-4, rtol=1e-3)


def test_ssd_step_continues_chunked():
    """State from a chunked prefill must continue exactly via steps."""
    b, s, h, p, n = 1, 32, 2, 8, 16
    ks = jax.random.split(jax.random.key(1), 4)
    X = jax.random.normal(ks[0], (b, s + 4, h, p)) * 0.5
    A = -jnp.abs(jax.random.normal(ks[1], (b, s + 4, h))) * 0.3
    B = jax.random.normal(ks[2], (b, s + 4, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s + 4, n)) * 0.5
    Yfull, _ = ssd_chunked(X, A, B, C, chunk=36 if False else 4)
    _, state = ssd_chunked(X[:, :s], A[:, :s], B[:, :s], C[:, :s], 16)
    outs = []
    for t in range(s, s + 4):
        # ssd_step applies dt inside dBx; here X is already dt-scaled so
        # pass dt=1 and x=X
        state, y = ssd_step(state, X[:, t], A[:, t],
                            jnp.ones((b, h)), B[:, t], C[:, t])
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(Yfull[:, s:]), atol=1e-4,
                               rtol=1e-3)


def test_segsum_lower_triangular():
    x = jnp.ones((4,))
    ss = segsum(x)
    assert ss.shape == (4, 4)
    assert np.isneginf(np.asarray(ss)[0, 1])
    np.testing.assert_allclose(np.asarray(ss)[3, 0], 3.0)
    np.testing.assert_allclose(np.diag(np.asarray(ss)), 0.0)


def test_causal_conv_matches_tail_streaming():
    B, S, C, W = 2, 16, 8, 4
    x = jax.random.normal(jax.random.key(2), (B, S, C))
    w = jax.random.normal(jax.random.key(3), (W, C)) * 0.3
    bias = jnp.zeros((C,))
    y_full, tail = causal_conv(x, w, bias)
    # stream in two halves
    y1, t1 = causal_conv(x[:, :8], w, bias)
    y2, _ = causal_conv(x[:, 8:], w, bias, t1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 32]), st.sampled_from([16, 64]))
def test_rglru_scan_equals_step(b, s, d):
    p = {"w_r": jnp.full((d,), 0.5), "b_r": jnp.zeros((d,)),
         "w_i": jnp.full((d,), 0.5), "b_i": jnp.zeros((d,)),
         "lam": jnp.full((d,), 0.7)}

    class Cfg:
        rglru_c = 8.0

    x = jax.random.normal(jax.random.key(b + s), (b, s, d)) * 0.5
    y_full, h_final = rglru_full(p, x, Cfg)
    h = jnp.zeros((b, d))
    ys = []
    for t in range(s):
        y, h = rglru_step(p, x[:, t], Cfg, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_final),
                               atol=1e-4, rtol=1e-3)


def test_rglru_stability():
    """|a_t| < 1 by construction: long sequences must not blow up."""
    d = 32
    p = {"w_r": jnp.ones((d,)), "b_r": jnp.zeros((d,)),
         "w_i": jnp.ones((d,)), "b_i": jnp.zeros((d,)),
         "lam": jnp.full((d,), 0.7)}

    class Cfg:
        rglru_c = 8.0

    x = jax.random.normal(jax.random.key(9), (1, 2048, d))
    y, h = rglru_full(p, x, Cfg)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y).max()) < 100.0
