"""Unit tests for scripts/_ratchet.py — the baseline JSON I/O and
new/stale split shared by the repo's three ratchet gates — plus the
allowlist --update flow end to end through the repro-analyze CLI."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CLI = os.path.join(REPO, "scripts", "repro_analyze.py")

sys.path.insert(0, os.path.join(REPO, "scripts"))
from _ratchet import diff_ratchet, dump_json, load_json  # noqa: E402


def run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run([sys.executable, CLI, *args], cwd=REPO,
                          env=env, capture_output=True, text=True)


# ------------------------------------------------------- load_json ----

def test_load_json_missing_returns_default(tmp_path):
    assert load_json(str(tmp_path / "absent.json"), default={}) == {}
    assert load_json(str(tmp_path / "absent.json"), default=None) is None


def test_load_json_missing_without_default_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_json(str(tmp_path / "absent.json"))


def test_load_json_reads_what_dump_wrote(tmp_path):
    p = str(tmp_path / "b.json")
    dump_json(p, {"k": [1, 2], "a": "x"})
    assert load_json(p) == {"k": [1, 2], "a": "x"}


# ------------------------------------------------------- dump_json ----

def test_dump_json_canonical_format(tmp_path):
    p = str(tmp_path / "b.json")
    dump_json(p, {"z": 1, "a": 2})
    text = open(p).read()
    assert text.endswith("\n")                     # trailing newline
    assert text == json.dumps({"z": 1, "a": 2}, indent=1,
                              sort_keys=True) + "\n"
    assert text.index('"a"') < text.index('"z"')   # sorted keys


def test_dump_json_rewrite_is_byte_stable(tmp_path):
    p = str(tmp_path / "b.json")
    dump_json(p, {"b": 1, "a": {"y": 2, "x": 3}})
    first = open(p, "rb").read()
    dump_json(p, load_json(p))                     # round-trip rewrite
    assert open(p, "rb").read() == first


# ---------------------------------------------------- diff_ratchet ----

def test_diff_ratchet_new_and_stale():
    new, stale = diff_ratchet({"a", "b", "c"}, {"b", "d"})
    assert new == ["a", "c"]
    assert stale == ["d"]


def test_diff_ratchet_empty_baseline():
    new, stale = diff_ratchet(["x"], [])
    assert (new, stale) == (["x"], [])
    assert diff_ratchet([], []) == ([], [])


def test_diff_ratchet_identical_sets_are_quiet():
    assert diff_ratchet({"a", "b"}, ["a", "b"]) == ([], [])


# -------------------------------------- allowlist flow via the CLI ----

def test_empty_allowlist_gate_is_clean(tmp_path):
    """A missing allowlist means an empty baseline — the committed
    tree must gate clean against it (the repo carries no debt)."""
    r = run_cli("--allowlist", str(tmp_path / "allow.json"))
    assert r.returncode == 0, r.stdout + r.stderr


def test_stale_entry_fails_then_update_prunes(tmp_path):
    allow = tmp_path / "allow.json"
    dump_json(str(allow), {"src/repro/gone.py:wall-clock": "obsolete"})
    r = run_cli("--allowlist", str(allow))
    assert r.returncode == 1
    assert "stale" in r.stdout

    r = run_cli("--allowlist", str(allow), "--update")
    assert r.returncode == 0, r.stdout + r.stderr
    assert load_json(str(allow)) == {}             # pruned to empty

    assert run_cli("--allowlist", str(allow)).returncode == 0


def test_update_is_idempotent(tmp_path):
    allow = tmp_path / "allow.json"
    run_cli("--allowlist", str(allow), "--update")
    first = open(allow, "rb").read()
    run_cli("--allowlist", str(allow), "--update")
    assert open(allow, "rb").read() == first
