"""Distribution tests in a subprocess with 8 forced host devices
(device count locks at first jax init, so the main test process stays
single-device). Mesh/axis-type/shard_map API drift is absorbed by
repro.compat, so these run on every supported jax."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, timeout=420, ndev=8):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=%d"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh
    """ % ndev) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_shard_map_cold_path_matches_local_8dev(backend):
    """The shard-local cold path must reproduce the single-device jnp
    math — output within tolerance, selected cluster ids identical —
    for every mesh whose 'model' size divides the plan's groups, under
    both cold-path backends (pallas = the fused kernel, interpret mode,
    running inside the shard_map body — DESIGN.md §10)."""
    out = run_in_subprocess("""
        import dataclasses
        from repro.core.sparse_ffn import init_ffn, ffn_hybrid
        from repro.core.clusters import HybridPlan
        D, N, cs, G = 64, 512, 32, 4
        params = init_ffn(jax.random.key(0), D, N, "relu2", jnp.float32,
                          predictor_rank=16)
        x = jax.random.normal(jax.random.key(1), (2, D)) * 0.5
        plan = HybridPlan(n_hot=128, k_cold=64, groups=G, cluster_size=cs)
        # reference is always the single-device jnp chain
        y_local, cidx_local = ffn_hybrid(params, x, "relu2", "relu", plan,
                                         return_indices=True)
        plan = dataclasses.replace(plan, backend=%r)
        for nd, nm in ((2, 4), (2, 2), (1, 4)):
            mesh = make_mesh((nd, nm), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2,
                             devices=jax.devices()[:nd * nm])
            with set_mesh(mesh):
                spec = {"w": NamedSharding(mesh, P("model", None, None)),
                        "pred": {"A": NamedSharding(mesh, P(None, None)),
                                 "B": NamedSharding(mesh, P(None, "model"))}}
                ps = jax.tree.map(jax.device_put, params, spec)
                y_sm, cidx = jax.jit(lambda p, xx: ffn_hybrid(
                    p, xx, "relu2", "relu", plan,
                    return_indices=True))(ps, x)
            np.testing.assert_allclose(np.asarray(y_sm),
                                       np.asarray(y_local),
                                       atol=1e-3, rtol=1e-3)
            np.testing.assert_array_equal(np.asarray(cidx),
                                          np.asarray(cidx_local))
        print("OK shard_map")
    """ % backend)
    assert "OK shard_map" in out


def test_sharded_train_step_matches_single_device():
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.input_specs import param_specs

        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        params = model.init(jax.random.key(0))
        state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32),
                 "labels": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)}
        step = make_train_step(model, opt)
        _, _, m1 = jax.jit(step)(params, state, batch)

        mesh = make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            specs = param_specs(model, cfg, mesh)
            ps = jax.tree.map(lambda a, s: jax.device_put(a, s.sharding),
                              params, specs)
            ss = opt.init(ps)
            b = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                 for k, v in batch.items()}
            _, _, m2 = jax.jit(step)(ps, ss, b)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   atol=1e-3, rtol=1e-4)
        print("OK sharded train", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "OK sharded train" in out


def test_sharded_moe_forward_matches_single_device():
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.launch.input_specs import param_specs

        cfg = get_config("deepseek-moe-16b").reduced().replace(
            moe_capacity_factor=8.0, moe_dispatch_groups=2)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                        (4, 32)).astype(np.int32)}
        y1 = jax.jit(lambda p, b: model.forward(p, b))(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        with set_mesh(mesh):
            specs = param_specs(model, cfg, mesh)
            ps = jax.tree.map(lambda a, s: jax.device_put(a, s.sharding),
                              params, specs)
            b = {"tokens": jax.device_put(
                batch["tokens"], NamedSharding(mesh, P("data", None)))}
            y2 = jax.jit(lambda p, bb: model.forward(p, bb))(ps, b)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=2e-3, rtol=2e-3)
        print("OK sharded moe")
    """)
    assert "OK sharded moe" in out


def test_tensor_parallel_decode_token_identical_4dev():
    """The tentpole guarantee (golden comparison): the serving engine
    over a forced 4-host-device mesh decodes token-for-token what the
    single-device engine decodes — same grouped plan, same sampling-key
    sequence, cluster selection shard-local — while the storage plane
    reports per-shard accounting."""
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.core.planner import build_plan, permute_ffn_params
        from repro.core.clusters import make_plan, scale_plan_for_batch
        from repro.data.pipeline import DataConfig, SyntheticTokens
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.engine import ServeEngine

        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        # brief training: real logit margins so greedy decode is
        # robust to the mesh's fp reassociation noise (~1e-5)
        opt = AdamW(lr=2e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        state = opt.init(params)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=0))
        for _ in range(30):
            params, state, _ = step(params, state, data.batch())

        plan = build_plan(cfg)
        base = make_plan(cfg.d_ff, 0.25, 0.25, cfg.sparse_ffn.cluster_size,
                         groups=4)
        plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b,
                                              cfg.sparse_ffn.cluster_size)
                      for b in (1, 2, 4, 8)}
        params = permute_ffn_params(params, plan.neuron_order)

        def run(mesh, backend=None):
            eng = ServeEngine(cfg, params, plan, buckets=(1, 2, 4),
                              ctx_budget=48, temperature=0.0, seed=0,
                              mesh=mesh, backend=backend)
            rng = np.random.default_rng(0)
            for i in range(3):
                eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=8,
                           arrival_time=i * 1e-3)
            rep = eng.run_until_drained()
            toks = {u: list(r.generated)
                    for u, r in eng.sched.sequences.items()}
            eng.close()
            return rep, toks

        rep1, toks1 = run(None)
        rep4, toks4 = run(make_serving_mesh(4))
        assert toks1 == toks4, (toks1, toks4)
        assert all(len(t) == 8 for t in toks1.values())
        # the fused pallas cold path (DESIGN.md §10) decodes the same
        # tokens as jnp, single-device and under the tp=4 mesh
        _, toksp1 = run(None, backend="pallas")
        assert toksp1 == toks1, (toksp1, toks1)
        _, toksp4 = run(make_serving_mesh(4), backend="pallas")
        assert toksp4 == toks1, (toksp4, toks1)
        s1, s4 = rep1.stats[0], rep4.stats[0]
        assert s1.n_shards == 1 and s1.shards is None
        assert s4.n_shards == 4 and len(s4.shards) == 4
        # per-shard raw I/O demand shrinks vs the single-device plane
        assert s4.io_s <= s1.io_s + 1e-12
        assert abs(s4.io_total_s
                   - sum(sh.io_s for sh in s4.shards)) < 1e-12
        # modeled per-step time must not regress under the mesh split
        e1 = sum(s.effective_s for s in rep1.stats)
        e4 = sum(s.effective_s for s in rep4.stats)
        assert e4 <= e1 * 1.01, (e1, e4)
        print("OK tp golden", len(rep4.stats), round(e1 / e4, 3))
    """, ndev=4)
    assert "OK tp golden" in out


def test_expert_parallel_moe_decode_token_identical_4dev():
    """The EP tentpole golden: a MoE engine over a forced-host-device
    mesh — experts sharded E/n per 'model' shard, dispatch/combine
    shard-local with one psum per layer (_moe_ep_shard_map) — decodes
    token-for-token what the single-device engine decodes, at ep=2 and
    composed dp=2 x ep=2; the storage plane reports per-shard expert
    slices whose raw I/O demand never exceeds the single-device
    plane's."""
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.core.planner import build_moe_plan
        from repro.data.pipeline import DataConfig, SyntheticTokens
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.engine import ServeEngine

        cfg = get_config("deepseek-moe-16b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        # brief training: real logit margins so greedy decode is
        # robust to the mesh's fp reassociation noise (~1e-5)
        opt = AdamW(lr=2e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        state = opt.init(params)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=0))
        for _ in range(20):
            params, state, _ = step(params, state, data.batch())
        plan = build_moe_plan(cfg)

        def run(mesh):
            eng = ServeEngine(cfg, params, plan, buckets=(1, 2),
                              ctx_budget=48, temperature=0.0, seed=0,
                              mesh=mesh)
            rng = np.random.default_rng(0)
            for i in range(3):
                eng.submit(rng.integers(0, cfg.vocab_size, 16), max_new=6,
                           arrival_time=i * 1e-3)
            rep = eng.run_until_drained()
            toks = {u: list(r.generated)
                    for u, r in eng.sched.sequences.items()}
            eng.close()
            return rep, toks

        rep1, toks1 = run(None)
        rep2, toks2 = run(make_serving_mesh(2))
        assert toks1 == toks2, (toks1, toks2)
        assert all(len(t) == 6 for t in toks1.values())
        s1, s2 = rep1.stats[0], rep2.stats[0]
        assert s1.n_shards == 1 and s1.shards is None
        assert s2.n_shards == 2 and len(s2.shards) == 2
        # per-shard raw I/O demand (the shard's expert slice) shrinks
        assert s2.io_s <= s1.io_s + 1e-12
        assert abs(s2.io_total_s
                   - sum(sh.io_s for sh in s2.shards)) < 1e-12

        # dp=2 x ep=2 over a (2, 2) mesh: replica routing composes
        # with expert parallelism without changing a single token
        # (per-request greedy decode is batch-composition-free)
        repg, toksg = run(make_serving_mesh(2, 2))
        assert toksg == toks1, (toksg, toks1)
        assert all(s.n_shards == 2 and len(s.shards) == 2
                   for s in repg.stats)
        assert {s.replica for s in repg.stats} == {0, 1}
        print("OK ep golden", len(rep2.stats))
    """, ndev=4, timeout=600)
    assert "OK ep golden" in out


def test_intra_expert_moe_decode_token_identical_4dev():
    """The two-level golden (DESIGN.md §9): intra-expert decode —
    per-expert hot/cold clusters, per-expert hot-first permutation,
    (L, E, 1+ncc) trace — is token-identical to the dense-expert
    decode at ep=1 AND over a 2-shard expert-parallel mesh (the
    per-expert cold gathers stay shard-local; the trace blocks
    all_gather in expert order), while per-shard raw I/O demand
    shrinks vs the single-device plane."""
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig, SyntheticTokens
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.engine import ServeEngine
        from repro.serving.families import serving_family

        cfg = get_config("turbosparse-mixtral-47b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        # brief training: real logit margins so greedy decode is
        # robust to the permutation's fp reassociation noise (~1e-5)
        opt = AdamW(lr=2e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        state = opt.init(params)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=0))
        for _ in range(20):
            params, state, _ = step(params, state, data.batch())

        fam = serving_family(cfg)
        plan = fam.build_plan(cfg)
        assert all(p.n_expert_hot > 0 for p in plan.plans.values())
        p_intra = fam.prepare_params(params, plan)
        cfgw = cfg.replace(moe_intra_expert=False)
        planw = serving_family(cfgw).build_plan(cfgw)

        def run(c, pp, pl, mesh):
            eng = ServeEngine(c, pp, pl, buckets=(1, 2), ctx_budget=48,
                              temperature=0.0, seed=0, mesh=mesh)
            rng = np.random.default_rng(0)
            for i in range(3):
                eng.submit(rng.integers(0, c.vocab_size, 16), max_new=6,
                           arrival_time=i * 1e-3)
            rep = eng.run_until_drained()
            toks = {u: list(r.generated)
                    for u, r in eng.sched.sequences.items()}
            eng.close()
            return rep, toks

        # dense-expert reference (whole-expert plan, unpermuted params)
        _, toks_ref = run(cfgw, params, planw, None)
        rep1, toks1 = run(cfg, p_intra, plan, None)
        assert toks1 == toks_ref, (toks1, toks_ref)
        rep2, toks2 = run(cfg, p_intra, plan, make_serving_mesh(2))
        assert toks2 == toks_ref, (toks2, toks_ref)
        assert all(len(t) == 6 for t in toks1.values())
        s1, s2 = rep1.stats[0], rep2.stats[0]
        assert s1.n_shards == 1 and s1.shards is None
        assert s2.n_shards == 2 and len(s2.shards) == 2
        assert s2.io_s <= s1.io_s + 1e-12
        assert abs(s2.io_total_s
                   - sum(sh.io_s for sh in s2.shards)) < 1e-12
        print("OK two-level ep golden", len(rep2.stats))
    """, ndev=4, timeout=600)
    assert "OK two-level ep golden" in out


def test_data_parallel_replica_routing_token_identical_4dev():
    """The dp tentpole golden: over a (2, 1) mesh the engine routes
    the seeded arrival trace across two replicas and decodes
    token-identical to two independent dp=1 engines fed the routed
    sub-streams; over a (2, 2) mesh each replica additionally
    tensor-shards on its own mesh row, leaving tokens unchanged while
    the merged report shows per-replica per-shard accounting and a
    shared-timeline span that beats the single-replica drain."""
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.core.planner import build_plan, permute_ffn_params
        from repro.core.clusters import make_plan, scale_plan_for_batch
        from repro.data.pipeline import DataConfig, SyntheticTokens
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.engine import ServeEngine

        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        # brief training: real logit margins so greedy decode is
        # robust to the mesh's fp reassociation noise (~1e-5)
        opt = AdamW(lr=2e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        state = opt.init(params)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 4, seed=0))
        for _ in range(30):
            params, state, _ = step(params, state, data.batch())

        plan = build_plan(cfg)
        base = make_plan(cfg.d_ff, 0.25, 0.25, cfg.sparse_ffn.cluster_size,
                         groups=2)
        plan.plans = {b: scale_plan_for_batch(base, cfg.d_ff, b,
                                              cfg.sparse_ffn.cluster_size)
                      for b in (1, 2, 4, 8)}
        params = permute_ffn_params(params, plan.neuron_order)

        # near-simultaneous arrivals: the stream overlaps, so replica
        # concurrency actually shortens the drained span (with spaced
        # arrivals each request drains before the next one lands and
        # dp buys nothing on this tiny modeled workload)
        rng = np.random.default_rng(0)
        reqs = [(rng.integers(0, cfg.vocab_size, 16),
                 6, i * 1e-6) for i in range(4)]

        def make(mesh=None, dp=None, backend=None):
            return ServeEngine(cfg, params, plan, buckets=(1, 2),
                               ctx_budget=48, temperature=0.0, seed=0,
                               mesh=mesh, dp=dp, backend=backend)

        def serve(eng, stream):
            uids = [eng.submit(p, m, arrival_time=t) for p, m, t in stream]
            rep = eng.run_until_drained()
            toks = {u: list(eng.sched.sequences[u].generated)
                    for u in uids}
            return rep, toks

        # dp=2 over the mesh's 'data' axis (tp=1)
        dp_eng = make(mesh=make_serving_mesh(1, 2))
        assert dp_eng.replicas is not None and len(dp_eng.replicas) == 2
        rep_dp, toks_dp = serve(dp_eng, reqs)
        assignment = dict(dp_eng.router.assignment)
        clocks = [r.clock_s for r in dp_eng.replicas]
        dp_eng.close()
        assert {r for r, _ in assignment.values()} == {0, 1}
        assert rep_dp.span_s == max(clocks)
        assert {s.replica for s in rep_dp.stats} == {0, 1}

        # golden: two independent dp=1 engines fed the routed streams
        toks_ref = {}
        for r in (0, 1):
            sub = make()
            local = {}
            for g, (ri, _) in sorted(assignment.items()):
                if ri == r:
                    p, m, t = reqs[g]
                    local[sub.submit(p, m, arrival_time=t)] = g
            sub.run_until_drained()
            for lu, g in local.items():
                toks_ref[g] = list(sub.sched.sequences[lu].generated)
            sub.close()
        assert toks_dp == toks_ref, (toks_dp, toks_ref)
        assert all(len(t) == 6 for t in toks_dp.values())

        # dp=2 x tp=2 over a (2, 2) mesh: per-replica tensor sharding
        # must not change a single token, and each step carries the
        # per-shard breakdown of its replica's storage plane
        grid_eng = make(mesh=make_serving_mesh(2, 2))
        rep_grid, toks_grid = serve(grid_eng, reqs)
        grid_eng.close()
        assert toks_grid == toks_dp, (toks_grid, toks_dp)
        assert all(s.n_shards == 2 and len(s.shards) == 2
                   for s in rep_grid.stats)

        # the fused pallas cold path over the same (2, 2) grid:
        # replica routing x tensor sharding x kernel backend, still
        # token-identical (DESIGN.md §10)
        pal_eng = make(mesh=make_serving_mesh(2, 2), backend="pallas")
        _, toks_pal = serve(pal_eng, reqs)
        pal_eng.close()
        assert toks_pal == toks_dp, (toks_pal, toks_dp)

        # the shared-timeline span beats draining the same trace on a
        # single replica (replicas decode concurrently)
        single = make()
        rep_1, toks_1 = serve(single, reqs)
        single.close()
        assert rep_dp.span_s < rep_1.span_s, (rep_dp.span_s, rep_1.span_s)
        assert rep_dp.total_tokens == rep_1.total_tokens
        print("OK dp golden", len(rep_dp.stats),
              round(rep_1.span_s / rep_dp.span_s, 3))
    """, ndev=4, timeout=600)
    assert "OK dp golden" in out
