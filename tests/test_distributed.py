"""Distribution tests in a subprocess with 8 forced host devices
(device count locks at first jax init, so the main test process stays
single-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, timeout=420):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_shard_map_cold_path_matches_local_8dev():
    out = run_in_subprocess("""
        from repro.core.sparse_ffn import init_ffn, ffn_hybrid
        from repro.core.clusters import HybridPlan
        D, N, cs, G = 64, 512, 32, 4
        params = init_ffn(jax.random.key(0), D, N, "relu2", jnp.float32,
                          predictor_rank=16)
        x = jax.random.normal(jax.random.key(1), (2, D)) * 0.5
        plan = HybridPlan(n_hot=128, k_cold=64, groups=G, cluster_size=cs)
        y_local = ffn_hybrid(params, x, "relu2", "relu", plan)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            spec = {"w": NamedSharding(mesh, P("model", None, None)),
                    "pred": {"A": NamedSharding(mesh, P(None, None)),
                             "B": NamedSharding(mesh, P(None, "model"))}}
            ps = jax.tree.map(jax.device_put, params, spec)
            y_sm = jax.jit(lambda p, xx: ffn_hybrid(
                p, xx, "relu2", "relu", plan))(ps, x)
        np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_local),
                                   atol=1e-3, rtol=1e-3)
        print("OK shard_map")
    """)
    assert "OK shard_map" in out


def test_sharded_train_step_matches_single_device():
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.optim.adamw import AdamW
        from repro.train.steps import make_train_step
        from repro.launch.input_specs import param_specs

        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        params = model.init(jax.random.key(0))
        state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32),
                 "labels": rng.integers(0, cfg.vocab_size, (4, 32)).astype(np.int32)}
        step = make_train_step(model, opt)
        _, _, m1 = jax.jit(step)(params, state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            specs = param_specs(model, cfg, mesh)
            ps = jax.tree.map(lambda a, s: jax.device_put(a, s.sharding),
                              params, specs)
            ss = opt.init(ps)
            b = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                 for k, v in batch.items()}
            _, _, m2 = jax.jit(step)(ps, ss, b)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   atol=1e-3, rtol=1e-4)
        print("OK sharded train", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "OK sharded train" in out


def test_sharded_moe_forward_matches_single_device():
    out = run_in_subprocess("""
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.launch.input_specs import param_specs

        cfg = get_config("deepseek-moe-16b").reduced().replace(
            moe_capacity_factor=8.0, moe_dispatch_groups=2)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                        (4, 32)).astype(np.int32)}
        y1 = jax.jit(lambda p, b: model.forward(p, b))(params, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            specs = param_specs(model, cfg, mesh)
            ps = jax.tree.map(lambda a, s: jax.device_put(a, s.sharding),
                              params, specs)
            b = {"tokens": jax.device_put(
                batch["tokens"], NamedSharding(mesh, P("data", None)))}
            y2 = jax.jit(lambda p, bb: model.forward(p, bb))(ps, b)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=2e-3, rtol=2e-3)
        print("OK sharded moe")
    """)
    assert "OK sharded moe" in out
