"""NeuronCache.rebalance() batch-growth/shrink edge cases, and the
storage plane's per-shard cache accounting (which slices the same
NeuronCache per mesh device — no mesh needed to test the pricing)."""
import numpy as np

from repro.core.cache import NeuronCache


CAP, CS, LAYERS, N = 1024, 32, 2, 4096


def make_cache():
    return NeuronCache(LAYERS, N, CS, capacity_neurons=CAP,
                       hot_fraction=0.5, bytes_per_neuron=96)


def hot_neuron_capacity(c: NeuronCache) -> int:
    return c.hot.capacity * c.cluster_size


def test_hot_region_grows_monotonically_with_batch():
    caps = []
    for b in (1, 2, 4, 8, 16, 32, 64):
        c = make_cache()
        c.rebalance(b)
        caps.append(hot_neuron_capacity(c))
    assert caps == sorted(caps)
    assert caps[-1] > caps[0]
    # ramp saturates at batch 32: hot share 0.8 of capacity
    assert caps[-2] == caps[-1] == int(CAP * 0.8) // CS * CS


def test_rebalance_extremes_and_degenerate_batches():
    c = make_cache()
    c.rebalance(0)          # clamps: log2(max(0,1)) = 0 -> base split
    assert hot_neuron_capacity(c) == int(CAP * 0.5) // CS * CS
    assert c.cold.capacity == CAP - int(CAP * 0.5)
    c.rebalance(10 ** 9)    # far beyond the ramp: capped at 0.8
    assert hot_neuron_capacity(c) == int(CAP * 0.8) // CS * CS
    assert c.cold.capacity == CAP - int(CAP * 0.8)


def test_capacity_never_exceeded_through_grow_shrink_cycle():
    c = make_cache()
    rng = np.random.default_rng(0)
    for b in (1, 8, 32, 4, 1, 64, 2):
        c.rebalance(b)
        # saturate both regions with traffic at the new split
        for l in range(LAYERS):
            c.admit_cold(l, rng.integers(0, N, 600))
            for _cl in range(40):
                c.admit_hot_cluster(l, int(rng.integers(0, N // CS)))
        assert len(c.cold) <= c.cold.capacity
        assert len(c.hot) <= c.hot.capacity
        assert c.resident_neurons <= CAP + CS  # cluster-rounding slack
        assert c.hot.capacity * CS + c.cold.capacity <= CAP + CS


def test_shrinking_cold_region_counts_evictions():
    c = make_cache()
    for l in range(LAYERS):
        c.admit_cold(l, range(512))     # fill cold to its base capacity
    filled = len(c.cold)
    ev0 = c.stats.evictions
    c.rebalance(32)                     # hot 0.8 -> cold capacity shrinks
    assert c.cold.capacity == CAP - int(CAP * 0.8)
    assert len(c.cold) == c.cold.capacity < filled
    # every overflow entry was discarded and counted, exactly once
    assert c.stats.evictions - ev0 == filled - c.cold.capacity


def test_shrinking_hot_region_counts_cluster_evictions():
    c = make_cache()
    c.rebalance(32)                     # grow hot to 0.8
    for cl in range(c.hot.capacity):
        c.admit_hot_cluster(0, cl)      # fill hot completely
    ev0 = c.stats.evictions
    c.rebalance(1)                      # shrink back to the base split
    dropped_clusters = int(CAP * 0.8) // CS - int(CAP * 0.5) // CS
    assert c.stats.evictions - ev0 == dropped_clusters * CS
    assert len(c.hot) <= c.hot.capacity


def test_grow_shrink_preserves_lru_recency_order():
    c = make_cache()
    c.admit_cold(0, range(400))
    c.lookup_cold(0, range(200, 400))   # touch the upper half (recent)
    c.rebalance(64)                     # cold capacity shrinks below 400
    cap = c.cold.capacity
    assert cap < 400
    kept = {k[1] for k in c.cold.keys()}
    # LRU keeps the `cap` most recent: the touched 200..399 plus the
    # newest untouched admissions right before them
    assert kept == set(range(400 - cap, 400))


# ------------------------------------------------- per-shard accounting ----

def _tiny_plane(n_shards):
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.core.baselines import POWERINFER2
    from repro.core.planner import build_plan
    from repro.models.model import build_model
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    plan = build_plan(cfg)
    from repro.serving.storage_plane import StoragePlane
    return cfg, plan, StoragePlane(
        cfg, params, plan, spec=POWERINFER2, offload_ratio=0.5,
        prefetch=False, n_shards=n_shards)


def test_storage_plane_shard_split_partitions_neurons():
    from repro.core.clusters import make_plan
    cfg, plan, plane = _tiny_plane(4)
    try:
        ids = np.arange(plane.N)
        # plan-aware split (what step() uses): the plan's cold region
        # splits by group — each shard owns G/n whole groups, an exact
        # quarter of the cold traffic — and the hot prefix uniformly
        p4 = make_plan(plane.N, 0.25, 0.25, plane.cs, groups=4)
        parts = plane._split_by_owner(ids, p4)
        assert len(parts) == 4
        assert sorted(np.concatenate(parts).tolist()) == ids.tolist()
        cold_sizes = [int((p >= p4.n_hot).sum()) for p in parts]
        assert max(cold_sizes) == min(cold_sizes)
        hot_sizes = [int((p < p4.n_hot).sum()) for p in parts]
        assert max(hot_sizes) - min(hot_sizes) <= 1
        # plan-less fallback (strided): still a true partition
        parts = plane._split_by_owner(ids)
        assert sorted(np.concatenate(parts).tolist()) == ids.tolist()
    finally:
        plane.close()


def test_storage_plane_aggregates_across_shards():
    cfg, plan, plane1 = _tiny_plane(1)
    cfg4, plan4, plane4 = _tiny_plane(4)
    try:
        p1 = plan.plan_for_batch(1)
        nc_g = max((plane1.N - p1.n_hot)
                   // plane1.cs // max(p1.groups, 1), 1)
        rng = np.random.default_rng(0)
        trace = rng.integers(
            0, nc_g, (cfg.num_layers, max(p1.groups, 1),
                      max(p1.clusters_per_group, 1)))
        s1 = plane1.step(trace, p1, batch=1, ctx_len=16.0)
        s4 = plane4.step(trace, p1, batch=1, ctx_len=16.0)
        assert s1.n_shards == 1 and s1.shards is None
        assert s4.n_shards == 4 and len(s4.shards) == 4
        # headline io is the worst shard; totals sum the shards
        assert abs(s4.io_total_s
                   - sum(sh.io_s for sh in s4.shards)) < 1e-12
        assert abs(s4.io_s - max(sh.io_s for sh in s4.shards)) < 1e-12
        assert s4.n_miss == sum(sh.n_miss for sh in s4.shards)
        assert abs(s4.effective_s
                   - max(sh.effective_s for sh in s4.shards)) < 1e-12
        # sharded compute (FFN split 4-way) beats the single device
        assert s4.compute_s < s1.compute_s
        # per-shard miss traffic shrank vs the whole-cache plane
        assert s4.io_s <= s1.io_s + 1e-12
    finally:
        plane1.close()
        plane4.close()


def test_storage_plane_single_shard_unchanged_alias():
    cfg, plan, plane = _tiny_plane(1)
    try:
        assert plane.cache is plane.caches[0]
        assert len(plane.caches) == 1
    finally:
        plane.close()
