"""Pallas kernel validation (deliverable c): shape/dtype sweeps against
the pure-jnp oracles in kernels/ref.py, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (cluster_gather_ffn, cluster_gather_ffn_grouped,
                               dense_ffn, fused_cold_ffn)
from repro.kernels.ref import cluster_gather_ffn_ref, dense_ffn_ref

ACTS = [("silu", 3), ("relu2", 3), ("gelu", 2), ("geglu", 3)]
SHAPES = [(1, 64, 256, 32), (4, 128, 512, 64), (8, 256, 1024, 128),
          (2, 384, 768, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("act,R", ACTS)
@pytest.mark.parametrize("B,D,N,cs", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cluster_gather_ffn_sweep(act, R, B, D, N, cs, dtype):
    kx, kw, ki = jax.random.split(jax.random.key(B * N + cs), 3)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(dtype)
    w = (jax.random.normal(kw, (N, R, D)) * 0.1).astype(dtype)
    n_clusters = N // cs
    k = max(1, n_clusters // 2)
    idx = jax.random.permutation(ki, n_clusters)[:k].astype(jnp.int32)
    y = cluster_gather_ffn(x, w, idx, activation=act, cluster_size=cs)
    yr = cluster_gather_ffn_ref(x, w, idx, activation=act, cluster_size=cs)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("act,R", ACTS[:2])
@pytest.mark.parametrize("B,D,N,cs", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_dense_ffn_sweep(act, R, B, D, N, cs, dtype):
    kx, kw = jax.random.split(jax.random.key(7))
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(dtype)
    w = (jax.random.normal(kw, (N, R, D)) * 0.1).astype(dtype)
    y = dense_ffn(x, w, activation=act, block_n=cs)
    yr = dense_ffn_ref(x, w, activation=act)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


def test_gather_equals_dense_when_all_selected():
    """Selecting every cluster must reproduce the dense FFN exactly."""
    B, D, N, cs = 2, 128, 512, 64
    x = jax.random.normal(jax.random.key(0), (B, D)) * 0.5
    w = jax.random.normal(jax.random.key(1), (N, 3, D)) * 0.1
    idx = jnp.arange(N // cs, dtype=jnp.int32)
    y = cluster_gather_ffn(x, w, idx, activation="silu", cluster_size=cs)
    yd = dense_ffn(x, w, activation="silu", block_n=cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               atol=1e-4, rtol=1e-4)


def test_gather_order_invariance():
    """Cluster accumulation is order-independent (fp32 accumulator)."""
    B, D, N, cs = 2, 128, 512, 64
    x = jax.random.normal(jax.random.key(0), (B, D)) * 0.5
    w = jax.random.normal(jax.random.key(1), (N, 3, D)) * 0.1
    idx = jnp.array([0, 2, 5, 7], jnp.int32)
    y1 = cluster_gather_ffn(x, w, idx, activation="silu", cluster_size=cs)
    y2 = cluster_gather_ffn(x, w, idx[::-1], activation="silu",
                            cluster_size=cs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


# ---- fused cold path: score -> top-k -> gather -> FFN (DESIGN.md §10) ----

# (B, D, N, cs, G, kc): N must split into G groups of nc_g clusters
FUSED_SHAPES = [(2, 64, 512, 32, 1, 3), (4, 128, 512, 64, 2, 2),
                (1, 64, 768, 32, 3, 4)]


def _fused_inputs(B, D, N, cs, G, R, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = (jax.random.normal(ks[0], (B, D)) * 0.5).astype(dtype)
    wc = (jax.random.normal(ks[1], (G, N // (G * cs), cs, R, D))
          * 0.1).astype(dtype)
    A = jax.random.normal(ks[2], (D, 16)) * 0.3
    Bp = jax.random.normal(ks[3], (16, N)) * 0.3
    return x, wc, A, Bp


def _fused_oracle(x, wc, A, Bp, act, mode, kc, mask=None):
    """The jnp chain the kernel fuses, composed step by step."""
    from repro.models.modules import activation_fn
    G, nc_g, cs, R, D = wc.shape
    xf = jnp.asarray(x, jnp.float32)
    scores = (xf @ A) @ Bp                              # (B, G*nc_g*cs)
    neg = float(jnp.finfo(jnp.float32).min)
    u = scores if mask is None else jnp.where(mask[:, None], scores, neg)
    union = u.max(0).reshape(G, nc_g, cs).max(-1)       # (G, nc_g)
    _, idx = jax.lax.top_k(union, kc)                   # (G, kc)
    actf = activation_fn(act)
    y = jnp.zeros((x.shape[0], D), jnp.float32)
    for g in range(G):
        for k in range(kc):
            c = int(idx[g, k])
            wk = wc[g, c].astype(jnp.float32)           # (cs, R, D)
            hh = actf(xf @ wk[:, 0].T)
            if R == 3:
                hh = hh * (xf @ wk[:, 1].T)
            if mode == "cats":
                tok = scores[:, (g * nc_g + c) * cs:(g * nc_g + c + 1) * cs]
                hh = hh * (tok > 0.0)
            y = y + hh @ wk[:, -1]
    return y, idx


@pytest.mark.parametrize("act,R", ACTS)
@pytest.mark.parametrize("B,D,N,cs,G,kc", FUSED_SHAPES)
@pytest.mark.parametrize("mode", ["relu", "cats"])
def test_fused_cold_ffn_sweep(act, R, B, D, N, cs, G, kc, mode):
    x, wc, A, Bp = _fused_inputs(B, D, N, cs, G, R, jnp.float32,
                                 seed=B * N + cs)
    y, idx = fused_cold_ffn(x, wc, A, Bp, activation=act, mode=mode, kc=kc)
    yr, ir = _fused_oracle(x, wc, A, Bp, act, mode, kc)
    # in-kernel iterative argmax must reproduce lax.top_k exactly
    # (same tie-breaking), so selection — hence decode — is identical
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_cold_ffn_masked_rows(dtype):
    """Inactive rows must not vote in the batch-union selection."""
    B, D, N, cs, G, kc = 4, 64, 512, 32, 2, 2
    x, wc, A, Bp = _fused_inputs(B, D, N, cs, G, 3, dtype, seed=11)
    mask = jnp.array([True, False, True, False])
    y, idx = fused_cold_ffn(x, wc, A, Bp, activation="silu", mode="cats",
                            kc=kc, active_mask=mask)
    yr, ir = _fused_oracle(x, wc, A, Bp, "silu", "cats", kc, mask=mask)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)


def test_fused_all_clusters_equals_dense():
    """kc == nc_g selects everything: the fused kernel must equal the
    dense FFN over the cold region (CATS off so no extra gating)."""
    B, D, N, cs, G = 2, 64, 512, 64, 2
    x, wc, A, Bp = _fused_inputs(B, D, N, cs, G, 3, jnp.float32, seed=3)
    nc_g = N // (G * cs)
    y, idx = fused_cold_ffn(x, wc, A, Bp, activation="silu", mode="relu",
                            kc=nc_g)
    yd = dense_ffn(x, wc.reshape(N, 3, D), activation="silu", block_n=cs)
    assert sorted(np.asarray(idx)[0].tolist()) == list(range(nc_g))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               atol=1e-4, rtol=1e-4)


def test_grouped_matches_per_group_sum():
    G, nc_g, cs, D, B = 3, 4, 32, 64, 2
    wc = jax.random.normal(jax.random.key(2), (G, nc_g, cs, 3, D)) * 0.1
    cidx = jnp.array([[0, 2], [1, 3], [0, 1]], jnp.int32)
    x = jax.random.normal(jax.random.key(3), (B, D)) * 0.5
    y = cluster_gather_ffn_grouped(x, wc, cidx, activation="silu")
    ref = sum(cluster_gather_ffn_ref(x, wc[g].reshape(nc_g * cs, 3, D),
                                     cidx[g], activation="silu",
                                     cluster_size=cs) for g in range(G))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
