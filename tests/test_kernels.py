"""Pallas kernel validation (deliverable c): shape/dtype sweeps against
the pure-jnp oracles in kernels/ref.py, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (cluster_gather_ffn, cluster_gather_ffn_grouped,
                               dense_ffn)
from repro.kernels.ref import cluster_gather_ffn_ref, dense_ffn_ref

ACTS = [("silu", 3), ("relu2", 3), ("gelu", 2), ("geglu", 3)]
SHAPES = [(1, 64, 256, 32), (4, 128, 512, 64), (8, 256, 1024, 128),
          (2, 384, 768, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("act,R", ACTS)
@pytest.mark.parametrize("B,D,N,cs", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cluster_gather_ffn_sweep(act, R, B, D, N, cs, dtype):
    kx, kw, ki = jax.random.split(jax.random.key(B * N + cs), 3)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(dtype)
    w = (jax.random.normal(kw, (N, R, D)) * 0.1).astype(dtype)
    n_clusters = N // cs
    k = max(1, n_clusters // 2)
    idx = jax.random.permutation(ki, n_clusters)[:k].astype(jnp.int32)
    y = cluster_gather_ffn(x, w, idx, activation=act, cluster_size=cs)
    yr = cluster_gather_ffn_ref(x, w, idx, activation=act, cluster_size=cs)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("act,R", ACTS[:2])
@pytest.mark.parametrize("B,D,N,cs", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_dense_ffn_sweep(act, R, B, D, N, cs, dtype):
    kx, kw = jax.random.split(jax.random.key(7))
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(dtype)
    w = (jax.random.normal(kw, (N, R, D)) * 0.1).astype(dtype)
    y = dense_ffn(x, w, activation=act, block_n=cs)
    yr = dense_ffn_ref(x, w, activation=act)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


def test_gather_equals_dense_when_all_selected():
    """Selecting every cluster must reproduce the dense FFN exactly."""
    B, D, N, cs = 2, 128, 512, 64
    x = jax.random.normal(jax.random.key(0), (B, D)) * 0.5
    w = jax.random.normal(jax.random.key(1), (N, 3, D)) * 0.1
    idx = jnp.arange(N // cs, dtype=jnp.int32)
    y = cluster_gather_ffn(x, w, idx, activation="silu", cluster_size=cs)
    yd = dense_ffn(x, w, activation="silu", block_n=cs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               atol=1e-4, rtol=1e-4)


def test_gather_order_invariance():
    """Cluster accumulation is order-independent (fp32 accumulator)."""
    B, D, N, cs = 2, 128, 512, 64
    x = jax.random.normal(jax.random.key(0), (B, D)) * 0.5
    w = jax.random.normal(jax.random.key(1), (N, 3, D)) * 0.1
    idx = jnp.array([0, 2, 5, 7], jnp.int32)
    y1 = cluster_gather_ffn(x, w, idx, activation="silu", cluster_size=cs)
    y2 = cluster_gather_ffn(x, w, idx[::-1], activation="silu",
                            cluster_size=cs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_grouped_matches_per_group_sum():
    G, nc_g, cs, D, B = 3, 4, 32, 64, 2
    wc = jax.random.normal(jax.random.key(2), (G, nc_g, cs, 3, D)) * 0.1
    cidx = jnp.array([[0, 2], [1, 3], [0, 1]], jnp.int32)
    x = jax.random.normal(jax.random.key(3), (B, D)) * 0.5
    y = cluster_gather_ffn_grouped(x, wc, cidx, activation="silu")
    ref = sum(cluster_gather_ffn_ref(x, wc[g].reshape(nc_g * cs, 3, D),
                                     cidx[g], activation="silu",
                                     cluster_size=cs) for g in range(G))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
