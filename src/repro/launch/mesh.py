"""Production mesh construction (deliverable e).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — 'pod'
is the outer replica/data axis crossing the ICI/DCN boundary.

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any init).
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh for CPU smoke runs of mesh-aware code paths."""
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def make_serving_mesh(n_model: int, n_data: int = 1):
    """(data, model) mesh for the serving plane: 'model' is the
    tensor-parallel axis, 'data' the replica-routing axis
    (DESIGN.md §3/§5).

    Uses the first n_data*n_model visible devices (on CPU runs, force
    them with XLA_FLAGS=--xla_force_host_platform_device_count=N before
    the first jax call)."""
    import jax
    need = n_data * n_model
    avail = jax.device_count()
    if need > avail:
        raise ValueError(
            f"serving mesh ({n_data}, {n_model}) needs {need} devices "
            f"but only {avail} are visible")
    return make_mesh((n_data, n_model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2,
                     devices=jax.devices()[:need])


def dispatch_groups(mesh) -> int:
    """Data-local MoE dispatch groups for a mesh: one token group per
    (pod x data) row, so the dispatch buffer shards over the batch
    axes while the expert dim shards over 'model' (EP). This is the
    single source of truth for `cfg.moe_dispatch_groups` — the dry-run
    derives the launcher-global group count from the production mesh,
    and each serving replica derives its own (its submesh has
    data == 1, so replica dispatch is one local group and dp x tp x ep
    composes). Meshless hosts dispatch in one group."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    n = 1
    for ax in ("pod", "data"):
        n *= shape.get(ax, 1)
    return int(n)


def replica_submeshes(mesh):
    """One (1, n_model) tensor-parallel submesh per 'data'-axis row of
    `mesh` — replica r keeps exactly the devices of row r, so a
    replica-routed engine places each serving stack on its own slice
    of the parent mesh."""
    import numpy as np
    shape = dict(mesh.shape)
    n_data = shape.get("data", 1)
    n_model = shape.get("model", 1)
    devs = np.asarray(mesh.devices).reshape(n_data, n_model)
    return [make_mesh((1, n_model), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2,
                      devices=list(devs[r]))
            for r in range(n_data)]


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
