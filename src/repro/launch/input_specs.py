"""ShapeDtypeStruct stand-ins for every (arch × input-shape) pair.

No device allocation: shapes + dtypes + shardings only. For the audio
and VLM archs the modality frontend is a stub — specs provide the frame
/ patch embeddings directly (the sanctioned carve-out).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from repro.sharding import batch_axes


def _sds(shape, dtype, mesh, spec):
    from repro.sharding import _filter_spec
    spec = _filter_spec(spec, mesh, shape=shape)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_spec(mesh):
    return P(batch_axes(mesh))


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config adaptation. long_500k decode on
    full-attention archs switches to the sliding-window variant
    (DESIGN.md §Arch-applicability — noted per row in EXPERIMENTS.md)."""
    if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid")
            and not cfg.sliding_window):
        cfg = cfg.replace(sliding_window=cfg.long_context_window)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """Model-input ShapeDtypeStructs for the given global shape."""
    b = batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    emb = jnp.dtype(cfg.param_dtype)

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": _sds((B, cfg.num_frames, cfg.d_model), emb, mesh,
                               P(b, None, None)),
                "tokens": _sds((B, S), tok, mesh, P(b, None)),
                "labels": _sds((B, S), tok, mesh, P(b, None)),
            }
        if cfg.family == "vlm":
            P_img = cfg.num_image_tokens
            return {
                "patch_embeds": _sds((B, P_img, cfg.d_model), emb, mesh,
                                     P(b, None, None)),
                "tokens": _sds((B, S - P_img), tok, mesh, P(b, None)),
                "labels": _sds((B, S - P_img), tok, mesh, P(b, None)),
            }
        return {"tokens": _sds((B, S), tok, mesh, P(b, None)),
                "labels": _sds((B, S), tok, mesh, P(b, None))}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": _sds((B, cfg.num_frames, cfg.d_model), emb, mesh,
                               P(b, None, None)),
                "tokens": _sds((B, S), tok, mesh, P(b, None)),
            }
        if cfg.family == "vlm":
            P_img = cfg.num_image_tokens
            return {
                "patch_embeds": _sds((B, P_img, cfg.d_model), emb, mesh,
                                     P(b, None, None)),
                "tokens": _sds((B, S - P_img), tok, mesh, P(b, None)),
            }
        return {"tokens": _sds((B, S), tok, mesh, P(b, None))}

    # decode: one new token against a cache of length S
    return {"tokens": _sds((B, 1), tok, mesh, P(b, None))}


def cache_specs(model, cfg: ModelConfig, shape: InputShape, mesh):
    """Decode-cache ShapeDtypeStructs with the model's cache sharding."""
    B, S = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    specs = model.cache_spec(B, S)

    def attach(sd, spec):
        from repro.sharding import _filter_spec
        return jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=NamedSharding(mesh, _filter_spec(spec, mesh,
                                                      shape=sd.shape)))

    return jax.tree.map(attach, shapes, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
                        or isinstance(x, P))


def param_specs(model, cfg: ModelConfig, mesh, fsdp: bool = False):
    """Parameter ShapeDtypeStructs with the model's param sharding.

    fsdp=True additionally shards each leaf's largest replicated dim
    over 'data' (for the 314B/405B train states)."""
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = model.param_spec()

    def attach(sd, spec):
        from repro.sharding import _filter_spec
        spec = _filter_spec(spec, mesh, shape=sd.shape)
        if fsdp and "data" in mesh.axis_names:
            parts = list(spec) + [None] * (len(sd.shape) - len(spec))
            if "data" not in str(parts):
                # shard the largest free dim over data
                cand = [(dim, i) for i, (dim, pp) in
                        enumerate(zip(sd.shape, parts)) if pp is None]
                if cand:
                    size, idx = max(cand)
                    if size % 16 == 0:
                        parts[idx] = "data"
            spec = P(*parts)
        return jax.ShapeDtypeStruct(sd.shape, sd.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(attach, shapes, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
                        or isinstance(x, P))
