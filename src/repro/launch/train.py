"""End-to-end training driver.

CPU demo (examples/quickstart uses it): train a reduced config for a
few hundred steps on the synthetic pipeline and watch loss fall. On a
pod the same code path runs the full config: pjit with the model's
param spec over make_production_mesh().

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens, shard_batch
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step


def add_modal_inputs(batch, cfg, rng):
    """Stub modality frontends for encdec/vlm (per DESIGN.md carve-out)."""
    B = batch["tokens"].shape[0]
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.num_frames, cfg.d_model)).astype(np.float32) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32) * 0.1
    return batch


def train(arch: str, steps: int = 100, batch_size: int = 8,
          seq_len: int = 128, reduced: bool = True, lr: float = 1e-3,
          log_every: int = 20, mesh=None, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt = AdamW(lr=lr)
    params = model.init(jax.random.key(seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len, batch_size,
                                      seed=seed))
    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for i in range(steps):
        batch = add_modal_inputs(data.batch(), cfg, rng)
        batch = shard_batch(batch, mesh)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.3f}s/step)", flush=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      args.reduced, args.lr)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
