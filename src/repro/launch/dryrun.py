"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes, record
memory/cost/collective artifacts for the roofline (deliverable g).

MUST set XLA_FLAGS before any jax import — the host platform locks its
device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape decode_32k --mesh pod --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import get_config, ASSIGNED_ARCHS, INPUT_SHAPES  # noqa: E402
from repro.compat import set_mesh                                   # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch import input_specs as ispec                       # noqa: E402
from repro.models.model import build_model                          # noqa: E402
from repro.optim.adamw import AdamW                                 # noqa: E402
from repro.train.steps import make_train_step                       # noqa: E402
from repro.core.planner import build_plan                           # noqa: E402


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes of every `dtype[d0,d1,...]` in an HLO type expression."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective-kind result bytes (per device) from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(%?)(" +
                     "|".join(_COLLECTIVES) + r")(-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(3)
        if m.group(4) == "-done":
            continue                       # avoid double count of async pairs
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


def decode_plan_for(cfg, groups: int):
    """Hybrid plan for the decode dry-run: per-shard grouped cold path."""
    if not cfg.sparse_ffn.enabled or cfg.family in ("ssm", "moe"):
        return None
    plan = build_plan(cfg, groups=groups).plan_for_batch(1)
    return plan


def adapt_moe_groups(cfg, mesh):
    """MoE configs dispatch within data-local token groups: retie
    `moe_dispatch_groups` to the mesh's replica rows
    (launch.mesh.dispatch_groups — the shared helper both dry-run
    paths and the serving engine use). Non-MoE configs pass through."""
    if not cfg.num_experts:
        return cfg
    from repro.launch.mesh import dispatch_groups
    return cfg.replace(moe_dispatch_groups=dispatch_groups(mesh))


def lower_target(arch: str, shape_name: str, multi_pod: bool,
                 verbose: bool = True) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()
    try:
        shape = INPUT_SHAPES[shape_name]
        cfg = ispec.adapt_config(get_config(arch), shape)
        if cfg.param_count() > 5e10:
            # bf16 Adam moments so the 314B/405B train state fits
            opt = AdamW(moment_dtype="bfloat16")
            fsdp = True
        else:
            opt = AdamW()
            fsdp = False
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg = adapt_moe_groups(cfg, mesh)
        model = build_model(cfg)
        groups = mesh.shape["model"]

        with set_mesh(mesh):
            pspecs = ispec.param_specs(model, cfg, mesh,
                                       fsdp=fsdp and shape.kind == "train")
            batch = ispec.input_specs(cfg, shape, mesh)

            if shape.kind == "train":
                ospecs = jax.tree.map(
                    lambda sd: jax.ShapeDtypeStruct(sd.shape, sd.dtype,
                                                    sharding=sd.sharding),
                    jax.eval_shape(opt.init, pspecs))
                step = make_train_step(model, opt)
                lowered = jax.jit(step).lower(pspecs, ospecs, batch)
            elif shape.kind == "prefill":
                lowered = jax.jit(model.prefill).lower(pspecs, batch)
            else:
                plan = decode_plan_for(cfg, groups)
                cspecs = ispec.cache_specs(model, cfg, shape, mesh)
                fn = lambda p, t, c: model.decode_step(p, t, c, plan)  # noqa
                lowered = jax.jit(fn).lower(pspecs, batch["tokens"], cspecs)
                if plan:
                    rec["plan"] = {"n_hot": plan.n_hot, "k_cold": plan.k_cold,
                                   "groups": plan.groups,
                                   "cluster_size": plan.cluster_size}
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

            ca = compiled.cost_analysis() or {}
            rec["flops_per_device"] = float(ca.get("flops", -1.0))
            rec["bytes_per_device"] = float(ca.get("bytes accessed", -1.0))
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory"] = {
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                }
            rec["collectives"] = parse_collectives(compiled.as_text())
            rec["n_devices"] = mesh.size
            rec["ok"] = True
    except Exception as e:  # record failures as artifacts, not crashes
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if verbose:
        status = "OK " if rec["ok"] else "FAIL"
        print(f"[{status}] {arch} x {shape_name} x {rec['mesh']} "
              f"({rec['total_s']}s)", flush=True)
        if not rec["ok"]:
            print("   ", rec["error"], flush=True)
    return rec


# ----------------------------------------------------------- cost probe ----
#
# XLA's cost analysis counts a while-loop body ONCE regardless of trip
# count (verified empirically), so the scanned dry-run under-reports
# FLOPs/bytes/collectives by ~the layer count. The probe lowers two
# UNROLLED reduced-depth variants (whole pattern groups for the hybrid)
# with single-chunk flash attention — the lowered HLO then contains no
# loops at all — and extrapolates linearly in depth:
#     cost(L) = base + L * per_layer   (exact: HLO cost is affine in L)

def _probe_depths(cfg):
    if cfg.block_pattern:
        p = len(cfg.block_pattern)
        return p, 2 * p                      # whole groups, no remainder
    return 2, 4


def _probe_cfg(cfg, L):
    kw = {"num_layers": L}
    if cfg.num_encoder_layers:
        kw["num_encoder_layers"] = L
    return cfg.replace(**kw)


def _cost_of(arch, shape_name, cfg, multi_pod):
    """Lower+compile one variant, return (flops, bytes, coll bytes/counts)."""
    from repro.models import blocks as _blocks
    from repro.models import attention as _attn
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = adapt_moe_groups(cfg, mesh)
    model = build_model(cfg)
    groups = mesh.shape["model"]
    opt = AdamW(moment_dtype="bfloat16" if cfg.param_count() > 5e10
                else "float32")
    _blocks.UNROLL = True
    _attn.FLASH_FULL_BLOCKS = True
    try:
        with set_mesh(mesh):
            pspecs = ispec.param_specs(model, cfg, mesh,
                                       fsdp=shape.kind == "train"
                                       and cfg.param_count() > 5e10)
            batch = ispec.input_specs(cfg, shape, mesh)
            if shape.kind == "train":
                ospecs = jax.eval_shape(opt.init, pspecs)
                step = make_train_step(model, opt)
                lowered = jax.jit(step).lower(pspecs, ospecs, batch)
            elif shape.kind == "prefill":
                lowered = jax.jit(model.prefill).lower(pspecs, batch)
            else:
                plan = decode_plan_for(cfg, groups)
                cspecs = ispec.cache_specs(model, cfg, shape, mesh)
                fn = lambda p, t, c: model.decode_step(p, t, c, plan)  # noqa
                lowered = jax.jit(fn).lower(pspecs, batch["tokens"], cspecs)
            compiled = lowered.compile()
            ca = compiled.cost_analysis() or {}
            txt = compiled.as_text()
            coll = parse_collectives(txt)
            return (float(ca.get("flops", 0.0)),
                    float(ca.get("bytes accessed", 0.0)),
                    coll["bytes"], coll["counts"], mesh.size,
                    model_traffic_bytes(txt))
    finally:
        _blocks.UNROLL = False
        _attn.FLASH_FULL_BLOCKS = False


def probe_target(arch: str, shape_name: str, multi_pod: bool = False,
                 verbose: bool = True) -> dict:
    rec = {"arch": arch, "shape": shape_name, "kind": "probe",
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()
    try:
        shape = INPUT_SHAPES[shape_name]
        cfg = ispec.adapt_config(get_config(arch), shape)
        L_full = cfg.num_layers
        l1, l2 = _probe_depths(cfg)
        f1, b1, c1, n1, ndev, t1 = _cost_of(arch, shape_name,
                                            _probe_cfg(cfg, l1), multi_pod)
        f2, b2, c2, n2, _, t2 = _cost_of(arch, shape_name,
                                         _probe_cfg(cfg, l2), multi_pod)
        dL = l2 - l1

        def extrap(v1, v2):
            per = (v2 - v1) / dL
            base = v1 - l1 * per
            return base + L_full * per

        rec["flops_per_device"] = extrap(f1, f2)
        rec["bytes_per_device"] = extrap(b1, b2)
        rec["traffic_bytes_per_device"] = extrap(t1, t2)
        rec["collectives"] = {
            "bytes": {k: extrap(c1[k], c2[k]) for k in c1},
            "counts": {k: extrap(n1[k], n2[k]) for k in n1},
        }
        rec["probe_depths"] = [l1, l2]
        rec["n_devices"] = ndev
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if verbose:
        status = "OK " if rec["ok"] else "FAIL"
        print(f"[{status}] probe {arch} x {shape_name} ({rec['total_s']}s)",
              flush=True)
        if not rec["ok"]:
            print("   ", rec["error"], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="unrolled cost probe for the roofline")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.probe:
        out = args.out if args.out != "artifacts/dryrun" \
            else "artifacts/probe"
        os.makedirs(out, exist_ok=True)
        archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
            else [args.arch]
        shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
            else [args.shape]
        n_fail = 0
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}"
                path = os.path.join(out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[SKIP] probe {tag} (cached)", flush=True)
                            continue
                rec = probe_target(arch, shape)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                n_fail += 0 if rec["ok"] else 1
        print(f"probe done; failures: {n_fail}", flush=True)
        raise SystemExit(1 if n_fail else 0)

    os.makedirs(args.out, exist_ok=True)
    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[SKIP] {tag} (cached)", flush=True)
                            continue
                rec = lower_target(arch, shape, mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                n_fail += 0 if rec["ok"] else 1
    print(f"done; failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)




# ----------------------------------------------- traffic-model bytes ----
#
# 'bytes accessed' from XLA:CPU counts dtype-convert copies that exist
# only because the CPU backend lowers bf16 dots as f32 (verified in
# §Perf iteration 4: a single (N,R,D) bf16 weight was converted to f32
# 40x in the llama3 long_500k probe). The TPU MXU consumes bf16
# natively. `model_traffic_bytes` re-prices the HLO: compute/data ops
# count operands at their *root* (pre-convert/bitcast/reshape) dtypes;
# layout and dtype artifacts count zero.

# dtype/layout artifacts are transparent for pricing (consumers price
# operands at the artifact's ROOT); slices terminate resolution (their
# own, smaller, result type is the right price for consumers).
_ARTIFACT_OPS = {"convert", "bitcast", "copy", "transpose", "reshape",
                 "broadcast", "get-tuple-element", "tuple"}
_SKIP_OPS = _ARTIFACT_OPS | {"slice", "parameter", "constant", "iota",
                             "while", "conditional", "call", "after-all",
                             "partition-id", "custom-call"}


def model_traffic_bytes(hlo_text: str) -> float:
    types, src = {}, {}
    ops = []
    line_re = re.compile(
        r"\s*(%?[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)")
    for line in hlo_text.splitlines():
        m = line_re.match(line)
        if not m:
            continue
        name, ts, kind, args = m.groups()
        name = name.lstrip("%")
        types[name] = ts
        refs = re.findall(r"%?([\w.\-]+)", args)
        operands = [r for r in refs if r in types]
        if kind in _ARTIFACT_OPS and operands:
            src[name] = operands[0]
        ops.append((name, ts, kind, operands))

    def root(n):
        seen = 0
        while n in src and seen < 50:
            n = src[n]
            seen += 1
        return n

    total = 0.0
    for _name, ts, kind, operands in ops:
        if kind in _SKIP_OPS:
            continue
        rb = _shape_bytes(ts)
        if kind in ("dot", "fusion", "dynamic-update-slice",
                    "dynamic-slice", "gather", "scatter", "concatenate",
                    "reduce", "sort", "select-and-scatter") \
                or kind in _COLLECTIVES:
            ob = sum(_shape_bytes(types.get(root(o), "")) for o in operands)
            total += rb + ob
        else:
            total += rb          # top-level elementwise: result only
    return total


if __name__ == "__main__":
    main()
