"""End-to-end serving driver — the paper's kind of workload.

Plan (offline §5) -> permute weights hot-first -> ServeEngine (online
§4) -> batched generation with Best-of-N and continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --offload 0.5 --bon 4 --max-new 32

Tensor-parallel serving (DESIGN.md §3): pass --tp N to run the engine
over an (1, N) device mesh — on CPU hosts force the devices first with
XLA_FLAGS=--xla_force_host_platform_device_count=N.

Data-parallel serving (DESIGN.md §5): pass --dp N to route requests
over N replicas (the mesh's 'data' axis). With --tp 1 the replicas are
scheduler-level and need no extra devices; with --tp > 1 each replica
owns its own (1, tp) row of a (dp, tp) mesh, so dp*tp devices must be
visible. A --dp run serves the Best-of-N prompts as a request stream
(submit/run_until_drained) instead of the static-batch generate().

Families (DESIGN.md §8): --family {dense,vlm,moe} serves that family's
default arch through the registry; for moe, --ep N is the
expert-parallel degree — the same mesh 'model' axis --tp sets for the
dense families (each shard owns E/N experts), so

  PYTHONPATH=src python -m repro.launch.serve --family moe --ep 2 --dp 2

Fleet serving (DESIGN.md §11): --fleet N stands up N complete
single-device engines behind the FleetGateway front door (weighted
least-loaded dispatch, circuit breakers, response LRU, heartbeats) and
serves the prompts as a request stream through it:

  PYTHONPATH=src python -m repro.launch.serve --fleet 2 --bon 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.baselines import POWERINFER2
from repro.core.io_model import UFS40, HOST_DMA
from repro.core.planner import profile_activations
from repro.serving.engine import ServeEngine
from repro.serving.families import default_archs, serving_family

# default arch per servable family (--family shorthand), straight
# from the registry so a newly registered family appears here for free
FAMILY_ARCHS = default_archs()


def build_engine(arch: str, reduced: bool = True, offload: float = 0.5,
                 spec=POWERINFER2, storage=UFS40, profile: bool = False,
                 seed: int = 0, tp: int = 1, dp: int = 1,
                 backend: str = "jnp", storage_dtype: str = "fp16",
                 **engine_kwargs):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(seed))
    freqs = None
    if profile and not cfg.num_experts:
        # dense-layer activation profiling; the MoE router needs none
        # (routing is the predictor, experts are the clusters)
        batches = [jax.random.randint(jax.random.key(i), (4, 64), 0,
                                      cfg.vocab_size) for i in range(4)]
        counts, n_tok = profile_activations(params, cfg, batches)
        freqs = (counts / n_tok).astype(np.float32)
    plan = fam.build_plan(cfg, freqs, backend=backend,
                          storage_dtype=storage_dtype)
    params = fam.prepare_params(params, plan)
    if backend != "jnp":
        # the decoder also gets the override so per-bucket plans the
        # planner (or a bench) pinned later still trace the chosen
        # kernel path
        engine_kwargs.setdefault("backend", backend)
    if tp > 1 and "mesh" not in engine_kwargs:
        from repro.launch.mesh import make_serving_mesh
        engine_kwargs["mesh"] = make_serving_mesh(tp, dp)
    if dp > 1:
        # always forward dp (tp=1 replicas are meshless — replica
        # routing is scheduler-level and needs no devices); with a
        # mesh, the engine verifies dp against the 'data' axis
        engine_kwargs.setdefault("dp", dp)
    return ServeEngine(cfg, params, plan, spec=spec, storage=storage,
                       offload_ratio=offload, seed=seed,
                       **engine_kwargs), cfg


def build_fleet(arch: str, n: int, reduced: bool = True,
                offload: float = 0.5, spec=POWERINFER2, storage=UFS40,
                seed: int = 0, backend: str = "jnp",
                storage_dtype: str = "fp16", **gateway_kwargs):
    """N complete single-device engines behind a FleetGateway — the
    --fleet front door (DESIGN.md §11). Engines share jit caches via
    local_fleet, so fleet size never multiplies trace time."""
    from repro.serving.gateway import FleetGateway, local_fleet
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(seed))
    plan = fam.build_plan(cfg, backend=backend,
                          storage_dtype=storage_dtype)
    params = fam.prepare_params(params, plan)
    engine_kwargs = {} if backend == "jnp" else {"backend": backend}
    backends = local_fleet(cfg, params, plan, n, spec=spec,
                           storage=storage, offload_ratio=offload,
                           seed=seed, **engine_kwargs)
    return FleetGateway(backends, **gateway_kwargs), cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: the --family arch)")
    ap.add_argument("--family", choices=sorted(FAMILY_ARCHS),
                    default="dense",
                    help="serving family; picks the default arch "
                         "unless --arch is given")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--offload", type=float, default=0.5)
    ap.add_argument("--bon", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--host-dma", action="store_true",
                    help="use the TPU host-DMA tier instead of UFS 4.0")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (mesh 'model' axis)")
    ap.add_argument("--ep", type=int, default=0,
                    help="expert-parallel degree for the moe family — "
                         "the same mesh 'model' axis as --tp (each "
                         "shard owns E/ep experts)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replicas (mesh 'data' axis)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="serve through the fleet gateway over N "
                         "complete single-device engines (DESIGN.md "
                         "§11); mutually exclusive with --tp/--dp/--ep")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="cold-path kernel backend: 'pallas' runs the "
                         "fused score->top-k->gather->FFN kernel "
                         "(interpret mode off-TPU; DESIGN.md §10); "
                         "decode is token-identical either way")
    ap.add_argument("--storage-dtype",
                    choices=("fp16", "int8", "int4-mixed"),
                    default="fp16",
                    help="cold-bundle storage dtype (§7.6): cold FFN "
                         "bundles are quantized at prepare time, both "
                         "cold paths dequantize at the gather boundary, "
                         "and the storage plane prices I/O + residency "
                         "at the declared bundle bytes (§4.4)")
    args = ap.parse_args()

    arch = args.arch or FAMILY_ARCHS[args.family]
    tp = args.tp
    if args.ep:
        if not get_config(arch).num_experts:
            ap.error(f"--ep is expert parallelism but {arch} has no "
                     f"experts; use --tp for tensor parallelism")
        if tp > 1 and tp != args.ep:
            ap.error(f"--tp {tp} and --ep {args.ep} both size the mesh "
                     f"'model' axis; pass one")
        tp = args.ep
    storage = HOST_DMA if args.host_dma else UFS40
    if args.backend == "pallas" and get_config(arch).num_experts:
        ap.error("--backend pallas is the dense-family fused cold-path "
                 "kernel; the moe family has no pallas backend")
    if args.fleet:
        if args.tp > 1 or args.dp > 1 or args.ep:
            ap.error("--fleet members are single-device engines; "
                     "mesh axes (--tp/--dp/--ep) don't apply")
        import time
        gw, cfg = build_fleet(arch, args.fleet, args.reduced,
                              args.offload, storage=storage,
                              backend=args.backend,
                              storage_dtype=args.storage_dtype)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size,
                              (args.bon, args.prompt_len))
        t0 = time.perf_counter()
        for i in range(args.bon):
            gw.submit(prompt[i].astype(np.int32), max_new=args.max_new,
                      arrival_time=0.0)
        rep = gw.run_until_drained()
        wall = time.perf_counter() - t0
        miss = rep.ttft_percentiles("miss")
        print(f"arch={cfg.name} spec=powerinfer-2 storage={storage.name} "
              f"fleet={args.fleet}")
        print(f"modeled fleet serve: {rep.throughput_tok_s:.2f} tok/s "
              f"over the {rep.span_s:.2f}s span | "
              f"{rep.n_completed}/{rep.n_submitted} completed, "
              f"{rep.n_rejected} rejected, {rep.n_retries} retries | "
              f"cache {rep.cache_hits} hit / {rep.cache_misses} miss")
        print(f"ttft ms (miss): mean {miss['mean']*1e3:.2f} "
              f"p50 {miss['p50']*1e3:.2f} p99 {miss['p99']*1e3:.2f} | "
              f"per-backend "
              f"{[b['completed'] for b in rep.per_backend]} completed")
        print(f"wall time {wall:.1f}s for {rep.total_tokens} tokens "
              f"(CPU jit)")
        gw.close()
        return
    engine, cfg = build_engine(arch, args.reduced, args.offload,
                               storage=storage, profile=True, tp=tp,
                               dp=args.dp, backend=args.backend,
                               storage_dtype=args.storage_dtype)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.bon, args.prompt_len)).astype(np.int32)
    if args.dp > 1:
        # replica-routed engines serve a stream, not a static batch
        import time
        t0 = time.perf_counter()
        for i in range(args.bon):
            engine.submit(prompt[i], max_new=args.max_new,
                          arrival_time=0.0)
        rep = engine.run_until_drained()
        wall = time.perf_counter() - t0
        pct = rep.latency_percentiles()
        hit = float(np.mean([s.cache_hit_rate for s in rep.stats]))
        io = sum(s.io_s for s in rep.stats)
        eff = sum(s.effective_s for s in rep.stats)
        print(f"arch={cfg.name} spec=powerinfer-2 storage={storage.name} "
              f"dp={args.dp} {'ep' if args.ep else 'tp'}={tp}")
        print(f"modeled serve: {rep.throughput_tok_s:.2f} tok/s over the "
              f"{rep.span_s:.2f}s span ({rep.tokens_per_s:.2f} tok/s "
              f"per-replica pipeline rate) | cache hit {hit:.1%} | "
              f"I/O share {io/max(eff,1e-12):.1%}")
        print(f"ttft ms: mean {float(rep.ttft().mean())*1e3:.2f} | "
              f"latency ms: p50 {pct['p50']*1e3:.2f} "
              f"p90 {pct['p90']*1e3:.2f} p99 {pct['p99']*1e3:.2f}")
        print(f"wall time {wall:.1f}s for {rep.total_tokens} tokens "
              f"(CPU jit)")
        engine.close()
        return
    res = engine.generate(prompt, max_new=args.max_new)
    pct = res.latency_percentiles()
    hit = float(np.mean([s.cache_hit_rate for s in res.stats]))
    io = sum(s.io_s for s in res.stats)
    eff = sum(s.effective_s for s in res.stats)
    print(f"arch={cfg.name} spec=powerinfer-2 storage={storage.name}")
    print(f"modeled decode: {res.tokens_per_s:.2f} tok/s | "
          f"cache hit {hit:.1%} | I/O share {io/max(eff,1e-12):.1%}")
    print(f"latency ms: mean {pct['mean']*1e3:.2f} p50 {pct['p50']*1e3:.2f} "
          f"p90 {pct['p90']*1e3:.2f} p99 {pct['p99']*1e3:.2f}")
    print(f"wall time {res.wall_s:.1f}s for "
          f"{int(np.sum(res.tokens >= 0))} tokens (CPU jit)")


if __name__ == "__main__":
    main()
