"""Roofline analysis (deliverable g) from dry-run artifacts.

Per (arch × shape × mesh):
  compute term    = per-device HLO FLOPs / 197 TFLOP/s (bf16, v5e)
  memory term     = per-device HLO bytes / 819 GB/s HBM
  collective term = per-device collective bytes / 50 GB/s ICI link

cost_analysis() is per-device (verified empirically — DESIGN.md §8).
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per trained token;
for prefill 2·N·D, for decode 2·N_active per token. The ratio
MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/redundancy waste.

  PYTHONPATH=src python -m repro.launch.roofline --artifacts artifacts/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config, INPUT_SHAPES
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW


def model_flops(arch: str, shape_name: str) -> float:
    """Useful (algorithmic) FLOPs for the whole global step."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_token = (6 if shape.kind == "train" else 2) * n_active
    return float(per_token) * tokens


def analyze_record(rec: dict) -> dict:
    flops_dev = max(rec.get("flops_per_device", 0.0), 0.0)
    # prefer the traffic-model bytes (TPU-dtype pricing; the raw
    # 'bytes accessed' double-counts XLA:CPU's bf16->f32 dot converts)
    bytes_dev = max(rec.get("traffic_bytes_per_device",
                            rec.get("bytes_per_device", 0.0)), 0.0)
    coll = rec.get("collectives", {}).get("bytes", {})
    coll_bytes = float(sum(coll.values()))
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * rec.get("n_devices", 1)
    return {
        **{k: float(f"{v:.3e}") for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": float(f"{mf:.3e}"),
        "hlo_flops_total": float(f"{hlo_total:.3e}"),
        "useful_ratio": round(mf / hlo_total, 3) if hlo_total else None,
        "bound_time_s": float(f"{max(terms.values()):.3e}"),
    }


def load_table(artifacts_dir: str, mesh: str = "16x16",
               probe_dir: str = None):
    """Prefer the unrolled cost-probe artifacts (exact FLOP counts —
    the scanned dry-run hides loop trip counts from cost analysis);
    fall back to raw dry-run records."""
    base = os.path.dirname(artifacts_dir.rstrip("/"))
    if probe_dir is None:
        # prefer the traffic-model probe artifacts when present
        for cand in ("probe_v2", "probe"):
            if os.path.isdir(os.path.join(base, cand)):
                probe_dir = os.path.join(base, cand)
                break
        else:
            probe_dir = os.path.join(base, "probe")
    rows = []
    for f in sorted(glob.glob(os.path.join(artifacts_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("mesh") != mesh or not rec.get("ok"):
            continue
        pf = os.path.join(probe_dir, f"{rec['arch']}__{rec['shape']}.json")
        source = "raw"
        if os.path.exists(pf):
            probe = json.load(open(pf))
            if probe.get("ok"):
                keys = ["flops_per_device", "bytes_per_device",
                        "collectives"]
                if "traffic_bytes_per_device" in probe:
                    keys.append("traffic_bytes_per_device")
                rec = {**rec, **{k: probe[k] for k in keys}}
                source = "probe"
        rows.append({**{k: rec[k] for k in ("arch", "shape", "mesh")},
                     **analyze_record(rec),
                     "source": source,
                     "collective_detail": rec["collectives"]["bytes"]})
    return rows


def format_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>10s} {'useful':>7s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.3e} "
            f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
            f"{r['dominant']:>10s} "
            f"{r['useful_ratio'] if r['useful_ratio'] is not None else -1:7.3f}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    rows = load_table(args.artifacts, args.mesh)
    print(format_table(rows))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} rows -> {args.json_out}")


if __name__ == "__main__":
    main()
