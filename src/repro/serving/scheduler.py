"""Request-level continuous-batching scheduler (DESIGN.md §4).

Tracks the full request lifecycle — queued (submitted, not yet
admitted), running (owns a KV slot, decoding), finished — and the
resulting effective-batch-size timeline that drives the dynamic
CPU/NPU adaptation (paper §4.1.3, Fig 13). Unlike the seed's passive
bookkeeping, requests can now *join* a running batch: `submit()`
enqueues, the engine admits per step up to the decoder's next bucket
boundary, so `batch_history` traces both growth and decay.

All times are in the engine's modeled clock (seconds of effective
latency), not wall time.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    """One generation request through its whole lifecycle."""
    uid: int
    prompt_len: int
    max_new: int
    prompt: Optional[np.ndarray] = None    # (S,) int32; None for legacy add()
    arrival_time: float = 0.0
    generated: list = field(default_factory=list)
    finished: bool = False
    # modeled-clock timestamps, filled by the engine
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time


# Backwards-compatible name: the seed called these Sequences.
Sequence = Request


class BatchScheduler:
    """Admission queue + active set + batch-size timeline."""

    def __init__(self, eos_id: Optional[int] = None):
        self.eos_id = eos_id
        self.sequences: dict[int, Request] = {}
        self.queue: deque[int] = deque()        # submitted, not admitted
        self.running: list[int] = []            # admission order
        self._next_uid = 0
        self.batch_history: list[int] = []

    # ------------------------------------------------------ lifecycle ----
    def submit(self, prompt, max_new: int,
               arrival_time: float = 0.0) -> Request:
        """Enqueue a request for admission (continuous batching)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(self._next_uid, int(prompt.shape[0]), max_new,
                      prompt=prompt, arrival_time=arrival_time)
        self._next_uid += 1
        self.sequences[req.uid] = req
        self.queue.append(req.uid)
        return req

    def add(self, prompt_len: int, max_new: int) -> Request:
        """Legacy static-batch entry: immediately running, no prompt."""
        req = Request(self._next_uid, prompt_len, max_new)
        self._next_uid += 1
        self.sequences[req.uid] = req
        self.running.append(req.uid)
        return req

    def pop_admissible(self, now: float, limit: int) -> list:
        """Dequeue up to `limit` requests that have arrived by `now`
        (FIFO; no reordering past the head — arrival order is part of
        the modeled workload)."""
        out = []
        while self.queue and len(out) < limit:
            req = self.sequences[self.queue[0]]
            if req.arrival_time > now:
                break
            self.queue.popleft()
            out.append(req)
        return out

    def admit(self, req: Request, now: float = 0.0):
        req.admit_time = now
        self.running.append(req.uid)

    def finish(self, uid: int, now: float = 0.0):
        """Force-finish (cancellation / Best-of-N early stop)."""
        req = self.sequences[uid]
        if not req.finished:
            req.finished = True
            req.finish_time = now
        if uid in self.running:
            self.running.remove(uid)

    def next_arrival(self) -> Optional[float]:
        if not self.queue:
            return None
        return self.sequences[self.queue[0]].arrival_time

    # ----------------------------------------------------- properties ----
    @property
    def active(self) -> list:
        return [self.sequences[u] for u in self.running]

    @property
    def batch_size(self) -> int:
        return len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    # ----------------------------------------------------------- step ----
    def step(self, tokens_by_uid: dict):
        """Record one generated token per active sequence; mark EOS /
        length completions. Returns uids that finished this step."""
        done = []
        for uid, tok in tokens_by_uid.items():
            seq = self.sequences[uid]
            seq.generated.append(int(tok))
            if ((self.eos_id is not None and int(tok) == self.eos_id)
                    or seq.n_generated >= seq.max_new):
                seq.finished = True
                done.append(uid)
        for uid in done:
            if uid in self.running:
                self.running.remove(uid)
        self.batch_history.append(self.batch_size)
        return done
