"""Continuous-batching scheduler: tracks live sequences, their
completion (EOS or length), and the resulting effective-batch-size
timeline that drives the dynamic CPU/NPU adaptation (paper §4.1.3,
Fig 13: Best-of-N batch shrinks as candidates finish)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Sequence:
    uid: int
    prompt_len: int
    max_new: int
    generated: list = field(default_factory=list)
    finished: bool = False

    @property
    def n_generated(self) -> int:
        return len(self.generated)


class BatchScheduler:
    """Keeps the active set; reports batch-size changes."""

    def __init__(self, eos_id: Optional[int] = None):
        self.eos_id = eos_id
        self.sequences: dict[int, Sequence] = {}
        self._next_uid = 0
        self.batch_history: list[int] = []

    def add(self, prompt_len: int, max_new: int) -> Sequence:
        seq = Sequence(self._next_uid, prompt_len, max_new)
        self._next_uid += 1
        self.sequences[seq.uid] = seq
        return seq

    @property
    def active(self) -> list:
        return [s for s in self.sequences.values() if not s.finished]

    @property
    def batch_size(self) -> int:
        return len(self.active)

    def step(self, tokens_by_uid: dict):
        """Record one generated token per active sequence; mark EOS /
        length completions. Returns uids that finished this step."""
        done = []
        for uid, tok in tokens_by_uid.items():
            seq = self.sequences[uid]
            seq.generated.append(int(tok))
            if ((self.eos_id is not None and int(tok) == self.eos_id)
                    or seq.n_generated >= seq.max_new):
                seq.finished = True
                done.append(uid)
        self.batch_history.append(self.batch_size)
        return done
