"""Request-level continuous-batching scheduler (DESIGN.md §4) and the
data-parallel replica router above it (DESIGN.md §5).

`BatchScheduler` tracks the full request lifecycle — queued (submitted,
not yet admitted), running (owns a KV slot, decoding), finished — and
the resulting effective-batch-size timeline that drives the dynamic
CPU/NPU adaptation (paper §4.1.3, Fig 13). Unlike the seed's passive
bookkeeping, requests can now *join* a running batch: `submit()`
enqueues, the engine admits per step up to the decoder's next bucket
boundary, so `batch_history` traces both growth and decay.

`ReplicaRouter` shards a request stream over the mesh's 'data' axis:
one `BatchScheduler` per replica, submits routed least-loaded with a
FIFO tiebreak, global uids mapped onto per-replica local uids. FIFO
head-of-line blocking is *per replica*: a not-yet-arrived head on one
replica never starves an arrived request on another.

All times are in the engine's modeled clock (seconds of effective
latency), not wall time.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    """One generation request through its whole lifecycle."""
    uid: int
    prompt_len: int
    max_new: int
    prompt: Optional[np.ndarray] = None    # (S,) int32; None for legacy add()
    arrival_time: float = 0.0
    generated: list = field(default_factory=list)
    finished: bool = False
    # modeled-clock timestamps, filled by the engine
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time


# Backwards-compatible name: the seed called these Sequences.
Sequence = Request


class BatchScheduler:
    """Admission queue + active set + batch-size timeline."""

    def __init__(self, eos_id: Optional[int] = None):
        self.eos_id = eos_id
        self.sequences: dict[int, Request] = {}
        self.queue: deque[int] = deque()        # submitted, not admitted
        self.running: list[int] = []            # admission order
        self._next_uid = 0
        self.batch_history: list[int] = []

    # ------------------------------------------------------ lifecycle ----
    def submit(self, prompt, max_new: int,
               arrival_time: float = 0.0) -> Request:
        """Enqueue a request for admission (continuous batching)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(self._next_uid, int(prompt.shape[0]), max_new,
                      prompt=prompt, arrival_time=arrival_time)
        self._next_uid += 1
        self.sequences[req.uid] = req
        self.queue.append(req.uid)
        return req

    def add(self, prompt_len: int, max_new: int) -> Request:
        """Legacy static-batch entry: immediately running, no prompt."""
        req = Request(self._next_uid, prompt_len, max_new)
        self._next_uid += 1
        self.sequences[req.uid] = req
        self.running.append(req.uid)
        return req

    def pop_admissible(self, now: float, limit: int) -> list:
        """Dequeue up to `limit` requests that have arrived by `now`
        (FIFO; no reordering past the head — arrival order is part of
        the modeled workload)."""
        out = []
        while self.queue and len(out) < limit:
            req = self.sequences[self.queue[0]]
            if req.arrival_time > now:
                break
            self.queue.popleft()
            out.append(req)
        return out

    def admit(self, req: Request, now: float = 0.0):
        req.admit_time = now
        self.running.append(req.uid)

    def finish(self, uid: int, now: float = 0.0):
        """Force-finish (cancellation / Best-of-N early stop).

        Removing a *running* request is a batch-decay event that
        happens between step() calls, so it must land on the
        batch-size timeline the CPU/NPU adaptation consumes —
        otherwise the recorded history skips straight from the
        pre-cancel size to whatever the next step() appends. Dequeuing
        a still-queued request changes no live batch, so it records
        nothing."""
        req = self.sequences[uid]
        if not req.finished:
            req.finished = True
            req.finish_time = now
        if uid in self.running:
            self.running.remove(uid)
            self.batch_history.append(self.batch_size)
        elif uid in self.queue:
            self.queue.remove(uid)

    def next_arrival(self) -> Optional[float]:
        if not self.queue:
            return None
        return self.sequences[self.queue[0]].arrival_time

    # ----------------------------------------------------- properties ----
    @property
    def active(self) -> list:
        return [self.sequences[u] for u in self.running]

    @property
    def batch_size(self) -> int:
        return len(self.running)

    @property
    def load(self) -> int:
        """Outstanding work: submitted-but-unfinished requests — the
        load this scheduler *reports* upward (the replica router and
        the fleet gateway both route on it)."""
        return len(self.queue) + len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    # ----------------------------------------------------------- step ----
    def step(self, tokens_by_uid: dict):
        """Record one generated token per active sequence; mark EOS /
        length completions. Returns uids that finished this step."""
        done = []
        for uid, tok in tokens_by_uid.items():
            seq = self.sequences[uid]
            seq.generated.append(int(tok))
            if ((self.eos_id is not None and int(tok) == self.eos_id)
                    or seq.n_generated >= seq.max_new):
                seq.finished = True
                done.append(uid)
        for uid in done:
            if uid in self.running:
                self.running.remove(uid)
        self.batch_history.append(self.batch_size)
        return done


# ----------------------------------------------------- replica routing ----

class ReplicaRouter:
    """Routes a request stream over per-replica BatchSchedulers
    (DESIGN.md §5 — the mesh's 'data' axis made real).

    Policy: least outstanding load (queued + running), ties broken
    FIFO over replicas (the replica assigned least recently wins), so
    an empty stream round-robins deterministically. The router owns
    the global-uid namespace — per-replica schedulers keep minting
    their own local uids, exactly as an independent single-replica
    engine would, which is what makes the dp=N engine token-identical
    to N independent dp=1 engines fed the routed sub-streams.

    It also quacks enough like a BatchScheduler (`sequences`,
    `has_work`, `batch_size`, `batch_history`) for report/benchmark
    consumers to stay replica-agnostic; `batch_history` is the merged
    timeline the owning engine appends to after every replica step
    (total running across replicas, on the shared modeled clock).
    """

    def __init__(self, schedulers):
        self.scheds: list[BatchScheduler] = list(schedulers)
        if not self.scheds:
            raise ValueError("ReplicaRouter needs at least one scheduler")
        self.assignment: dict[int, tuple] = {}   # global uid -> (r, local)
        self._global_of: dict[tuple, int] = {}   # (r, local) -> global uid
        self._next_uid = 0
        self._fifo = deque(range(len(self.scheds)))
        self.batch_history: list[int] = []

    # ------------------------------------------------------- routing ----
    def load_of(self, r: int) -> int:
        """Outstanding load: submitted-but-unfinished requests."""
        return self.scheds[r].load

    def pick_replica(self) -> int:
        """Least-loaded replica; FIFO tiebreak (least recently
        assigned). Pure read — the tiebreak queue rotates only when
        the routed submit actually lands (`bind`), so a submit that
        fails validation downstream leaves the deterministic routing
        order untouched."""
        best, best_load = None, None
        for r in self._fifo:
            load = self.load_of(r)
            if best is None or load < best_load:
                best, best_load = r, load
        return best

    def bind(self, replica: int, local_uid: int) -> int:
        """Register a routed submit; returns the global uid. Moves the
        replica to the back of the FIFO tiebreak queue."""
        uid = self._next_uid
        self._next_uid += 1
        self.assignment[uid] = (replica, local_uid)
        self._global_of[(replica, local_uid)] = uid
        self._fifo.remove(replica)
        self._fifo.append(replica)
        return uid

    def locate(self, uid: int) -> tuple:
        """Global uid -> (replica index, replica-local uid)."""
        return self.assignment[uid]

    def to_global(self, replica: int, local_uid: int) -> int:
        return self._global_of[(replica, local_uid)]

    def request(self, uid: int) -> Request:
        r, local = self.assignment[uid]
        return self.scheds[r].sequences[local]

    # ------------------------------------- scheduler-compatible views ----
    @property
    def sequences(self) -> dict:
        """Global-uid view of every routed request (submission order)."""
        return {uid: self.scheds[r].sequences[local]
                for uid, (r, local) in self.assignment.items()}

    @property
    def has_work(self) -> bool:
        return any(s.has_work for s in self.scheds)

    @property
    def load(self) -> int:
        """Fleet-facing load report: outstanding work summed over
        every replica (same contract as BatchScheduler.load)."""
        return sum(s.load for s in self.scheds)

    @property
    def batch_size(self) -> int:
        return sum(len(s.running) for s in self.scheds)

    @property
    def running(self) -> list:
        """Global uids currently decoding, replica-major order."""
        return [self._global_of[(r, u)]
                for r, s in enumerate(self.scheds) for u in s.running]
