"""Storage plane of the serving stack (DESIGN.md §2).

Everything below the activation trace lives here: the segmented
NeuronCache, the bundled ColdStore, the analytic compute/I-O pricing at
deployment-size constants (TimingProfile), the neuron-cluster pipeline
simulator, and the single-I/O-thread PrefetchExecutor that overlaps
next-layer miss fetches with current-layer pricing (paper §4.3: compute
of one matrix overlaps I/O of the next).

The plane's public surface is deliberately narrow:

    plane.step(trace, plan, batch, ctx) -> TokenStats

where `trace` is the real per-layer activation trace produced by the
data plane — (G, kc) selected cold-cluster ids for the dense families,
(E,) kept-dispatch expert counts for MoE (or the two-level (E, 1+ncc)
intra-expert form, DESIGN.md §9). The orchestrator
(serving/engine.py) never touches cache/coldstore internals.

Family genericity (DESIGN.md §8): everything family-specific — the
flat neuron space, the bundled weight tensors, the trace -> neuron-id
mapping, and per-device shard ownership — lives in a *storage view*
(`FFNStorageView` for dense/vlm, `MoEStorageView` for moe, selected by
`make_storage_view`). MoE experts are priced exactly like dense neuron
clusters: shared experts form the pinned hot prefix, routed experts
are cold clusters of d_ff neurons each — resident experts are
"hot/NPU", evicted experts pay cold-store I/O.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass

import numpy as np

from repro.core.cache import NeuronCache
from repro.core.clusters import HybridPlan
from repro.core.coldstore import ColdStore
from repro.core.io_model import StorageModel, UFS40
from repro.core.pipeline import ClusterTask, PrefetchExecutor, \
    simulate_pipeline
from repro.core.planner import HardwareProfile
from repro.quant.quantize import bundle_nbytes
from repro.quant.storage import plan_storage_dtype


# ----------------------------------------------------- family views ----

class FFNStorageView:
    """Dense-family (dense / vlm backbone) neuron space: the bundled
    (N, R, D) FFN tensor, N = cfg.d_ff, clusters of
    sparse_ffn.cluster_size neurons after the hot-first permutation."""

    def __init__(self, cfg):
        from repro.core.sparse_ffn import ffn_rows
        self.cfg = cfg
        self.n_neurons = cfg.d_ff
        self.cluster_size = cfg.sparse_ffn.cluster_size
        self.rows = ffn_rows(cfg.activation)

    def bundles(self, params):
        return [np.asarray(params["layers"]["ffn"]["w"][l])
                for l in range(self.cfg.num_layers)]

    def deploy_neurons(self, timing) -> float:
        """Deployment-size flat neuron count per layer (streamed once
        during prefill; the dense-everything compute unit)."""
        return timing.d_ff

    def deploy_prefill_neurons(self, timing) -> float:
        """Per-token FFN compute neurons during prefill."""
        return timing.d_ff

    def trace_cold_ids(self, trace_l, plan: HybridPlan):
        """Map one layer's (G, kc) group-relative cluster trace to
        global cold neuron ids (hot-first permuted space). The
        *stepped* plan's hot prefix anchors the mapping — the trace's
        cluster ids are relative to it, not to the batch-1 plan's."""
        cs, N = self.cluster_size, self.n_neurons
        n_hot = plan.n_hot
        tr = np.asarray(trace_l)
        if tr.ndim < 2:
            tr = tr.reshape(1, -1)
        G = tr.shape[0]
        nc_g = max((N - n_hot) // cs // G, 1)
        glob = tr.reshape(G, -1) + np.arange(G)[:, None] * nc_g
        ids = np.unique(glob.reshape(-1))
        cold = (n_hot
                + (ids[:, None] * cs + np.arange(cs)[None]).reshape(-1))
        return cold[cold < N]

    def hot_ids(self, trace_l, plan: HybridPlan):
        """The stepped plan's hot set — streamed through the LRU by
        systems without a pinned hot region (spec.pinned_hot=False)."""
        return np.arange(plan.n_hot)

    def warm_cold_ids(self, n_hot: int, count: int):
        """Most-frequent cold ids (hot-first space: the cold region
        starts right after the plane's pinned prefix) used to pre-warm
        each shard's cold cache."""
        return np.arange(n_hot, min(n_hot + count, self.n_neurons))

    def owner_of(self, ids, plan: HybridPlan, n_shards: int):
        """Owning device shard per neuron id, following the plan's
        compute sharding: the cold region splits by *group* (each
        device owns G/n whole groups — `_cold_path_shard_map`'s
        layout) and the hot prefix splits uniformly. Without a plan
        (or when groups don't divide), cluster-strided round-robin."""
        ids = np.asarray(ids)
        n, cs, N = n_shards, self.cluster_size, self.n_neurons
        owner = (ids // cs) % n
        if plan is not None and plan.groups >= n and plan.groups % n == 0:
            G = plan.groups
            width = max((N - plan.n_hot) // G, 1)
            g_loc = G // n
            owner = np.where(
                ids >= plan.n_hot,
                np.minimum((ids - plan.n_hot) // width, G - 1) // g_loc,
                (ids * n) // max(plan.n_hot, 1))
        return owner


class MoEStorageView:
    """MoE flat neuron space [shared experts | routed experts], each
    routed expert a contiguous f-row block (DESIGN.md §8/§9).

    Whole-expert mode (cfg.moe_intra_expert=False): one cluster per
    routed expert (cluster_size = d_ff); the trace is the per-layer
    kept-dispatch counts (E,) — an expert with count > 0 was activated
    and its d_ff neuron bundles are the fetch unit.

    Two-level mode: each expert's rows are hot-first permuted
    (prepare_params applied the plan's per-expert permutation, so flat
    id == physical row) and the cluster unit is the intra-expert
    sparse_ffn.cluster_size. The trace is (E, 1+ncc): column 0 the
    kept-dispatch counts, columns 1.. the real activation counts per
    cold cluster — only the activated experts' *active cold clusters*
    pay cold-store I/O, while every expert's hot prefix (plus the
    shared experts) is pinned via the plan's n_pinned.

    Shard ownership is expert-parallel either way: device s owns the
    contiguous ceil(E/n) routed-expert blocks the mesh 'model' axis
    assigns it (the `_moe_ep_shard_map` layout — an expert's hot and
    cold rows travel together) plus a uniform share of the pinned
    shared-expert prefix."""

    def __init__(self, cfg):
        from repro.core.sparse_ffn import ffn_rows
        self.cfg = cfg
        self.f = cfg.d_ff
        self.E = cfg.num_experts
        self.n_shared = cfg.num_shared_experts
        self.S = cfg.num_shared_experts * cfg.d_ff
        self.n_neurons = cfg.moe_flat_neurons
        self.intra = bool(cfg.moe_intra_expert)
        self.cluster_size = cfg.sparse_ffn.cluster_size if self.intra \
            else cfg.d_ff
        self.rows = ffn_rows(cfg.activation)

    def bundles(self, params):
        moe = params["layers"]["moe"]
        ex = np.asarray(moe["experts"])             # (L, E, f, R, D)
        L, E, f, R, D = ex.shape
        flat = ex.reshape(L, E * f, R, D)
        if "shared" in moe:
            sh = np.asarray(moe["shared"]["w"])     # (L, n_sh*f, R, D)
            flat = np.concatenate([sh, flat], axis=1)
        return [flat[l] for l in range(L)]

    def deploy_neurons(self, timing) -> float:
        # timing.d_ff is the deployment per-expert width; the expert
        # count is the data plane's (only widths rescale, like layers)
        return timing.d_ff * (self.n_shared + self.E)

    def deploy_prefill_neurons(self, timing) -> float:
        # per-token prefill compute: shared + routed top-k experts
        return timing.d_ff * (self.n_shared + self.cfg.experts_per_token)

    def _expert_hot(self, plan: HybridPlan) -> int:
        return plan.n_expert_hot if plan is not None else 0

    def trace_cold_ids(self, trace_l, plan: HybridPlan):
        """Flat cold neuron ids for one layer's trace. A trace whose
        shape disagrees with the stepped plan (wrong expert count,
        wrong cold-cluster count for the plan's n_expert_hot) raises —
        a shape mismatch means the data plane and the plan disagree
        about the neuron space, and silently dropping ids would mask
        it as under-priced I/O."""
        tr = np.asarray(trace_l)
        S, f, E, cs = self.S, self.f, self.E, self.cluster_size
        n_hot_e = self._expert_hot(plan)
        if n_hot_e:
            ncc = (f - n_hot_e) // cs
            if tr.shape != (E, 1 + ncc):
                raise ValueError(
                    f"two-level MoE trace shape {tr.shape} does not "
                    f"match the stepped plan: expected (E, 1+ncc) = "
                    f"({E}, {1 + ncc}) for n_expert_hot={n_hot_e}, "
                    f"cluster_size={cs}, d_ff={f}")
            act_e, act_c = np.nonzero(tr[:, 1:] > 0)
            ids = (S + act_e[:, None] * f + n_hot_e
                   + act_c[:, None] * cs
                   + np.arange(cs)[None]).reshape(-1)
        else:
            counts = tr.reshape(-1)
            if counts.shape[0] != E:
                raise ValueError(
                    f"MoE expert trace has {counts.shape[0]} entries "
                    f"for {E} experts — the trace and the plan "
                    f"disagree about the expert space")
            act = np.nonzero(counts > 0)[0]
            ids = (S + act[:, None] * f
                   + np.arange(f)[None]).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_neurons):
            raise ValueError(
                f"MoE trace maps outside the flat neuron space "
                f"[0, {self.n_neurons}) — ids span "
                f"[{ids.min()}, {ids.max()}]")
        return ids

    def hot_ids(self, trace_l, plan: HybridPlan):
        """The stepped hot set for systems without a pinned region:
        the shared prefix plus, in two-level mode, the hot rows of the
        experts the trace shows activated."""
        n_hot_e = self._expert_hot(plan)
        if not n_hot_e:
            return np.arange(self.S)
        tr = np.asarray(trace_l)
        act = np.nonzero(tr[:, 0] > 0)[0]
        hot = (self.S + act[:, None] * self.f
               + np.arange(n_hot_e)[None]).reshape(-1)
        return np.concatenate([np.arange(self.S), hot])

    def warm_cold_ids(self, n_hot: int, count: int):
        """Pre-warm ids for the cold caches. Whole-expert mode mirrors
        the dense view (the cold region is flat after the shared
        prefix); two-level mode interleaves experts offset-major — the
        hot-first permutation makes the first cold cluster of *every*
        expert more frequent than any second cluster."""
        if not self.intra:
            return np.arange(n_hot, min(n_hot + count, self.n_neurons))
        # derive the per-expert pinned width from the plane's pinned
        # prefix (n_hot = S + E*n_hot_e, possibly capacity-capped)
        n_hot_e = max((n_hot - self.S) // max(self.E, 1), 0)
        offs = np.arange(self.f - n_hot_e)
        grid = (self.S + np.arange(self.E)[None, :] * self.f + n_hot_e
                + offs[:, None])                    # (n_cold_e, E)
        return grid.reshape(-1)[:count]

    def owner_of(self, ids, plan: HybridPlan, n_shards: int):
        """Owning shard per flat id, following `_moe_ep_shard_map`:
        contiguous expert blocks — ceil(E/n) experts per shard, the
        last block clamped when E doesn't divide (so the non-divisible
        fallback agrees with the divisible layout instead of
        round-robining the pinned shared prefix) — and a uniform split
        of the shared-expert prefix."""
        ids = np.asarray(ids)
        n, S = n_shards, self.S
        e_loc = max(-(-self.E // n), 1)             # ceil: clamped blocks
        expert = (ids - S) // self.f
        return np.where(
            ids >= S,
            np.minimum(expert // e_loc, n - 1),
            (ids * n) // max(S, 1))


_VIEW_FAMILIES = {"dense": FFNStorageView, "vlm": FFNStorageView,
                  "moe": MoEStorageView}


def make_storage_view(cfg):
    """Family-keyed storage view (the plane half of the serving
    family registry — serving/families.py holds the data-plane half)."""
    if cfg.family not in _VIEW_FAMILIES:
        raise ValueError(
            f"no storage view for family {cfg.family!r}; "
            f"storable families: {sorted(_VIEW_FAMILIES)}")
    return _VIEW_FAMILIES[cfg.family](cfg)


@dataclass(frozen=True)
class TimingProfile:
    """Cost constants for the storage plane.

    The engine's data plane runs the (reduced) model for real; the
    storage plane prices compute and I/O at the *deployment-size*
    model's constants so compute/I-O ratios land in the paper's regime
    (e.g. bamboo-7b FP16: 24KB Gate-Up-Down bundles — exactly §4.4).
    Defaults derive from the engine's own config.
    """
    d_model: int
    d_ff: int
    num_heads: int
    num_kv_heads: int
    d_head: int
    num_layers: int
    rows: int = 3
    itemsize: int = 2

    @classmethod
    def from_config(cls, cfg, rows):
        return cls(d_model=cfg.d_model, d_ff=cfg.d_ff,
                   num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                   d_head=cfg.d_head, num_layers=cfg.num_layers, rows=rows)

    @property
    def bundle_bytes(self):
        return self.rows * self.d_model * self.itemsize


@dataclass
class ShardStats:
    """Per-device slice of one decode step's storage accounting."""
    compute_s: float
    io_s: float
    effective_s: float
    cache_hit_rate: float
    n_miss: int


@dataclass
class TokenStats:
    compute_s: float       # critical-path (max-over-shards) compute
    io_s: float            # raw (unpipelined) I/O demand, worst shard
    effective_s: float     # after pipeline composition, max over shards
    cache_hit_rate: float  # aggregate over every shard's cache
    n_miss: int            # summed across shards
    batch: int
    n_shards: int = 1
    io_total_s: float = 0.0   # summed raw demand (aggregate traffic)
    shards: list = None       # per-shard ShardStats when n_shards > 1
    # 'data'-axis row that produced this step. Each replica owns a
    # whole StoragePlane (per-replica caches/channels are the same
    # per-shard machinery at dp granularity), so the plane itself
    # never sets this; the routing engine annotates it when merging
    # per-replica timelines into one ServeReport (DESIGN.md §5).
    replica: int = 0


class StoragePlane:
    """Cache + cold store + pipeline pricing behind one `step()` call."""

    def __init__(self, cfg, params, plan, *, spec, storage: StorageModel
                 = UFS40, offload_ratio: float = 0.5,
                 hw: HardwareProfile = None, timing: TimingProfile = None,
                 n_compute_workers: int = 4, prefetch: bool = True,
                 n_shards: int = 1, n_replicas: int = 1, view=None):
        self.cfg = cfg
        self.spec = spec
        self.hw = hw or plan.hardware
        self.n_workers = n_compute_workers
        self.offload_ratio = offload_ratio
        # Data-parallel accounting (DESIGN.md §5/§9): the host memory
        # budget is one per machine, not one per replica — a plane that
        # serves one of n_replicas 'data'-axis rows gets a 1/n share of
        # the resident-neuron budget, the same way the 'model' axis
        # splits each cache below. Total residency across replicas
        # therefore never exceeds the single-engine budget.
        self.n_replicas = max(int(n_replicas), 1)
        # Tensor-parallel accounting: device s owns the contiguous
        # neuron slice [s*N/n, (s+1)*N/n) — the same row split the mesh
        # 'model' axis applies to the bundled FFN tensor — with its own
        # NeuronCache slice and its own storage channel.
        self.n_shards = max(int(n_shards), 1)

        # family view: flat neuron space, bundles, trace mapping,
        # shard ownership (FFNStorageView / MoEStorageView)
        self.view = view or make_storage_view(cfg)
        self.cs = self.view.cluster_size
        N = self.view.n_neurons
        self.N = N
        self.timing = timing or TimingProfile.from_config(
            cfg, self.view.rows)
        # scale factors: storage-plane costs priced at deployment size
        # while traces come from the (possibly reduced) data-plane model
        self.neuron_scale = self.view.deploy_neurons(self.timing) / N
        self.layer_scale = self.timing.num_layers / cfg.num_layers
        bundles = self.view.bundles(params)
        # Storage-dtype pricing (§7.6 + §4.4): the plan declares how
        # cold bundles live on the slow tier; every byte count below —
        # cold-store reads, cache residency, prefill streaming — prices
        # the declared dtype at deployment-size constants. fp16 keeps
        # the legacy unpadded rows*d_model*itemsize accounting exactly.
        self.storage_dtype = plan_storage_dtype(plan)
        qb = bundle_nbytes(self.timing.d_model, self.storage_dtype,
                           rows=self.timing.rows,
                           itemsize=self.timing.itemsize)
        self.coldstore = ColdStore(bundles, storage=storage,
                                   two_phase=spec.two_phase,
                                   block_size=24576 if spec.use_bundling
                                   else 4096,
                                   bundle_bytes_override=qb,
                                   count_scale=self.neuron_scale)
        self.bundle_bytes = self.coldstore.bundle_bytes()

        # memory budget: resident = (1-offload)*N neurons per layer.
        # With a pinned hot region (§4.2, PowerInfer-2) the budget splits
        # between hot prefix and cold LRU (hot may not starve cold below
        # its per-token working set). Baseline systems stream *all*
        # activated neurons (hot included) through one LRU cache, with
        # bundling-redundancy derating (spec.cache_efficiency).
        resident = int(N * (1.0 - offload_ratio)) // self.n_replicas
        plan1 = plan.plan_for_batch(1)
        # Quantized cold bundles stretch the same host-byte budget over
        # fp_bytes/q_bytes x more cold neurons (~3-4x at int4-mixed);
        # the pinned hot prefix stays fp on the NPU, so only the cold
        # LRU scales — capped at the neurons that actually exist.
        ratio = self.timing.bundle_bytes / self.bundle_bytes
        if spec.pinned_hot:
            hot_cap = (resident // 2) // self.cs * self.cs
            # two-level MoE plans pin every expert's hot prefix
            # (plan.n_pinned), not just the per-step computed hot
            self.n_hot = min(plan1.resident_hot, max(hot_cap, self.cs))
            cold_per_layer = min(
                int(max(resident - self.n_hot, self.cs) * ratio),
                max(N - self.n_hot, self.cs))
            cold_capacity = cold_per_layer * cfg.num_layers
        else:
            self.n_hot = 0
            cold_capacity = min(
                int(max(int(resident * spec.cache_efficiency),
                        self.cs) * ratio), N) * cfg.num_layers
        # the hot prefix is pinned (fixed region); the LRU capacity below
        # is entirely the cold region. One segmented cache *per device
        # shard*, each a 1/n miniature of the single-device cache:
        # ownership follows the compute sharding (every device owns its
        # share of the hot prefix plus its own cold groups — see
        # _split_by_owner), so cold traffic splits uniformly and so
        # does capacity. Per-device miss traffic shrinks with the mesh
        # instead of replicating the whole LRU.
        self.caches = [
            NeuronCache(cfg.num_layers, N, self.cs,
                        capacity_neurons=max(
                            cold_capacity // self.n_shards, self.cs),
                        hot_fraction=0.0,
                        bytes_per_neuron=self.bundle_bytes)
            for _ in range(self.n_shards)]
        # warm each shard's cold cache with its most-frequent cold
        # slice (the family view orders the cold space — flat after
        # the pinned prefix for dense/whole-expert, expert-interleaved
        # for two-level MoE)
        per_layer = cold_capacity // cfg.num_layers
        for l in range(cfg.num_layers):
            ids = self.view.warm_cold_ids(self.n_hot, per_layer)
            for s, part in enumerate(self._split_by_owner(ids, plan1)):
                self.caches[s].admit_cold(l, list(part))
        for c in self.caches:
            c.stats.reset()
        self.coldstore.reset_stats()
        # ONE I/O thread (single UFS command queue, §4.3): layer l+1's
        # misses are fetched while layer l is being priced. The thread
        # is non-daemon, so tie its shutdown to this plane's lifetime —
        # engines are created freely in benchmarks and must not
        # accumulate idle executors.
        self.prefetcher = PrefetchExecutor() if prefetch else None
        if self.prefetcher is not None:
            self._finalizer = weakref.finalize(
                self, PrefetchExecutor.shutdown, self.prefetcher)

    # ------------------------------------------------- shard ownership ----
    @property
    def cache(self):
        """Shard 0's cache — the whole cache when n_shards == 1."""
        return self.caches[0]

    @property
    def resident_capacity_neurons(self) -> int:
        """Modeled resident footprint of this plane in neurons: the
        pinned hot prefix across every layer plus each shard's cold
        LRU capacity. Replica budgeting (DESIGN.md §9) guarantees the
        sum over a routed engine's replicas stays within one engine's
        budget."""
        return self.n_hot * self.cfg.num_layers \
            + sum(c.capacity for c in self.caches)

    def _split_by_owner(self, neuron_ids, plan: HybridPlan = None):
        """Partition global neuron ids by owning device shard,
        following the compute sharding the family view declares —
        dense: the plan's G/n cold groups per device + uniform hot
        split (`_cold_path_shard_map`'s layout, so per-step cold
        traffic is balanced by construction); moe: E/n contiguous
        routed experts per device (`_moe_ep_shard_map`'s layout).
        Bucket switches move the hot/cold boundary, so a neuron near
        it can migrate shards and miss once in its new cache — the
        modeled cost of the resharding collective the mesh pays on an
        executable swap."""
        ids = np.asarray(neuron_ids)
        n = self.n_shards
        if n == 1:
            return [ids]
        owner = self.view.owner_of(ids, plan, n)
        return [ids[owner == s] for s in range(n)]

    # ---------------------------------------------------- timing model ----
    def _ffn_flops_token(self, plan: HybridPlan):
        t = self.timing
        per_neuron = 2 * t.rows * t.d_model
        hot = plan.n_hot * self.neuron_scale * per_neuron
        cold = plan.total_cold * self.neuron_scale * per_neuron
        return hot, cold

    def _attn_flops_token(self, ctx_len: float):
        t = self.timing
        return 4 * t.num_heads * t.d_head * ctx_len \
            + 4 * t.d_model * (t.num_heads + 2 * t.num_kv_heads) * t.d_head

    def _attn_frac(self) -> float:
        """Attention's per-device share: heads shard over 'model' when
        they divide (the KV arena's layout); otherwise replicated."""
        if self.n_shards > 1 and self.timing.num_heads % self.n_shards == 0 \
                and self.timing.num_kv_heads % self.n_shards == 0:
            return 1.0 / self.n_shards
        return 1.0

    def _compute_time(self, plan: HybridPlan, batch: int, ctx_len: float,
                      shard_frac: float = 1.0):
        """Per-device compute seconds: FFN flops scale with the device's
        neuron-slice fraction, attention with the head split."""
        hot_f, cold_f = self._ffn_flops_token(plan)
        hot_f, cold_f = hot_f * shard_frac, cold_f * shard_frac
        L = self.timing.num_layers
        attn = self._attn_flops_token(ctx_len) * L * batch \
            * (self._attn_frac() if shard_frac < 1.0 else 1.0)
        if self.spec.hybrid_engines:
            # hot on the dense engine, cold on the sparse path, overlapped
            t_ffn = max(hot_f / self.hw.dense_engine_flops,
                        cold_f / self.hw.sparse_engine_flops) * L * batch
        elif self.spec.use_predictor:
            t_ffn = (hot_f + cold_f) / self.hw.sparse_engine_flops * L * batch
        else:
            # dense everything (llama.cpp): every flat neuron (all
            # experts, for moe) on the sparse engine
            t_ffn = (self.view.deploy_neurons(self.timing) * shard_frac
                     * 2 * self.timing.rows * self.timing.d_model) \
                / self.hw.sparse_engine_flops * L * batch
        return t_ffn + attn / self.hw.dense_engine_flops

    def prefill_cost(self, prompt_len: int, batch: int = 1) -> float:
        """Modeled prefill seconds (§4.1.1: NPU-centric dense prefill;
        every non-resident layer slice streams once at sequential
        bandwidth, overlapped with dense compute). Each device streams
        and computes only its neuron slice (for moe: its expert slice
        streams, but per-token compute touches only shared + top-k)."""
        t = self.timing
        flat = self.view.deploy_neurons(t)
        n_off = int(flat * self.offload_ratio) // self.n_shards
        io = self.coldstore.storage.read_time(
            n_off * self.bundle_bytes * t.num_layers, 524288, random=False)
        ffn = self.view.deploy_prefill_neurons(t) * 2 * t.rows * t.d_model \
            / self.n_shards
        attn = self._attn_flops_token(prompt_len / 2.0) * self._attn_frac()
        comp = (ffn + attn) * t.num_layers * prompt_len * batch \
            / self.hw.dense_engine_flops
        return max(io, comp)

    # ------------------------------------------------------- pricing ----
    def _fetch_shard(self, l: int, misses) -> float:
        """Cold-store I/O for one shard's misses in one layer. Returns
        modeled seconds on that shard's storage channel."""
        spec = self.spec
        if not len(misses):
            return 0.0
        misses = list(misses)
        if spec.use_bundling:
            gate_active = np.random.default_rng(l).random(
                len(misses)) < 0.8 if spec.two_phase else None
            return self.coldstore.fetch(l, misses, gate_active).io_time
        # unbundled: R scattered 4KB-class reads per neuron
        # (paper §4.4 — this is what bundling removes)
        R = self.timing.rows
        per = self.bundle_bytes // R
        nbytes = int(per * len(misses) * R * self.neuron_scale)
        io_l = self.coldstore.storage.read_time(
            nbytes, min(4096, per), random=True)
        self.coldstore.total_bytes += nbytes
        self.coldstore.total_io_time += io_l
        return io_l

    def _fetch_layer(self, l: int, misses_per_shard) -> list:
        """One layer's miss fetches, every shard (runs as one job on
        the I/O thread when prefetch is on). Returns per-shard modeled
        seconds — each device has its own storage channel, so the times
        are independent even though the modeled fetches run serially."""
        return [self._fetch_shard(l, m) for m in misses_per_shard]

    def _trace_neuron_ids(self, trace_l, plan: HybridPlan):
        """Map one layer's activation trace to global cold neuron ids
        — the family view interprets its own trace shape against the
        *stepped* plan (dense: (G, kc) group-relative cluster ids;
        moe: (E,) kept-dispatch counts or the two-level (E, 1+ncc)
        form). A trace that disagrees with the plan's shape raises
        instead of silently under-pricing."""
        return self.view.trace_cold_ids(trace_l, plan)

    def step(self, trace, plan: HybridPlan, batch: int,
             ctx_len: float) -> TokenStats:
        """Price one decode step given the real cluster trace
        `trace` (L, G, kc) from the data plane.

        With n_shards > 1 every phase is per-device: each shard looks
        up its own cache slice, fetches its own misses on its own
        channel, and runs its own cluster pipeline over its share of
        the compute; the step's effective time is the slowest shard
        (the psum barrier at each layer's output keeps devices in
        lock-step at layer granularity)."""
        cfg, spec = self.cfg, self.spec
        L = cfg.num_layers
        cs = self.cs
        S = self.n_shards
        comp_shard = self._compute_time(plan, batch, ctx_len,
                                        shard_frac=1.0 / S)
        base = [(c.stats.hits, c.stats.misses) for c in self.caches]

        # Phase 1 — cache lookups, strictly in layer order (the LRU
        # state sequence is part of the modeled behavior), shard-split.
        per_layer = []
        for l in range(L):
            if spec.use_predictor:
                cold_ids = self._trace_neuron_ids(trace[l], plan)
                if spec.pinned_hot:
                    neuron_ids = cold_ids       # hot prefix pinned: no I/O
                else:
                    # activated set = hot set + selected cold, all
                    # streamed through the single cache
                    neuron_ids = np.concatenate(
                        [self.view.hot_ids(trace[l], plan), cold_ids])
            else:
                neuron_ids = np.arange(self.N)       # dense: everything
            parts = self._split_by_owner(neuron_ids, plan)
            misses_ps, n_ids_ps = [], []
            for s, part in enumerate(parts):
                if spec.use_cache:
                    _, misses = self.caches[s].lookup_cold(l, part)
                    self.caches[s].admit_cold(l, misses)
                else:
                    misses = list(part)
                misses_ps.append(misses)
                n_ids_ps.append(len(part))
            per_layer.append((n_ids_ps, misses_ps))

        # Phase 2 — fetch + price. With the prefetcher, layer l+1's
        # misses are submitted to the I/O thread before layer l's fetch
        # is consumed, so real data movement overlaps pricing; the
        # modeled per-layer I/O times are identical either way.
        futures = {}
        if self.prefetcher is not None:
            futures[0] = self.prefetcher.submit(
                self._fetch_layer, 0, per_layer[0][1])
        tasks = [[] for _ in range(S)]
        io_raw = [0.0] * S
        comp_per_matrix = comp_shard / L
        for l in range(L):
            n_ids_ps, misses_ps = per_layer[l]
            if self.prefetcher is not None:
                if l + 1 < L:
                    futures[l + 1] = self.prefetcher.submit(
                        self._fetch_layer, l + 1, per_layer[l + 1][1])
                io_ps = futures.pop(l).result()
            else:
                io_ps = self._fetch_layer(l, misses_ps)
            for s in range(S):
                # price the trace's L_reduced layers at deployment depth
                io_l = io_ps[s] * self.layer_scale
                io_raw[s] += io_l
                n_miss_clusters = max(len(misses_ps[s]) // cs, 0)
                n_clusters = max(n_ids_ps[s] // cs, 1)
                comp_c = comp_per_matrix / n_clusters
                io_c = io_l / max(n_miss_clusters, 1) if io_l else 0.0
                for c in range(n_clusters):
                    tasks[s].append(ClusterTask(
                        l, c, comp_c,
                        io_c if c < n_miss_clusters else 0.0))

        shards = []
        for s in range(S):
            if spec.pipeline == "none":
                eff_s = comp_shard + io_raw[s]
            else:
                eff_s = simulate_pipeline(tasks[s], n_compute=self.n_workers,
                                          policy=spec.pipeline).makespan
            d_hits = self.caches[s].stats.hits - base[s][0]
            d_miss = self.caches[s].stats.misses - base[s][1]
            seen = d_hits + d_miss
            shards.append(ShardStats(
                compute_s=comp_shard, io_s=io_raw[s], effective_s=eff_s,
                cache_hit_rate=1.0 if seen == 0 else d_hits / seen,
                n_miss=d_miss))
        tot_hits = sum(self.caches[s].stats.hits - base[s][0]
                       for s in range(S))
        tot_miss = sum(sh.n_miss for sh in shards)
        seen = tot_hits + tot_miss
        return TokenStats(
            compute_s=comp_shard,
            io_s=max(sh.io_s for sh in shards),
            effective_s=max(sh.effective_s for sh in shards),
            cache_hit_rate=1.0 if seen == 0 else float(tot_hits / seen),
            n_miss=tot_miss, batch=batch, n_shards=S,
            io_total_s=float(sum(sh.io_s for sh in shards)),
            shards=shards if S > 1 else None)

    def close(self):
        if self.prefetcher is not None:
            self.prefetcher.shutdown()
            self.prefetcher = None
