"""Storage plane of the serving stack (DESIGN.md §2).

Everything below the activation trace lives here: the segmented
NeuronCache, the bundled ColdStore, the analytic compute/I-O pricing at
deployment-size constants (TimingProfile), the neuron-cluster pipeline
simulator, and the single-I/O-thread PrefetchExecutor that overlaps
next-layer miss fetches with current-layer pricing (paper §4.3: compute
of one matrix overlaps I/O of the next).

The plane's public surface is deliberately narrow:

    plane.step(trace, plan, batch, ctx) -> TokenStats

where `trace` is the real per-layer cold-cluster selection (L, G, kc)
produced by the data plane. The orchestrator (serving/engine.py) never
touches cache/coldstore internals.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass

import numpy as np

from repro.core.cache import NeuronCache
from repro.core.clusters import HybridPlan
from repro.core.coldstore import ColdStore
from repro.core.io_model import StorageModel, UFS40
from repro.core.pipeline import ClusterTask, PrefetchExecutor, \
    simulate_pipeline
from repro.core.planner import HardwareProfile


@dataclass(frozen=True)
class TimingProfile:
    """Cost constants for the storage plane.

    The engine's data plane runs the (reduced) model for real; the
    storage plane prices compute and I/O at the *deployment-size*
    model's constants so compute/I-O ratios land in the paper's regime
    (e.g. bamboo-7b FP16: 24KB Gate-Up-Down bundles — exactly §4.4).
    Defaults derive from the engine's own config.
    """
    d_model: int
    d_ff: int
    num_heads: int
    num_kv_heads: int
    d_head: int
    num_layers: int
    rows: int = 3
    itemsize: int = 2

    @classmethod
    def from_config(cls, cfg, rows):
        return cls(d_model=cfg.d_model, d_ff=cfg.d_ff,
                   num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                   d_head=cfg.d_head, num_layers=cfg.num_layers, rows=rows)

    @property
    def bundle_bytes(self):
        return self.rows * self.d_model * self.itemsize


@dataclass
class TokenStats:
    compute_s: float
    io_s: float            # raw (unpipelined) I/O demand
    effective_s: float     # after pipeline composition
    cache_hit_rate: float
    n_miss: int
    batch: int


class StoragePlane:
    """Cache + cold store + pipeline pricing behind one `step()` call."""

    def __init__(self, cfg, params, plan, *, spec, storage: StorageModel
                 = UFS40, offload_ratio: float = 0.5,
                 hw: HardwareProfile = None, timing: TimingProfile = None,
                 n_compute_workers: int = 4, prefetch: bool = True):
        self.cfg = cfg
        self.spec = spec
        self.hw = hw or plan.hardware
        self.n_workers = n_compute_workers
        self.offload_ratio = offload_ratio

        sc = cfg.sparse_ffn
        self.cs = sc.cluster_size
        N = cfg.d_ff
        self.N = N
        from repro.core.sparse_ffn import ffn_rows
        self.timing = timing or TimingProfile.from_config(
            cfg, ffn_rows(cfg.activation))
        # scale factors: storage-plane costs priced at deployment size
        # while traces come from the (possibly reduced) data-plane model
        self.neuron_scale = self.timing.d_ff / N
        self.layer_scale = self.timing.num_layers / cfg.num_layers
        bundles = [np.asarray(params["layers"]["ffn"]["w"][l])
                   for l in range(cfg.num_layers)]
        self.coldstore = ColdStore(bundles, storage=storage,
                                   two_phase=spec.two_phase,
                                   block_size=24576 if spec.use_bundling
                                   else 4096,
                                   bundle_bytes_override=self.timing.bundle_bytes,
                                   count_scale=self.neuron_scale)
        self.bundle_bytes = self.coldstore.bundle_bytes()

        # memory budget: resident = (1-offload)*N neurons per layer.
        # With a pinned hot region (§4.2, PowerInfer-2) the budget splits
        # between hot prefix and cold LRU (hot may not starve cold below
        # its per-token working set). Baseline systems stream *all*
        # activated neurons (hot included) through one LRU cache, with
        # bundling-redundancy derating (spec.cache_efficiency).
        resident = int(N * (1.0 - offload_ratio))
        plan1 = plan.plan_for_batch(1)
        if spec.pinned_hot:
            hot_cap = (resident // 2) // self.cs * self.cs
            self.n_hot = min(plan1.n_hot, max(hot_cap, self.cs))
            cold_capacity = max(resident - self.n_hot, self.cs) \
                * cfg.num_layers
        else:
            self.n_hot = 0
            cold_capacity = max(int(resident * spec.cache_efficiency),
                                self.cs) * cfg.num_layers
        # the per-token activated set always includes the plan's hot
        # prefix; pinned systems never do I/O for it.
        self.plan_hot = plan1.n_hot
        # the hot prefix is pinned (fixed region); the LRU capacity below
        # is entirely the cold region.
        self.cache = NeuronCache(cfg.num_layers, N, self.cs,
                                 capacity_neurons=cold_capacity,
                                 hot_fraction=0.0,
                                 bytes_per_neuron=self.bundle_bytes)
        # warm the cold cache with the most-frequent cold neurons
        per_layer = cold_capacity // cfg.num_layers
        for l in range(cfg.num_layers):
            ids = range(self.n_hot, min(self.n_hot + per_layer, N))
            self.cache.admit_cold(l, list(ids))
        self.cache.stats.reset()
        self.coldstore.reset_stats()
        # ONE I/O thread (single UFS command queue, §4.3): layer l+1's
        # misses are fetched while layer l is being priced. The thread
        # is non-daemon, so tie its shutdown to this plane's lifetime —
        # engines are created freely in benchmarks and must not
        # accumulate idle executors.
        self.prefetcher = PrefetchExecutor() if prefetch else None
        if self.prefetcher is not None:
            self._finalizer = weakref.finalize(
                self, PrefetchExecutor.shutdown, self.prefetcher)

    # ---------------------------------------------------- timing model ----
    def _ffn_flops_token(self, plan: HybridPlan):
        t = self.timing
        per_neuron = 2 * t.rows * t.d_model
        hot = plan.n_hot * self.neuron_scale * per_neuron
        cold = plan.total_cold * self.neuron_scale * per_neuron
        return hot, cold

    def _attn_flops_token(self, ctx_len: float):
        t = self.timing
        return 4 * t.num_heads * t.d_head * ctx_len \
            + 4 * t.d_model * (t.num_heads + 2 * t.num_kv_heads) * t.d_head

    def _compute_time(self, plan: HybridPlan, batch: int, ctx_len: float):
        hot_f, cold_f = self._ffn_flops_token(plan)
        L = self.timing.num_layers
        attn = self._attn_flops_token(ctx_len) * L * batch
        if self.spec.hybrid_engines:
            # hot on the dense engine, cold on the sparse path, overlapped
            t_ffn = max(hot_f / self.hw.dense_engine_flops,
                        cold_f / self.hw.sparse_engine_flops) * L * batch
        elif self.spec.use_predictor:
            t_ffn = (hot_f + cold_f) / self.hw.sparse_engine_flops * L * batch
        else:
            # dense everything (llama.cpp): all N neurons on sparse engine
            t_ffn = (self.timing.d_ff * 2 * self.timing.rows
                     * self.timing.d_model) \
                / self.hw.sparse_engine_flops * L * batch
        return t_ffn + attn / self.hw.dense_engine_flops

    def prefill_cost(self, prompt_len: int, batch: int = 1) -> float:
        """Modeled prefill seconds (§4.1.1: NPU-centric dense prefill;
        every non-resident layer slice streams once at sequential
        bandwidth, overlapped with dense compute)."""
        t = self.timing
        n_off = int(t.d_ff * self.offload_ratio)
        io = self.coldstore.storage.read_time(
            n_off * t.bundle_bytes * t.num_layers, 524288, random=False)
        ffn = t.d_ff * 2 * t.rows * t.d_model
        attn = self._attn_flops_token(prompt_len / 2.0)
        comp = (ffn + attn) * t.num_layers * prompt_len * batch \
            / self.hw.dense_engine_flops
        return max(io, comp)

    # ------------------------------------------------------- pricing ----
    def _fetch_layer(self, l: int, misses) -> float:
        """Cold-store I/O for one layer's misses (runs on the I/O
        thread when prefetch is enabled). Returns modeled seconds."""
        spec = self.spec
        if not misses:
            return 0.0
        if spec.use_bundling:
            gate_active = np.random.default_rng(l).random(
                len(misses)) < 0.8 if spec.two_phase else None
            return self.coldstore.fetch(l, misses, gate_active).io_time
        # unbundled: R scattered 4KB-class reads per neuron
        # (paper §4.4 — this is what bundling removes)
        R = self.timing.rows
        per = self.bundle_bytes // R
        nbytes = int(per * len(misses) * R * self.neuron_scale)
        io_l = self.coldstore.storage.read_time(
            nbytes, min(4096, per), random=True)
        self.coldstore.total_bytes += nbytes
        self.coldstore.total_io_time += io_l
        return io_l

    def step(self, trace, plan: HybridPlan, batch: int,
             ctx_len: float) -> TokenStats:
        """Price one decode step given the real cluster trace
        `trace` (L, G, kc) from the data plane."""
        cfg, spec = self.cfg, self.spec
        L = cfg.num_layers
        cs = self.cs
        comp_total = self._compute_time(plan, batch, ctx_len)
        h0, m0 = self.cache.stats.hits, self.cache.stats.misses

        # Phase 1 — cache lookups, strictly in layer order (the LRU
        # state sequence is part of the modeled behavior).
        per_layer = []
        for l in range(L):
            if spec.use_predictor:
                ids = np.unique(np.asarray(trace[l]).reshape(-1))
                cold_ids = (self.plan_hot
                            + (ids[:, None] * cs
                               + np.arange(cs)[None]).reshape(-1))
                cold_ids = cold_ids[cold_ids < self.N]
                if spec.pinned_hot:
                    neuron_ids = cold_ids       # hot prefix pinned: no I/O
                else:
                    # activated set = hot prefix + selected cold, all
                    # streamed through the single cache
                    neuron_ids = np.concatenate(
                        [np.arange(self.plan_hot), cold_ids])
            else:
                neuron_ids = np.arange(self.N)       # dense: everything
            if spec.use_cache:
                hits, misses = self.cache.lookup_cold(l, neuron_ids)
                self.cache.admit_cold(l, misses)
            else:
                hits, misses = [], list(neuron_ids)
            per_layer.append((len(neuron_ids), misses))

        # Phase 2 — fetch + price. With the prefetcher, layer l+1's
        # misses are submitted to the I/O thread before layer l's fetch
        # is consumed, so real data movement overlaps pricing; the
        # modeled per-layer I/O times are identical either way.
        futures = {}
        if self.prefetcher is not None:
            futures[0] = self.prefetcher.submit(
                self._fetch_layer, 0, per_layer[0][1])
        tasks = []
        io_raw = 0.0
        comp_per_matrix = comp_total / L
        for l in range(L):
            n_ids, misses = per_layer[l]
            if self.prefetcher is not None:
                if l + 1 < L:
                    futures[l + 1] = self.prefetcher.submit(
                        self._fetch_layer, l + 1, per_layer[l + 1][1])
                io_l = futures.pop(l).result()
            else:
                io_l = self._fetch_layer(l, misses)
            # price the trace's L_reduced layers at deployment depth
            io_l *= self.layer_scale
            io_raw += io_l
            n_miss_clusters = max(len(misses) // cs, 0)
            n_clusters = max(n_ids // cs, 1)
            comp_c = comp_per_matrix / n_clusters
            io_c = io_l / max(n_miss_clusters, 1) if io_l else 0.0
            for c in range(n_clusters):
                tasks.append(ClusterTask(l, c, comp_c,
                                         io_c if c < n_miss_clusters else 0.0))

        if spec.pipeline == "none":
            eff = comp_total + io_raw
        else:
            res = simulate_pipeline(tasks, n_compute=self.n_workers,
                                    policy=spec.pipeline)
            eff = res.makespan
        d_hits = self.cache.stats.hits - h0
        d_miss = self.cache.stats.misses - m0
        seen = d_hits + d_miss
        hr = 1.0 if seen == 0 else d_hits / seen
        return TokenStats(compute_s=comp_total, io_s=io_raw,
                          effective_s=eff, cache_hit_rate=float(hr),
                          n_miss=d_miss, batch=batch)

    def close(self):
        if self.prefetcher is not None:
            self.prefetcher.shutdown()
            self.prefetcher = None
