"""PowerInfer-2 serving engine — the thin orchestrator.

Three layers, cleanly separated (DESIGN.md §2 records why):

* **Data plane** — always numerically real: pre-jitted decode
  executables per batch bucket (core/adaptation.BucketedDecoder — the
  paper's per-batch NPU graph table) run the hybrid hot/cold FFN and
  return, besides logits, the *true* per-layer cold-cluster selections
  (the activation trace).
* **Storage plane** (serving/storage_plane.py) — the trace drives the
  segmented NeuronCache and the bundled ColdStore exactly as on the
  phone; I/O time comes from the StorageModel, per-token effective
  latency is composed by the neuron-cluster pipeline simulator, and a
  single-I/O-thread prefetcher overlaps next-layer miss fetches with
  current-layer pricing.
* **Scheduler** (serving/scheduler.py) — request-level continuous
  batching: an admission queue, per-step admission up to the decoder's
  next bucket boundary, prefill-on-admit, completion/eviction.

This module only orchestrates: submit()/step()/run_until_drained()
drive requests through slot-based KV management (models/kv_cache.
KVSlotArena); generate() remains as a static-batch compatibility
wrapper over the same loop.

Tensor parallel (DESIGN.md §3): pass `mesh=` a (data, model) device
mesh and all three layers shard over 'model' — params and the KV arena
are placed on the mesh, decode executables are keyed on (bucket × mesh
shape) and traced in the mesh context (the sparse-FFN cold path goes
shard-local via shard_map), and the storage plane prices per-device
cache slices and I/O channels, aggregating TokenStats across shards.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ModelConfig
from repro.core.adaptation import BucketedDecoder, bucket_for
from repro.core.baselines import SystemSpec, POWERINFER2
from repro.core.io_model import StorageModel, UFS40
from repro.core.planner import ExecutionPlan, HardwareProfile
from repro.models import dense
from repro.models.kv_cache import KVSlotArena
from repro.models.modules import dtype_of
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import BatchScheduler
from repro.serving.storage_plane import StoragePlane, TimingProfile, \
    TokenStats

__all__ = ["ServeEngine", "GenerationResult", "ServeReport", "StepResult",
           "TimingProfile", "TokenStats"]


@dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, new)
    stats: list                        # TokenStats per step
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        total = sum(s.effective_s for s in self.stats)
        n = sum(s.batch for s in self.stats)
        return n / total if total else float("inf")

    def latency_percentiles(self):
        lat = np.array([s.effective_s for s in self.stats])
        return {"mean": float(lat.mean()),
                "p50": float(np.percentile(lat, 50)),
                "p90": float(np.percentile(lat, 90)),
                "p99": float(np.percentile(lat, 99))}


@dataclass
class StepResult:
    """Outcome of one continuous-batching decode step."""
    stats: TokenStats
    tokens: dict                       # uid -> generated token
    admitted: list = field(default_factory=list)
    finished: list = field(default_factory=list)


@dataclass
class ServeReport:
    """Aggregate serving metrics over a drained request stream."""
    stats: list                        # TokenStats per step
    requests: list                     # finished Requests

    @property
    def total_tokens(self) -> int:
        return sum(s.batch for s in self.stats)

    @property
    def tokens_per_s(self) -> float:
        total = sum(s.effective_s for s in self.stats)
        return self.total_tokens / total if total else float("inf")

    def ttft(self) -> np.ndarray:
        return np.array([r.ttft for r in self.requests
                         if r.ttft is not None])

    def token_latencies(self) -> np.ndarray:
        """Per-token effective latency: every token generated in a step
        experienced that step's effective seconds."""
        out = []
        for s in self.stats:
            out.extend([s.effective_s] * s.batch)
        return np.array(out)

    def latency_percentiles(self):
        lat = self.token_latencies()
        return {"mean": float(lat.mean()),
                "p50": float(np.percentile(lat, 50)),
                "p90": float(np.percentile(lat, 90)),
                "p99": float(np.percentile(lat, 99))}


class ServeEngine:
    """Single-host continuous-batching engine for dense sparse-FFN
    models. Orchestrates the data plane (BucketedDecoder), the storage
    plane (StoragePlane) and the scheduler (BatchScheduler) over a
    slot-based KV arena."""

    def __init__(self, cfg: ModelConfig, params, plan: ExecutionPlan,
                 spec: SystemSpec = POWERINFER2,
                 storage: StorageModel = UFS40,
                 offload_ratio: float = 0.5,
                 hw: HardwareProfile = None,
                 timing: TimingProfile = None,
                 n_compute_workers: int = 4,
                 seed: int = 0,
                 buckets: tuple = None,
                 ctx_budget: int = None,
                 eos_id: int = None,
                 temperature: float = 0.8,
                 prefetch: bool = True,
                 mesh=None):
        assert cfg.family in ("dense", "vlm"), "engine demo targets dense family"
        self.cfg = cfg
        self.plan = plan
        self.spec = spec
        self.key = jax.random.key(seed)
        # ---- device mesh (tensor parallel over 'model') ----
        self.mesh = mesh
        self.n_shards = dict(mesh.shape).get("model", 1) \
            if mesh is not None else 1

        # ---- data plane ----
        self.model = dense.make_model(cfg)
        if mesh is not None:
            params = self._shard_params(params)
        self.params = params
        self._step_traced = dense.make_decode_step(cfg, collect_indices=True)
        self.decoder = BucketedDecoder(
            plan_source=plan,
            make_step=lambda p: (lambda pr, t, c, m: self._step_traced(
                pr, t, c, p, m)),
            buckets=tuple(buckets) if buckets else tuple(range(1, 65)),
            mesh=mesh)

        # ---- storage plane ----
        self.storage = StoragePlane(
            cfg, params, plan, spec=spec, storage=storage,
            offload_ratio=offload_ratio, hw=hw, timing=timing,
            n_compute_workers=n_compute_workers, prefetch=prefetch,
            n_shards=self.n_shards)

        # ---- scheduler + KV slots ----
        self.sched = BatchScheduler(eos_id=eos_id)
        self.arena: Optional[KVSlotArena] = None
        self._last = None                  # (n_slots, V) next-token logits
        self._prefill_fns = {}
        self._temperature = temperature
        self.ctx_budget = ctx_budget
        self.clock_s = 0.0                 # modeled serving clock

    def close(self):
        """Release the storage plane's I/O thread (also runs at GC)."""
        self.storage.close()

    # --------------------------------------------------- mesh placement ----
    def _shard_params(self, params):
        """Place params on the mesh with the model's param sharding —
        the bundled (L, N, R, D) FFN tensor and the predictor columns
        row/col-split over 'model'; non-dividing dims replicate."""
        from jax.sharding import NamedSharding
        from repro.sharding import _filter_spec
        mesh, specs = self.mesh, self.model.param_spec()

        def put(a, s):
            fs = _filter_spec(s, mesh, shape=a.shape)
            return jax.device_put(a, NamedSharding(mesh, fs))
        return jax.tree.map(put, params, specs)

    # ------------------------------------------------ legacy attributes ----
    # Storage-plane internals used to live on the engine; keep read
    # access for benchmarks/examples without re-exposing the wiring.
    @property
    def cache(self):
        return self.storage.cache

    @property
    def coldstore(self):
        return self.storage.coldstore

    @property
    def timing(self):
        return self.storage.timing

    @property
    def hw(self):
        return self.storage.hw

    @property
    def max_slots(self) -> int:
        return self.decoder.buckets[-1]

    # ------------------------------------------------------- admission ----
    def submit(self, prompt, max_new: int = 32,
               arrival_time: float = None) -> int:
        """Enqueue one request (prompt: (S,) token ids). Returns uid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] == 0:
            raise ValueError("empty prompt: at least one token required")
        if arrival_time is None:
            arrival_time = self.clock_s
        need = prompt.shape[0] + max_new
        if self.arena is not None and need > self.arena.max_len:
            raise ValueError(
                f"request needs {need} KV positions but the arena was "
                f"sized for {self.arena.max_len}; raise ctx_budget")
        req = self.sched.submit(prompt, max_new, arrival_time)
        return req.uid

    def _ensure_arena(self, n_slots: int, min_len: int):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        if self.arena is None:
            T = max(self.ctx_budget or 0, min_len)
            self.arena = KVSlotArena(cfg.num_layers, n_slots, T,
                                     cfg.num_kv_heads, cfg.d_head, dtype,
                                     mesh=self.mesh)
            self._last = jnp.zeros((n_slots, cfg.vocab_padded),
                                   dtype_of(cfg.compute_dtype))
        elif min_len > self.arena.max_len:
            raise ValueError(
                f"admitted request needs {min_len} KV positions but the "
                f"arena was sized for {self.arena.max_len}; raise "
                f"ctx_budget")
        elif self.arena.n_slots != n_slots:
            order = list(self.sched.running)
            rows = self.arena.rows_for(order)
            self.arena.resize(n_slots, order)
            # gather the per-slot logits the same way
            if rows:
                gat = self._last.take(jnp.asarray(rows, jnp.int32), axis=0)
            else:
                gat = self._last[:0]
            pad = n_slots - len(rows)
            if pad:
                zeros = jnp.zeros((pad,) + self._last.shape[1:],
                                  self._last.dtype)
                gat = jnp.concatenate([gat, zeros], axis=0)
            self._last = gat

    def _prefill(self, tokens: np.ndarray):
        """Jitted dense prefill padded to the arena length (traced and
        run inside the serving mesh when tensor-parallel)."""
        B, S = tokens.shape
        T = self.arena.max_len
        key = (B, S, T)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b, max_len=T))
        if self.mesh is not None:
            with set_mesh(self.mesh):
                return self._prefill_fns[key](self.params,
                                              {"tokens": tokens})
        return self._prefill_fns[key](self.params, {"tokens": tokens})

    def _admit(self, reqs: list):
        """Prefill-on-admit: joint prefill per prompt-length group,
        then write each request's KV row into a free slot."""
        i = 0
        while i < len(reqs):
            group = [reqs[i]]
            i += 1
            while i < len(reqs) and reqs[i].prompt_len == group[0].prompt_len:
                group.append(reqs[i])
                i += 1
            tokens = np.stack([r.prompt for r in group]).astype(np.int32)
            logits, cache = self._prefill(tokens)
            self.clock_s += self.storage.prefill_cost(group[0].prompt_len,
                                                      len(group))
            for j, req in enumerate(group):
                self.sched.admit(req, self.clock_s)
                self.arena.alloc(req.uid)
                row = {
                    "k": cache["k"][:, j:j + 1],
                    "v": cache["v"][:, j:j + 1],
                    "kv_pos": cache["kv_pos"][j:j + 1],
                    "length": cache["length"][j:j + 1],
                }
                slot = self.arena.write(req.uid, row)
                self._last = self._last.at[slot].set(logits[j, -1])

    # ------------------------------------------------------ decode loop ----
    def step(self) -> Optional[StepResult]:
        """One continuous-batching step: admit -> (resize at bucket
        boundary) -> sample+decode -> price -> complete."""
        sched = self.sched
        if not sched.has_work:
            return None
        # idle engine: jump the modeled clock to the next arrival
        if not sched.running:
            nxt = sched.next_arrival()
            if nxt is not None and nxt > self.clock_s:
                self.clock_s = nxt
        room = self.max_slots - len(sched.running)
        admits = sched.pop_admissible(self.clock_s, room)
        n_active = len(sched.running) + len(admits)
        if n_active == 0:
            return None
        # the KV arena tracks the decoder's bucket table: one resize
        # (and at most one retrace) per boundary crossing. Its length is
        # fixed at creation, so size it for everything already submitted
        # (still-queued requests were never checked against an arena).
        b = bucket_for(n_active, self.decoder.buckets)
        need = [r.prompt_len + r.max_new for r in admits]
        if self.arena is None:
            need += [sched.sequences[u].prompt_len
                     + sched.sequences[u].max_new for u in sched.queue]
        self._ensure_arena(b, max(need, default=0))
        if admits:
            self._admit(admits)
        n_slots = self.arena.n_slots

        plan_b, step_fn = self.decoder.executable_for(n_active)
        rows = self.arena.rows_for(sched.running)
        idx = jnp.asarray(rows, jnp.int32)
        self.key, sk = jax.random.split(self.key)
        toks_active = sample_tokens(sk, self._last.take(idx, axis=0),
                                    self._temperature)        # (n_active,)
        feed = np.zeros((n_slots,), np.int32)
        feed[rows] = np.asarray(toks_active)
        mask = np.zeros((n_slots,), bool)
        mask[rows] = True
        logits, cache, cidx = step_fn(self.params, jnp.asarray(feed)[:, None],
                                      self.arena.cache, jnp.asarray(mask))
        self.arena.cache = cache
        self._last = logits[:, 0]

        ctx = float(np.mean([sched.sequences[u].prompt_len
                             + sched.sequences[u].n_generated
                             for u in sched.running]))
        st = self.storage.step(np.asarray(cidx), plan_b, n_active, ctx)
        self.clock_s += st.effective_s

        tok_map = {u: int(feed[s])
                   for u, s in zip(sched.running, rows)}
        for u in sched.running:
            req = sched.sequences[u]
            if req.first_token_time is None:
                req.first_token_time = self.clock_s
        done = sched.step(tok_map)
        for u in done:
            sched.sequences[u].finish_time = self.clock_s
            self.arena.release(u)
        return StepResult(stats=st, tokens=tok_map,
                          admitted=[r.uid for r in admits], finished=done)

    def cancel(self, uids):
        """Force-finish running requests (Best-of-N early stop); their
        KV slots return to the free list immediately."""
        for uid in list(uids):
            if uid in self.sched.running:
                self.sched.finish(uid, self.clock_s)
                self.arena.release(uid)

    def run_until_drained(self, max_steps: int = 100000) -> ServeReport:
        """Step until queue and batch are empty. The report covers every
        request finished so far (including cancellations and requests
        completed by manual step() calls before the drain)."""
        stats = []
        for _ in range(max_steps):
            r = self.step()
            if r is None:
                break
            stats.append(r.stats)
        return ServeReport(stats=stats,
                           requests=[r for r in
                                     self.sched.sequences.values()
                                     if r.finished])

    # ---------------------------------------------- compatibility API ----
    def generate(self, prompt_tokens, max_new: int = 32,
                 temperature: float = 0.8,
                 completion_schedule: Optional[dict] = None,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """Static-batch wrapper over the continuous loop: submit B
        requests at the current clock, drain, return (B, max_new)
        tokens. With the default integer bucket table this reproduces
        the seed engine token-for-token (same executables, same
        sampling-key sequence, same storage trace).

        completion_schedule: {step: n_finish} forces sequences to finish
        (reproduces Fig 13's Best-of-N batch decay deterministically).
        """
        prompt = np.asarray(prompt_tokens)
        B, S = prompt.shape
        assert not self.sched.has_work, \
            "generate() requires an idle engine (drain submitted work first)"
        t_wall = time.perf_counter()
        old_temp, old_eos = self._temperature, self.sched.eos_id
        self._temperature = temperature
        self.sched.eos_id = eos_id
        # static batch wants an exact-length arena (seed behavior)
        if self.arena is not None and self.arena.max_len != S + max_new \
                and self.ctx_budget is None:
            self.arena = None
        uids = [self.submit(prompt[i], max_new) for i in range(B)]
        stats = []
        step_i = 0
        try:
            while self.sched.has_work:
                r = self.step()
                if r is None:
                    break
                stats.append(r.stats)
                if completion_schedule and step_i in completion_schedule:
                    still = [u for u in uids if u in self.sched.running]
                    self.cancel(still[: completion_schedule[step_i]])
                step_i += 1
        finally:
            self._temperature, self.sched.eos_id = old_temp, old_eos
        tokens = np.full((B, max_new), -1, np.int32)
        for i, u in enumerate(uids):
            gen = self.sched.sequences[u].generated
            tokens[i, :len(gen)] = gen
        return GenerationResult(tokens=tokens, stats=stats,
                                wall_s=time.perf_counter() - t_wall)
