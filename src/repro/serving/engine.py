"""PowerInfer-2 serving engine.

Two planes, cleanly separated (DESIGN.md §2 records why):

* **Data plane** — always numerically real: pre-jitted decode
  executables per batch bucket (core/adaptation.BucketedDecoder — the
  paper's per-batch NPU graph table) run the hybrid hot/cold FFN and
  return, besides logits, the *true* per-layer cold-cluster selections
  (the activation trace).
* **Storage plane** — the trace drives the segmented NeuronCache and
  the bundled ColdStore exactly as on the phone; I/O time comes from
  the StorageModel, and per-token effective latency is composed by the
  neuron-cluster pipeline simulator under the engine's SystemSpec
  (llama.cpp-analogue / LLMFlash-analogue / PowerInfer-2). On real
  hardware the storage plane gates the data plane; on this CPU
  container it produces the modeled timeline the benchmarks report.

Compute times in the storage plane are analytic (FLOPs / engine rate
from the HardwareProfile) so results are deterministic and
hardware-grounded rather than CPU-wall-clock noise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptation import BucketedDecoder, bucket_for
from repro.core.baselines import SystemSpec, POWERINFER2
from repro.core.cache import NeuronCache
from repro.core.clusters import HybridPlan
from repro.core.coldstore import ColdStore
from repro.core.io_model import StorageModel, UFS40
from repro.core.planner import ExecutionPlan, HardwareProfile
from repro.core.pipeline import ClusterTask, simulate_pipeline
from repro.models import dense
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import BatchScheduler


@dataclass(frozen=True)
class TimingProfile:
    """Cost constants for the storage plane.

    The engine's data plane runs the (reduced) model for real; the
    storage plane prices compute and I/O at the *deployment-size*
    model's constants so compute/I-O ratios land in the paper's regime
    (e.g. bamboo-7b FP16: 24KB Gate-Up-Down bundles — exactly §4.4).
    Defaults derive from the engine's own config.
    """
    d_model: int
    d_ff: int
    num_heads: int
    num_kv_heads: int
    d_head: int
    num_layers: int
    rows: int = 3
    itemsize: int = 2

    @classmethod
    def from_config(cls, cfg, rows):
        return cls(d_model=cfg.d_model, d_ff=cfg.d_ff,
                   num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                   d_head=cfg.d_head, num_layers=cfg.num_layers, rows=rows)

    @property
    def bundle_bytes(self):
        return self.rows * self.d_model * self.itemsize


@dataclass
class TokenStats:
    compute_s: float
    io_s: float            # raw (unpipelined) I/O demand
    effective_s: float     # after pipeline composition
    cache_hit_rate: float
    n_miss: int
    batch: int


@dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, new)
    stats: list                        # TokenStats per step
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        total = sum(s.effective_s for s in self.stats)
        n = sum(s.batch for s in self.stats)
        return n / total if total else float("inf")

    def latency_percentiles(self):
        lat = np.array([s.effective_s for s in self.stats])
        return {"mean": float(lat.mean()),
                "p50": float(np.percentile(lat, 50)),
                "p90": float(np.percentile(lat, 90)),
                "p99": float(np.percentile(lat, 99))}


class ServeEngine:
    """Single-host serving engine for dense sparse-FFN models."""

    def __init__(self, cfg: ModelConfig, params, plan: ExecutionPlan,
                 spec: SystemSpec = POWERINFER2,
                 storage: StorageModel = UFS40,
                 offload_ratio: float = 0.5,
                 hw: HardwareProfile = None,
                 timing: TimingProfile = None,
                 n_compute_workers: int = 4,
                 seed: int = 0):
        assert cfg.family in ("dense", "vlm"), "engine demo targets dense family"
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.spec = spec
        self.hw = hw or plan.hardware
        self.n_workers = n_compute_workers
        self.key = jax.random.key(seed)

        self.model = dense.make_model(cfg)
        self._step_traced = dense.make_decode_step(cfg, collect_indices=True)
        self.decoder = BucketedDecoder(
            plan_source=plan,
            make_step=lambda p: (lambda pr, t, c: self._step_traced(pr, t, c, p)),
            buckets=tuple(range(1, 65)))

        # ---- storage plane ----
        sc = cfg.sparse_ffn
        self.cs = sc.cluster_size
        N = cfg.d_ff
        self.N = N
        from repro.core.sparse_ffn import ffn_rows
        self.timing = timing or TimingProfile.from_config(
            cfg, ffn_rows(cfg.activation))
        # scale factors: storage-plane costs priced at deployment size
        # while traces come from the (possibly reduced) data-plane model
        self.neuron_scale = self.timing.d_ff / N
        self.layer_scale = self.timing.num_layers / cfg.num_layers
        bundles = [np.asarray(params["layers"]["ffn"]["w"][l])
                   for l in range(cfg.num_layers)]
        self.coldstore = ColdStore(bundles, storage=storage,
                                   two_phase=spec.two_phase,
                                   block_size=24576 if spec.use_bundling
                                   else 4096,
                                   bundle_bytes_override=self.timing.bundle_bytes,
                                   count_scale=self.neuron_scale)
        self.bundle_bytes = self.coldstore.bundle_bytes()

        # memory budget: resident = (1-offload)*N neurons per layer.
        # With a pinned hot region (§4.2, PowerInfer-2) the budget splits
        # between hot prefix and cold LRU (hot may not starve cold below
        # its per-token working set). Baseline systems stream *all*
        # activated neurons (hot included) through one LRU cache, with
        # bundling-redundancy derating (spec.cache_efficiency).
        resident = int(N * (1.0 - offload_ratio))
        plan1 = plan.plan_for_batch(1)
        if spec.pinned_hot:
            hot_cap = (resident // 2) // self.cs * self.cs
            self.n_hot = min(plan1.n_hot, max(hot_cap, self.cs))
            cold_capacity = max(resident - self.n_hot, self.cs) \
                * cfg.num_layers
        else:
            self.n_hot = 0
            cold_capacity = max(int(resident * spec.cache_efficiency),
                                self.cs) * cfg.num_layers
        # the per-token activated set always includes the plan's hot
        # prefix; pinned systems never do I/O for it.
        self.plan_hot = plan1.n_hot
        # the hot prefix is pinned (fixed region); the LRU capacity below
        # is entirely the cold region.
        self.cache = NeuronCache(cfg.num_layers, N, self.cs,
                                 capacity_neurons=cold_capacity,
                                 hot_fraction=0.0,
                                 bytes_per_neuron=self.bundle_bytes)
        # warm the cold cache with the most-frequent cold neurons
        per_layer = cold_capacity // cfg.num_layers
        for l in range(cfg.num_layers):
            ids = range(self.n_hot, min(self.n_hot + per_layer, N))
            self.cache.admit_cold(l, list(ids))
        self.cache.stats.reset()
        self.coldstore.reset_stats()

    # ---------------------------------------------------- timing model ----
    def _ffn_flops_token(self, plan: HybridPlan):
        t = self.timing
        per_neuron = 2 * t.rows * t.d_model
        hot = plan.n_hot * self.neuron_scale * per_neuron
        cold = plan.total_cold * self.neuron_scale * per_neuron
        return hot, cold

    def _attn_flops_token(self, ctx_len: int):
        t = self.timing
        return 4 * t.num_heads * t.d_head * ctx_len \
            + 4 * t.d_model * (t.num_heads + 2 * t.num_kv_heads) * t.d_head

    def _compute_time(self, plan: HybridPlan, batch: int, ctx_len: int):
        hot_f, cold_f = self._ffn_flops_token(plan)
        L = self.timing.num_layers
        attn = self._attn_flops_token(ctx_len) * L * batch
        if self.spec.hybrid_engines:
            # hot on the dense engine, cold on the sparse path, overlapped
            t_ffn = max(hot_f / self.hw.dense_engine_flops,
                        cold_f / self.hw.sparse_engine_flops) * L * batch
        elif self.spec.use_predictor:
            t_ffn = (hot_f + cold_f) / self.hw.sparse_engine_flops * L * batch
        else:
            # dense everything (llama.cpp): all N neurons on sparse engine
            t_ffn = (self.timing.d_ff * 2 * self.timing.rows
                     * self.timing.d_model) \
                / self.hw.sparse_engine_flops * L * batch
        return t_ffn + attn / self.hw.dense_engine_flops

    # ---------------------------------------------------- decode loop ----
    def _storage_step(self, cidx, plan: HybridPlan, batch: int,
                      ctx_len: int) -> TokenStats:
        """Run the storage plane for one decode step given the real
        cluster trace cidx (L, G, kc)."""
        cfg, spec = self.cfg, self.spec
        L = cfg.num_layers
        cs = self.cs
        comp_total = self._compute_time(plan, batch, ctx_len)
        h0, m0 = self.cache.stats.hits, self.cache.stats.misses

        tasks = []
        io_raw = 0.0
        comp_per_matrix = comp_total / L
        for l in range(L):
            if spec.use_predictor:
                ids = np.unique(np.asarray(cidx[l]).reshape(-1))
                cold_ids = (self.plan_hot
                            + (ids[:, None] * cs
                               + np.arange(cs)[None]).reshape(-1))
                cold_ids = cold_ids[cold_ids < self.N]
                if spec.pinned_hot:
                    neuron_ids = cold_ids       # hot prefix pinned: no I/O
                else:
                    # activated set = hot prefix + selected cold, all
                    # streamed through the single cache
                    neuron_ids = np.concatenate(
                        [np.arange(self.plan_hot), cold_ids])
            else:
                neuron_ids = np.arange(self.N)       # dense: everything
            if spec.use_cache:
                hits, misses = self.cache.lookup_cold(l, neuron_ids)
                self.cache.admit_cold(l, misses)
            else:
                hits, misses = [], list(neuron_ids)
            n_miss_clusters = max(len(misses) // cs, 0)
            n_clusters = max(len(neuron_ids) // cs, 1)
            if misses:
                if spec.use_bundling:
                    gate_active = np.random.default_rng(l).random(
                        len(misses)) < 0.8 if spec.two_phase else None
                    fr = self.coldstore.fetch(l, misses, gate_active)
                    io_l = fr.io_time
                else:
                    # unbundled: R scattered 4KB-class reads per neuron
                    # (paper §4.4 — this is what bundling removes)
                    R = self.timing.rows
                    per = self.bundle_bytes // R
                    nbytes = int(per * len(misses) * R * self.neuron_scale)
                    io_l = self.coldstore.storage.read_time(
                        nbytes, min(4096, per), random=True)
                    self.coldstore.total_bytes += nbytes
                    self.coldstore.total_io_time += io_l
            else:
                io_l = 0.0
            # price the trace's L_reduced layers at deployment depth
            io_l *= self.layer_scale
            io_raw += io_l
            comp_c = comp_per_matrix / n_clusters
            io_c = io_l / max(n_miss_clusters, 1) if io_l else 0.0
            for c in range(n_clusters):
                tasks.append(ClusterTask(l, c, comp_c,
                                         io_c if c < n_miss_clusters else 0.0))

        if spec.pipeline == "none":
            eff = comp_total + io_raw
        else:
            res = simulate_pipeline(tasks, n_compute=self.n_workers,
                                    policy=spec.pipeline)
            eff = res.makespan
        d_hits = self.cache.stats.hits - h0
        d_miss = self.cache.stats.misses - m0
        seen = d_hits + d_miss
        hr = 1.0 if seen == 0 else d_hits / seen
        return TokenStats(compute_s=comp_total, io_s=io_raw,
                          effective_s=eff, cache_hit_rate=float(hr),
                          n_miss=d_miss, batch=batch)

    def generate(self, prompt_tokens, max_new: int = 32,
                 temperature: float = 0.8,
                 completion_schedule: Optional[dict] = None,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """prompt_tokens (B, S) -> greedy/temperature decode.

        completion_schedule: {step: n_finish} forces sequences to finish
        (reproduces Fig 13's Best-of-N batch decay deterministically).
        """
        cfg = self.cfg
        prompt = jnp.asarray(prompt_tokens)
        B, S = prompt.shape
        t_wall = time.perf_counter()

        sched = BatchScheduler(eos_id=eos_id)
        for _ in range(B):
            sched.add(S, max_new)

        # prefill (dense, sequential I/O — §4.1.1): modeled as streaming
        # every non-resident layer once at sequential bandwidth.
        logits, cache = jax.jit(lambda p, b: self.model.prefill(
            p, b, max_len=S + max_new))(self.params, {"tokens": prompt})

        out_tokens = np.full((B, max_new), -1, np.int32)
        uid_rows = {s.uid: i for i, s in enumerate(sched.sequences.values())}
        active_uids = list(uid_rows)
        stats = []
        last = logits[:, -1]

        for step in range(max_new):
            batch = len(active_uids)
            if batch == 0:
                break
            plan_b, step_fn = self.decoder.executable_for(batch)
            # NOTE: the engine pins the hot prefix statically (fixed
            # region); batch-driven hot/cold REGION resizing
            # (NeuronCache.rebalance) applies when the hot region is
            # LRU-managed — here adaptation happens through the per-batch
            # plan bucket (n_hot grows with batch) instead.
            self.key, sk = jax.random.split(self.key)
            toks = sample_tokens(sk, last, temperature)     # (B_cur,)
            logits, cache, cidx = step_fn(self.params, toks[:, None], cache)
            last = logits[:, 0]
            ctx = S + step
            st = self._storage_step(np.asarray(cidx), plan_b,
                                    batch, ctx)
            stats.append(st)

            finish_uids = []
            tok_map = {}
            for row, uid in enumerate(active_uids):
                seq = sched.sequences[uid]
                out_tokens[uid_rows[uid], seq.n_generated] = int(toks[row])
                tok_map[uid] = int(toks[row])
            done = sched.step(tok_map)
            finish_uids.extend(done)
            if completion_schedule and step in completion_schedule:
                extra = [u for u in active_uids if u not in finish_uids][
                    : completion_schedule[step]]
                for u in extra:
                    sched.sequences[u].finished = True
                finish_uids.extend(extra)

            if finish_uids:
                keep_rows = [r for r, u in enumerate(active_uids)
                             if u not in finish_uids]
                active_uids = [u for u in active_uids if u not in finish_uids]
                if keep_rows and len(keep_rows) < batch:
                    rows = jnp.asarray(keep_rows)
                    # explicit per-key batch axes: k/v are (L,B,T,KV,dh);
                    # kv_pos (B,T); length (B,)
                    cache = {
                        "k": cache["k"].take(rows, axis=1),
                        "v": cache["v"].take(rows, axis=1),
                        "kv_pos": cache["kv_pos"].take(rows, axis=0),
                        "length": cache["length"].take(rows, axis=0),
                    }
                    last = last.take(rows, axis=0)

        return GenerationResult(tokens=out_tokens, stats=stats,
                                wall_s=time.perf_counter() - t_wall)
