"""PowerInfer-2 serving engine — the thin orchestrator.

Three layers, cleanly separated (DESIGN.md §2 records why):

* **Data plane** — always numerically real: pre-jitted decode
  executables per batch bucket (core/adaptation.BucketedDecoder — the
  paper's per-batch NPU graph table) run the hybrid hot/cold FFN and
  return, besides logits, the *true* per-layer cold-cluster selections
  (the activation trace).
* **Storage plane** (serving/storage_plane.py) — the trace drives the
  segmented NeuronCache and the bundled ColdStore exactly as on the
  phone; I/O time comes from the StorageModel, per-token effective
  latency is composed by the neuron-cluster pipeline simulator, and a
  single-I/O-thread prefetcher overlaps next-layer miss fetches with
  current-layer pricing.
* **Scheduler** (serving/scheduler.py) — request-level continuous
  batching: an admission queue, per-step admission up to the decoder's
  next bucket boundary, prefill-on-admit, completion/eviction.

This module only orchestrates: submit()/step()/run_until_drained()
drive requests through slot-based KV management (models/kv_cache.
KVSlotArena); generate() remains as a static-batch compatibility
wrapper over the same loop.

Tensor parallel (DESIGN.md §3): pass `mesh=` a (data, model) device
mesh and all three layers shard over 'model' — params and the KV arena
are placed on the mesh, decode executables are keyed on (bucket × mesh
shape) and traced in the mesh context (the sparse-FFN cold path goes
shard-local via shard_map), and the storage plane prices per-device
cache slices and I/O channels, aggregating TokenStats across shards.

Data parallel (DESIGN.md §5): with the mesh's 'data' axis > 1 (or an
explicit `dp=N` on meshless hosts) the engine becomes a replica
router: one full serving stack — BatchScheduler, KVSlotArena,
StoragePlane, BucketedDecoder, modeled clock — per 'data'-axis row,
each replica running over its own (1, n_model) tensor-parallel
submesh. Submits route least-loaded with a FIFO tiebreak
(serving/scheduler.py::ReplicaRouter); each replica admits at its own
decoder bucket boundary and advances its own clock; run_until_drained
merges the per-replica TokenStats onto the shared timeline and
reports span-based throughput.

Families (DESIGN.md §8): every family-specific piece — model factory,
traced decode step, plan builder, storage view — resolves through the
serving family registry (serving/families.py) keyed on `cfg.family`,
so dense, vlm and moe share this one orchestrator. For moe, the mesh
'model' axis is the *expert-parallel* axis (E/n experts per shard,
shard-local dispatch, one psum per layer) and the storage plane
prices expert residency as cold-cluster residency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.compat import set_mesh
from repro.configs.base import ModelConfig
from repro.core.adaptation import BucketedDecoder, bucket_for
from repro.core.baselines import SystemSpec, POWERINFER2
from repro.core.io_model import StorageModel, UFS40
from repro.core.planner import ExecutionPlan, HardwareProfile
from repro.models.kv_cache import KVSlotArena
from repro.models.modules import dtype_of
from repro.serving.families import serving_family
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import BatchScheduler
from repro.serving.storage_plane import StoragePlane, TimingProfile, \
    TokenStats

__all__ = ["ServeEngine", "GenerationResult", "ServeReport", "StepResult",
           "TimingProfile", "TokenStats"]


def _percentiles(lat: np.ndarray) -> dict:
    """Latency percentile summary; empty input (a stream cancelled
    before any step, a zero-token generation) yields zeros instead of
    np.percentile's IndexError / nan-mean."""
    if lat.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    return {"mean": float(lat.mean()),
            "p50": float(np.percentile(lat, 50)),
            "p90": float(np.percentile(lat, 90)),
            "p99": float(np.percentile(lat, 99))}


@dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, new)
    stats: list                        # TokenStats per step
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        total = sum(s.effective_s for s in self.stats)
        n = sum(s.batch for s in self.stats)
        return n / total if total else 0.0

    def latency_percentiles(self):
        return _percentiles(np.array([s.effective_s for s in self.stats]))


@dataclass
class StepResult:
    """Outcome of one continuous-batching decode step."""
    stats: TokenStats
    tokens: dict                       # uid -> generated token
    admitted: list = field(default_factory=list)
    finished: list = field(default_factory=list)
    replica: int = 0                   # 'data'-axis row that stepped
    t_s: float = 0.0                   # that replica's clock after the step


@dataclass
class ServeReport:
    """Aggregate serving metrics over a drained request stream.

    With replica routing the stats list merges every replica's steps
    ordered by completion time on the shared modeled timeline, and
    `span_s` is the drained makespan (slowest replica clock) —
    `throughput_tok_s` is the span-based rate that actually scales
    with the 'data' axis, while `tokens_per_s` keeps the legacy
    sum-of-step-latency semantics (per-engine pipeline rate)."""
    stats: list                        # TokenStats per step
    requests: list                     # finished Requests
    span_s: float = 0.0                # drained span on the shared timeline

    @property
    def total_tokens(self) -> int:
        return sum(s.batch for s in self.stats)

    @property
    def tokens_per_s(self) -> float:
        total = sum(s.effective_s for s in self.stats)
        return self.total_tokens / total if total else 0.0

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.span_s if self.span_s else 0.0

    def ttft(self) -> np.ndarray:
        """TTFT over requests that produced a first token — requests
        cancelled before their first token have `first_token_time is
        None` and are filtered, never coerced into the array."""
        return np.array([r.ttft for r in self.requests
                         if r.ttft is not None])

    def token_latencies(self) -> np.ndarray:
        """Per-token effective latency: every token generated in a step
        experienced that step's effective seconds."""
        out = []
        for s in self.stats:
            out.extend([s.effective_s] * s.batch)
        return np.array(out)

    def latency_percentiles(self):
        return _percentiles(self.token_latencies())


class ServeEngine:
    """Single-host continuous-batching engine for every registered
    serving family (dense sparse-FFN, vlm backbone, expert-parallel
    moe). Orchestrates the data plane (BucketedDecoder), the storage
    plane (StoragePlane) and the scheduler (BatchScheduler) over a
    slot-based KV arena.

    With a mesh whose 'data' axis is > 1 (or an explicit dp=N) the
    engine instead owns one single-replica engine per 'data'-axis row
    and routes requests across them (DESIGN.md §5)."""

    def __init__(self, cfg: ModelConfig, params, plan: ExecutionPlan,
                 spec: SystemSpec = POWERINFER2,
                 storage: StorageModel = UFS40,
                 offload_ratio: float = 0.5,
                 hw: HardwareProfile = None,
                 timing: TimingProfile = None,
                 n_compute_workers: int = 4,
                 seed: int = 0,
                 buckets: tuple = None,
                 ctx_budget: int = None,
                 eos_id: int = None,
                 temperature: float = 0.8,
                 prefetch: bool = True,
                 mesh=None,
                 dp: int = None,
                 n_replicas: int = 1,
                 backend: str = None):
        # family registry lookup (DESIGN.md §8): raises with the
        # servable set named when cfg.family has no entry
        self.family = serving_family(cfg)
        # cold-path kernel backend override, threaded per bucket into
        # the decoder's executable table (DESIGN.md §10). The moe cold
        # path is expert dispatch, not a cluster gather — no pallas
        # kernel exists for it, so refuse loudly instead of silently
        # serving the jnp path under a 'pallas' label.
        if backend not in (None, "jnp", "pallas"):
            raise ValueError(f"unknown cold-path backend {backend!r}; "
                             f"expected 'jnp' or 'pallas'")
        if backend == "pallas" and cfg.num_experts:
            raise ValueError(
                "backend='pallas' is the dense-family fused cold-path "
                "kernel; the moe family's cold path is expert dispatch "
                "(models/moe.py) and has no pallas backend yet")
        self.backend = backend
        self.cfg = cfg
        self.plan = plan
        self.spec = spec
        self.key = jax.random.key(seed)
        # ---- device mesh (tensor parallel over 'model') ----
        self.mesh = mesh
        mesh_shape = dict(mesh.shape) if mesh is not None else {}
        self.n_shards = mesh_shape.get("model", 1)
        # ---- replica routing over the 'data' axis (DESIGN.md §5) ----
        self.replicas = None
        self.router = None
        n_data = int(dp) if dp is not None else mesh_shape.get("data", 1)
        if mesh is not None and dp is not None \
                and n_data != mesh_shape.get("data", 1):
            raise ValueError(
                f"dp={dp} disagrees with the mesh's 'data' axis "
                f"({mesh_shape.get('data', 1)})")
        if n_data > 1:
            # One full serving stack per replica, each an ordinary
            # dp=1 engine: same seed (so its sampling-key chain is the
            # one an independent engine would use), its own scheduler /
            # KV arena / storage plane / modeled clock, and — when
            # tensor-parallel — its own (1, n_model) row of the mesh.
            if mesh is not None and self.n_shards > 1:
                from repro.launch.mesh import replica_submeshes
                subs = replica_submeshes(mesh)
            else:
                subs = [None] * n_data
            # each replica's storage plane gets a 1/n_data share of the
            # resident NeuronCache budget (DESIGN.md §9): the host
            # memory budget is per machine, so dp must not multiply it
            self.replicas = [
                ServeEngine(cfg, params, plan, spec=spec, storage=storage,
                            offload_ratio=offload_ratio, hw=hw,
                            timing=timing,
                            n_compute_workers=n_compute_workers, seed=seed,
                            buckets=buckets, ctx_budget=ctx_budget,
                            eos_id=eos_id, temperature=temperature,
                            prefetch=prefetch, mesh=subs[r],
                            n_replicas=n_data, backend=backend)
                for r in range(n_data)]
            if subs[0] is None:
                # meshless replicas run identical executables on the
                # same params object: share the jit caches so dp
                # doesn't multiply trace time (replica state that must
                # stay independent — scheduler, arena, key chain,
                # clock — lives outside them). Meshed replicas keep
                # their own: executables bind to their submesh.
                for rep in self.replicas[1:]:
                    rep.decoder._cache = self.replicas[0].decoder._cache
                    rep._prefill_fns = self.replicas[0]._prefill_fns
            from repro.serving.scheduler import ReplicaRouter
            self.router = ReplicaRouter([r.sched for r in self.replicas])
            self.sched = self.router
            self.arena = None
            self.decoder = None
            self.storage = None
            self.ctx_budget = ctx_budget
            self.clock_s = 0.0         # max over replica clocks
            return

        # ---- data plane ----
        if cfg.num_experts:
            # retie MoE dispatch groups to this replica's token block:
            # groups follow the engine's own submesh (its 'data' axis
            # is always 1 here — replica routing handled above), not
            # the launcher-global 'data' axis, so dp x tp x ep composes
            # (each replica dispatches over exactly its local tokens)
            from repro.launch.mesh import dispatch_groups
            cfg = cfg.replace(moe_dispatch_groups=dispatch_groups(mesh))
            self.cfg = cfg
        self.model = self.family.make_model(cfg)
        if mesh is not None:
            params = self._shard_params(params)
        self.params = params
        self._step_traced = self.family.make_decode_step(cfg)
        self.decoder = BucketedDecoder(
            plan_source=plan,
            make_step=lambda p: (lambda pr, t, c, m: self._step_traced(
                pr, t, c, p, m)),
            buckets=tuple(buckets) if buckets else tuple(range(1, 65)),
            mesh=mesh, backend=backend)

        # ---- storage plane ----
        self.storage = StoragePlane(
            cfg, params, plan, spec=spec, storage=storage,
            offload_ratio=offload_ratio, hw=hw, timing=timing,
            n_compute_workers=n_compute_workers, prefetch=prefetch,
            n_shards=self.n_shards, n_replicas=n_replicas)

        # ---- scheduler + KV slots ----
        self.sched = BatchScheduler(eos_id=eos_id)
        self.arena: Optional[KVSlotArena] = None
        self._last = None                  # (n_slots, V) next-token logits
        self._prefill_fns = {}
        self._temperature = temperature
        self.ctx_budget = ctx_budget
        self.clock_s = 0.0                 # modeled serving clock

    def close(self):
        """Release the storage plane's I/O thread (also runs at GC)."""
        if self.replicas is not None:
            for r in self.replicas:
                r.close()
            return
        self.storage.close()

    # --------------------------------------------------- mesh placement ----
    # Quantized-bundle containers (quant/storage.py) ride next to the
    # (L, N, R, D) ffn tensor but aren't in the static model spec; they
    # shard like `w` does — neuron dim over 'model'.
    _QUANT_FFN_SPECS = {
        "wq": PartitionSpec(None, "model", None, None),
        "wsc": PartitionSpec(None, "model", None),
        "wout": PartitionSpec(None, "model", None, None),
    }

    def _shard_params(self, params):
        """Place params on the mesh with the model's param sharding —
        the bundled (L, N, R, D) FFN tensor and the predictor columns
        row/col-split over 'model'; non-dividing dims replicate."""
        from jax.sharding import NamedSharding
        from repro.sharding import _filter_spec
        mesh, specs = self.mesh, self.model.param_spec()
        ffn = params.get("layers", {}).get("ffn", {})
        extra = {k: s for k, s in self._QUANT_FFN_SPECS.items() if k in ffn}
        if extra and "ffn" in specs.get("layers", {}):
            specs = dict(specs, layers=dict(
                specs["layers"],
                ffn=dict(specs["layers"]["ffn"], **extra)))

        def put(a, s):
            fs = _filter_spec(s, mesh, shape=a.shape)
            return jax.device_put(a, NamedSharding(mesh, fs))
        return jax.tree.map(put, params, specs)

    # ------------------------------------------------ legacy attributes ----
    # Storage-plane internals used to live on the engine; keep read
    # access for benchmarks/examples without re-exposing the wiring.
    # Replica-routed engines delegate to replica 0 (every replica is
    # configured identically).
    @property
    def _plane_owner(self):
        return self.replicas[0] if self.replicas is not None else self

    @property
    def cache(self):
        return self._plane_owner.storage.cache

    @property
    def coldstore(self):
        return self._plane_owner.storage.coldstore

    @property
    def timing(self):
        return self._plane_owner.storage.timing

    @property
    def hw(self):
        return self._plane_owner.storage.hw

    @property
    def max_slots(self) -> int:
        return self._plane_owner.decoder.buckets[-1]

    # --------------------------------------------- gateway reporting ----
    # The fleet gateway (serving/gateway.py, DESIGN.md §11) routes on
    # these two: the engine's reported load and its next modeled event
    # time. Both delegate to the scheduler layer, so a replica-routed
    # engine reports fleet-correct aggregates for free.
    @property
    def load(self) -> int:
        """Outstanding requests (queued + running) — the per-backend
        reported load weighted least-loaded dispatch divides by the
        backend weight."""
        return self.sched.load

    def next_event_time(self) -> Optional[float]:
        """When this engine's next decode event completes work on the
        modeled clock: its clock while a batch is running, else the
        head arrival it would jump to; None when drained. Replicated
        engines report the earliest replica's event (the same rule
        `_next_replica` steps by)."""
        if self.replicas is not None:
            best_t = None
            for rep in self.replicas:
                t = rep.next_event_time()
                if t is not None and (best_t is None or t < best_t):
                    best_t = t
            return best_t
        if not self.sched.has_work:
            return None
        if self.sched.running:
            return self.clock_s
        nxt = self.sched.next_arrival()
        return max(self.clock_s, nxt) if nxt is not None else self.clock_s

    # ------------------------------------------------------- admission ----
    def submit(self, prompt, max_new: int = 32,
               arrival_time: float = None) -> int:
        """Enqueue one request (prompt: (S,) token ids). Returns uid.

        Replica-routed engines pick the least-loaded replica (FIFO
        tiebreak) and return a router-global uid."""
        if self.replicas is not None:
            r = self.router.pick_replica()
            # default "now" is the engine's shared clock (max over
            # replicas), not the routed replica's possibly-lagging
            # one — a submit must never arrive before steps that had
            # already completed elsewhere on the merged timeline
            local = self.replicas[r].submit(
                prompt, max_new,
                self.clock_s if arrival_time is None else arrival_time)
            return self.router.bind(r, local)
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] == 0:
            raise ValueError("empty prompt: at least one token required")
        if arrival_time is None:
            arrival_time = self.clock_s
        need = prompt.shape[0] + max_new
        if self.arena is not None and need > self.arena.max_len:
            raise ValueError(
                f"request needs {need} KV positions but the arena was "
                f"sized for {self.arena.max_len}; raise ctx_budget")
        req = self.sched.submit(prompt, max_new, arrival_time)
        return req.uid

    def _ensure_arena(self, n_slots: int, min_len: int):
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        if self.arena is None:
            T = max(self.ctx_budget or 0, min_len)
            self.arena = KVSlotArena(cfg.num_layers, n_slots, T,
                                     cfg.num_kv_heads, cfg.d_head, dtype,
                                     mesh=self.mesh)
            self._last = jnp.zeros((n_slots, cfg.vocab_padded),
                                   dtype_of(cfg.compute_dtype))
        elif min_len > self.arena.max_len:
            raise ValueError(
                f"admitted request needs {min_len} KV positions but the "
                f"arena was sized for {self.arena.max_len}; raise "
                f"ctx_budget")
        elif self.arena.n_slots != n_slots:
            order = list(self.sched.running)
            rows = self.arena.rows_for(order)
            self.arena.resize(n_slots, order)
            # gather the per-slot logits the same way
            if rows:
                gat = self._last.take(jnp.asarray(rows, jnp.int32), axis=0)
            else:
                gat = self._last[:0]
            pad = n_slots - len(rows)
            if pad:
                zeros = jnp.zeros((pad,) + self._last.shape[1:],
                                  self._last.dtype)
                gat = jnp.concatenate([gat, zeros], axis=0)
            self._last = gat

    def _prefill(self, tokens: np.ndarray):
        """Jitted dense prefill padded to the arena length (traced and
        run inside the serving mesh when tensor-parallel)."""
        B, S = tokens.shape
        T = self.arena.max_len
        key = (B, S, T)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b, max_len=T))
        if self.mesh is not None:
            with set_mesh(self.mesh):
                return self._prefill_fns[key](self.params,
                                              {"tokens": tokens})
        return self._prefill_fns[key](self.params, {"tokens": tokens})

    def _admit(self, reqs: list):
        """Prefill-on-admit: joint prefill per prompt-length group,
        then write each request's KV row into a free slot."""
        i = 0
        while i < len(reqs):
            group = [reqs[i]]
            i += 1
            while i < len(reqs) and reqs[i].prompt_len == group[0].prompt_len:
                group.append(reqs[i])
                i += 1
            tokens = np.stack([r.prompt for r in group]).astype(np.int32)
            logits, cache = self._prefill(tokens)
            self.clock_s += self.storage.prefill_cost(group[0].prompt_len,
                                                      len(group))
            for j, req in enumerate(group):
                self.sched.admit(req, self.clock_s)
                self.arena.alloc(req.uid)
                row = {
                    "k": cache["k"][:, j:j + 1],
                    "v": cache["v"][:, j:j + 1],
                    "kv_pos": cache["kv_pos"][j:j + 1],
                    "length": cache["length"][j:j + 1],
                }
                slot = self.arena.write(req.uid, row)
                self._last = self._last.at[slot].set(logits[j, -1])

    # ------------------------------------------------------ decode loop ----
    def _next_replica(self) -> Optional[int]:
        """Earliest-next-event replica with work: its clock, or the
        head arrival it would jump to when idle (ties -> lowest row).
        This is the event-driven interleaving of clocks that advance
        independently in parallel on real hardware."""
        best, best_t = None, None
        for i, rep in enumerate(self.replicas):
            if not rep.sched.has_work:
                continue
            t = rep.clock_s
            if not rep.sched.running:
                nxt = rep.sched.next_arrival()
                if nxt is not None and nxt > t:
                    t = nxt
            if best is None or t < best_t:
                best, best_t = i, t
        return best

    def step(self) -> Optional[StepResult]:
        """One continuous-batching step: admit -> (resize at bucket
        boundary) -> sample+decode -> price -> complete.

        Replica-routed engines step the replica whose next event is
        earliest on the shared timeline; each replica admits at its
        own decoder bucket boundary and advances its own clock."""
        if self.replicas is not None:
            i = self._next_replica()
            if i is None:
                return None
            rep = self.replicas[i]
            r = rep.step()
            if r is None:
                return None
            self.clock_s = max(e.clock_s for e in self.replicas)
            self.router.batch_history.append(self.router.batch_size)
            r.stats.replica = i
            g = self.router.to_global
            return StepResult(
                stats=r.stats,
                tokens={g(i, u): t for u, t in r.tokens.items()},
                admitted=[g(i, u) for u in r.admitted],
                finished=[g(i, u) for u in r.finished],
                replica=i, t_s=rep.clock_s)
        sched = self.sched
        if not sched.has_work:
            return None
        # idle engine: jump the modeled clock to the next arrival
        if not sched.running:
            nxt = sched.next_arrival()
            if nxt is not None and nxt > self.clock_s:
                self.clock_s = nxt
        room = self.max_slots - len(sched.running)
        admits = sched.pop_admissible(self.clock_s, room)
        n_active = len(sched.running) + len(admits)
        if n_active == 0:
            return None
        # the KV arena tracks the decoder's bucket table: one resize
        # (and at most one retrace) per boundary crossing. Its length is
        # fixed at creation, so size it for everything already submitted
        # (still-queued requests were never checked against an arena).
        b = bucket_for(n_active, self.decoder.buckets)
        need = [r.prompt_len + r.max_new for r in admits]
        if self.arena is None:
            need += [sched.sequences[u].prompt_len
                     + sched.sequences[u].max_new for u in sched.queue]
        self._ensure_arena(b, max(need, default=0))
        if admits:
            self._admit(admits)
        n_slots = self.arena.n_slots

        plan_b, step_fn = self.decoder.executable_for(n_active)
        rows = self.arena.rows_for(sched.running)
        idx = jnp.asarray(rows, jnp.int32)
        self.key, sk = jax.random.split(self.key)
        toks_active = sample_tokens(sk, self._last.take(idx, axis=0),
                                    self._temperature)        # (n_active,)
        feed = np.zeros((n_slots,), np.int32)
        feed[rows] = np.asarray(toks_active)
        mask = np.zeros((n_slots,), bool)
        mask[rows] = True
        logits, cache, cidx = step_fn(self.params, jnp.asarray(feed)[:, None],
                                      self.arena.cache, jnp.asarray(mask))
        self.arena.cache = cache
        self._last = logits[:, 0]

        ctx = float(np.mean([sched.sequences[u].prompt_len
                             + sched.sequences[u].n_generated
                             for u in sched.running]))
        st = self.storage.step(np.asarray(cidx), plan_b, n_active, ctx)
        self.clock_s += st.effective_s

        tok_map = {u: int(feed[s])
                   for u, s in zip(sched.running, rows)}
        for u in sched.running:
            req = sched.sequences[u]
            if req.first_token_time is None:
                req.first_token_time = self.clock_s
        done = sched.step(tok_map)
        for u in done:
            sched.sequences[u].finish_time = self.clock_s
            self.arena.release(u)
        return StepResult(stats=st, tokens=tok_map,
                          admitted=[r.uid for r in admits], finished=done,
                          t_s=self.clock_s)

    def cancel(self, uids):
        """Force-finish requests (Best-of-N early stop / client
        cancel). Running requests release their KV slot immediately;
        still-queued requests are dequeued before ever being admitted
        — they finish with no tokens and `first_token_time` stays
        None, so reports must (and do) filter them from TTFT."""
        if self.replicas is not None:
            for uid in list(uids):
                r, local = self.router.locate(uid)
                was_running = local in self.replicas[r].sched.running
                self.replicas[r].cancel([local])
                if was_running:
                    # mirror BatchScheduler.finish: a between-step
                    # cancel is a decay event on the merged timeline
                    self.router.batch_history.append(
                        self.router.batch_size)
            return
        for uid in list(uids):
            if uid in self.sched.running:
                self.sched.finish(uid, self.clock_s)
                self.arena.release(uid)
            elif not self.sched.sequences[uid].finished:
                self.sched.finish(uid, self.clock_s)   # queued: no slot yet

    def run_until_drained(self, max_steps: int = 100000) -> ServeReport:
        """Step until queue and batch are empty. The report covers every
        request finished so far (including cancellations and requests
        completed by manual step() calls before the drain).

        Replica-routed engines merge every replica's TokenStats onto
        the shared timeline (ordered by each step's completion time)
        and report the drained makespan as `span_s`; requests come
        back in global-uid (submission) order."""
        if self.replicas is not None:
            log = []
            for _ in range(max_steps):
                r = self.step()
                if r is None:
                    break
                log.append((r.t_s, r.replica, r.stats))
            log.sort(key=lambda e: (e[0], e[1]))
            reqs = [self.router.request(u) for u in self.router.assignment]
            return ServeReport(
                stats=[s for _, _, s in log],
                requests=[q for q in reqs if q.finished],
                span_s=max(r.clock_s for r in self.replicas))
        stats = []
        for _ in range(max_steps):
            r = self.step()
            if r is None:
                break
            stats.append(r.stats)
        return ServeReport(stats=stats,
                           requests=[r for r in
                                     self.sched.sequences.values()
                                     if r.finished],
                           span_s=self.clock_s)

    # ---------------------------------------------- compatibility API ----
    def generate(self, prompt_tokens, max_new: int = 32,
                 temperature: float = 0.8,
                 completion_schedule: Optional[dict] = None,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """Static-batch wrapper over the continuous loop: submit B
        requests at the current clock, drain, return (B, max_new)
        tokens. With the default integer bucket table this reproduces
        the seed engine token-for-token (same executables, same
        sampling-key sequence, same storage trace).

        completion_schedule: {step: n_finish} forces sequences to finish
        (reproduces Fig 13's Best-of-N batch decay deterministically).
        """
        prompt = np.asarray(prompt_tokens)
        B, S = prompt.shape
        if self.replicas is not None:
            raise ValueError(
                "generate() is the static-batch compat path; a "
                "replica-routed engine serves via submit()/"
                "run_until_drained()")
        assert not self.sched.has_work, \
            "generate() requires an idle engine (drain submitted work first)"
        # wall_s is an observability stat, never fed back into the
        # modeled device clock or any scheduling decision
        t_wall = time.perf_counter()  # repro: ignore[wall-clock]
        old_temp, old_eos = self._temperature, self.sched.eos_id
        self._temperature = temperature
        self.sched.eos_id = eos_id
        # static batch wants an exact-length arena (seed behavior)
        if self.arena is not None and self.arena.max_len != S + max_new \
                and self.ctx_budget is None:
            self.arena = None
        uids = [self.submit(prompt[i], max_new) for i in range(B)]
        stats = []
        step_i = 0
        try:
            while self.sched.has_work:
                r = self.step()
                if r is None:
                    break
                stats.append(r.stats)
                if completion_schedule and step_i in completion_schedule:
                    still = [u for u in uids if u in self.sched.running]
                    self.cancel(still[: completion_schedule[step_i]])
                step_i += 1
        finally:
            self._temperature, self.sched.eos_id = old_temp, old_eos
        tokens = np.full((B, max_new), -1, np.int32)
        for i, u in enumerate(uids):
            gen = self.sched.sequences[u].generated
            tokens[i, :len(gen)] = gen
        return GenerationResult(
            tokens=tokens, stats=stats,
            # observability only, see t_wall above
            wall_s=time.perf_counter() - t_wall)  # repro: ignore[wall-clock]
