"""Token sampling: greedy / temperature / top-k, plus Best-of-N scoring."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(key, logits, temperature: float = 1.0, top_k: int = 0):
    """logits (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        v, _ = jax.lax.top_k(logits, top_k)
        cutoff = v[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sequence_logprob(logits_seq, tokens_seq):
    """Mean token log-prob — the Best-of-N ranking score (paper Fig 1b)."""
    logp = jax.nn.log_softmax(logits_seq.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tokens_seq[..., None], axis=-1)[..., 0]
    return ll.mean(axis=-1)
