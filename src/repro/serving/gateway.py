"""Fleet front door: an async serving gateway over many engines
(DESIGN.md §11).

The mesh work (DESIGN.md §3/§5/§8) scales one *process*; the
millions-of-users story needs a dispatch layer over many of them. This
module is that layer, in the spirit of llm-farm's FastAPI gateway over
a fleet of phones (SNIPPETS.md snippet 2) — each backend holds a
complete serving stack, the gateway load-balances requests across the
fleet — upgraded from round-robin to the policies a real front door
needs:

* **weighted least-loaded dispatch** — each backend reports its
  outstanding load (`ServeEngine.load`, the scheduler's queued +
  running count) and carries a throughput `weight`; the gateway routes
  to the eligible backend minimising load/weight (FIFO tiebreak, so an
  idle fleet round-robins deterministically). Per-device throughput on
  COTS hardware varies widely (arXiv 2410.03613) — the weight is how
  the router absorbs that.
* **per-backend max-concurrency caps** — a backend at its cap is
  skipped (requests queue at the gateway), so one slow engine never
  accumulates the whole fleet's backlog.
* **health / heartbeat probes** — a fleet clock event every
  `heartbeat_s` probes each backend; a dead backend is detected at
  probe time, its in-flight requests are recalled and redispatched
  elsewhere (retries counted), and a later successful probe rejoins it
  through the circuit breaker's half-open canary.
* **circuit breaker** (closed/open/half-open) — dispatch failures trip
  a per-backend breaker after `failure_threshold` consecutive
  failures; an open breaker rejects dispatch until `open_timeout_s` of
  fleet-clock time has passed, then admits `half_open_probes` canary
  requests whose completion closes it (failure reopens it).
* **response LRU** — completed responses are cached keyed on the
  *canonicalized* request (prompt token bytes + max_new); a hit
  replays the recorded token stream with zero decode work.
* **token streaming passthrough** — every decoded token is forwarded
  to the request's event stream the moment its backend step completes;
  `stream()` yields (t_s, token) events live while driving the fleet,
  and `AsyncGateway` exposes the same as an async iterator.

The **fleet clock** is modeled exactly the way the engine models the
device clock (core/io_model.py prices I/O, the engine accumulates
modeled effective seconds): every backend advances its own modeled
clock; the gateway is an event-driven simulator that always processes
the earliest next event — a control event (heartbeat, injected
loss/rejoin), a pending dispatch, or the earliest backend's decode
step — so a `fleet size x arrival rate` sweep is deterministic and
replayable (benchmarks/bench_serving.py --fleet).

Backends implement the narrow `BackendHandle` surface (submit / step /
cancel / load / alive / close) so the in-process `EngineBackend` can
later be joined by an RPC-backed multi-host handle without touching
the dispatch logic.

A request whose every dispatch attempt fails (all breakers open, every
backend lost or draining) surfaces a *typed* rejection — it lands in
`FleetReport.rejected` with a reason, never hangs the drain loop — and
an empty-fleet report is well-formed zeros (no division by zero).
"""
from __future__ import annotations

import heapq
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import numpy as np

__all__ = ["FleetGateway", "AsyncGateway", "EngineBackend", "Backend",
           "CircuitBreaker", "ResponseLRU", "FleetReport",
           "RejectedRequest", "BackendUnavailable", "canonical_key",
           "CLOSED", "OPEN", "HALF_OPEN"]

# breaker states (str constants: cheap to assert on and to serialize)
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class BackendUnavailable(RuntimeError):
    """Raised by a backend handle when a dispatch cannot land (the
    modeled host is down or refusing work)."""


def canonical_key(prompt, max_new: int) -> tuple:
    """Canonicalized request identity for the response LRU: the prompt
    as int32 token bytes plus the generation budget — list vs array vs
    dtype never splits the cache."""
    toks = np.asarray(prompt, np.int32).reshape(-1)
    return (toks.tobytes(), int(max_new))


# ---------------------------------------------------- circuit breaker ----

class CircuitBreaker:
    """Closed/open/half-open breaker on the fleet clock.

    Closed: dispatch allowed; `failure_threshold` *consecutive*
    failures trip it open. Open: dispatch refused until
    `open_timeout_s` of fleet time passes, then the next `allow()`
    moves it half-open. Half-open: up to `half_open_probes` canary
    requests may be in flight; a canary completing closes the breaker,
    a failure reopens it (restarting the timeout)."""

    def __init__(self, failure_threshold: int = 3,
                 open_timeout_s: float = 0.05,
                 half_open_probes: int = 1):
        self.failure_threshold = int(failure_threshold)
        self.open_timeout_s = float(open_timeout_s)
        self.half_open_probes = int(half_open_probes)
        self.state = CLOSED
        self.failures = 0              # consecutive, resets on success
        self.opened_at = 0.0
        self.probes_inflight = 0

    def allow(self, now: float) -> bool:
        """May a request be dispatched now? Open -> half-open happens
        here (time-driven), so callers never special-case the timer."""
        if self.state == OPEN:
            if now - self.opened_at >= self.open_timeout_s:
                self.state = HALF_OPEN
                self.probes_inflight = 0
            else:
                return False
        if self.state == HALF_OPEN:
            return self.probes_inflight < self.half_open_probes
        return True

    def on_dispatch(self):
        if self.state == HALF_OPEN:
            self.probes_inflight += 1

    def record_success(self):
        if self.state == HALF_OPEN:
            self.probes_inflight = max(0, self.probes_inflight - 1)
            self.state = CLOSED
        self.failures = 0

    def record_failure(self, now: float):
        if self.state == HALF_OPEN:
            self.probes_inflight = max(0, self.probes_inflight - 1)
            self.trip(now)
            return
        self.failures += 1
        if self.failures >= self.failure_threshold:
            self.trip(now)

    def trip(self, now: float):
        """Force-open (heartbeat loss detection skips the count)."""
        self.state = OPEN
        self.opened_at = now
        self.failures = 0


# ------------------------------------------------------- response LRU ----

class ResponseLRU:
    """Bounded LRU of completed responses keyed on the canonicalized
    request. `capacity=0` disables caching entirely."""

    def __init__(self, capacity: int = 128):
        self.capacity = int(capacity)
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        if not self.capacity:       # disabled: no hit/miss accounting
            return None
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, tokens: list):
        if not self.capacity:
            return
        self._d[key] = list(tokens)
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __len__(self):
        return len(self._d)


# ---------------------------------------------------- backend handles ----

class BackendHandle:
    """The narrow surface the gateway needs from one serving backend.

    `EngineBackend` implements it over an in-process ServeEngine; a
    multi-host deployment implements the same six calls over RPC and
    plugs into the unchanged dispatch logic."""

    def submit(self, prompt, max_new: int, arrival_time: float) -> int:
        raise NotImplementedError

    def step(self):
        raise NotImplementedError

    def cancel(self, local_uids):
        raise NotImplementedError

    @property
    def load(self) -> int:
        raise NotImplementedError

    def next_event_time(self) -> Optional[float]:
        raise NotImplementedError

    def close(self):
        pass


class EngineBackend(BackendHandle):
    """In-process replica: one full ServeEngine behind the handle.

    `lost` models the host dying: submits raise BackendUnavailable and
    the engine produces no further events until `restore()`. The
    engine object survives a loss (it is a simulation of a process
    that died); `recall()` cancels whatever was in flight so the
    gateway can redispatch it and a later rejoin starts clean."""

    def __init__(self, engine):
        self.engine = engine
        self.lost = False

    def submit(self, prompt, max_new: int, arrival_time: float) -> int:
        if self.lost:
            raise BackendUnavailable("backend is down")
        return self.engine.submit(prompt, max_new,
                                  arrival_time=arrival_time)

    def step(self):
        if self.lost:
            return None
        return self.engine.step()

    def cancel(self, local_uids):
        self.engine.cancel(local_uids)

    @property
    def load(self) -> int:
        return self.engine.load

    def next_event_time(self) -> Optional[float]:
        if self.lost:
            return None                # a dead host emits no events
        return self.engine.next_event_time()

    def close(self):
        self.engine.close()


@dataclass
class Backend:
    """One fleet member: a handle plus the gateway's routing state."""
    handle: BackendHandle
    weight: float = 1.0                # relative throughput (>=, >0)
    max_concurrency: int = 8           # outstanding dispatches cap
    breaker: CircuitBreaker = field(default_factory=CircuitBreaker)
    alive: bool = True                 # last heartbeat verdict
    draining: bool = False             # finish in-flight, take no new
    inflight: dict = field(default_factory=dict)   # local uid -> gw uid
    n_dispatched: int = 0
    n_completed: int = 0
    n_steps: int = 0

    def eligible(self, now: float) -> bool:
        """May a new request land here right now?"""
        return (self.alive and not self.draining
                and len(self.inflight) < self.max_concurrency
                and self.breaker.allow(now))

    def score(self) -> float:
        """Weighted load: reported outstanding work over throughput
        weight — the least-loaded policy's ordering key."""
        return self.handle.load / max(self.weight, 1e-9)


# ------------------------------------------------------ request state ----

@dataclass
class GatewayRequest:
    """One request through the gateway's lifecycle."""
    uid: int
    prompt: np.ndarray
    max_new: int
    arrival_time: float
    key: tuple = None
    backend: Optional[int] = None      # current backend index
    tokens: list = field(default_factory=list)
    events: list = field(default_factory=list)     # (t_s, token) stream
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    cache_hit: bool = False
    retries: int = 0                   # redispatches after a failure
    attempts: int = 0                  # dispatch attempts consumed
    epoch: int = 0                     # bumped on recall: stream restarts
    done: bool = False
    rejected: bool = False
    reject_reason: str = ""

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time


@dataclass
class RejectedRequest:
    """Typed rejection: the request surfaced an error instead of
    hanging — every dispatch attempt hit an open breaker / lost or
    draining backend, or the fleet was empty."""
    uid: int
    reason: str
    attempts: int
    t_s: float


@dataclass
class FleetReport:
    """Aggregate fleet metrics over a drained request stream. All
    denominators are guarded: an empty fleet (or a stream rejected
    wholesale) reports zeros, never a ZeroDivisionError."""
    n_submitted: int = 0
    n_completed: int = 0               # includes cache hits
    n_rejected: int = 0
    n_retries: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    total_tokens: int = 0
    span_s: float = 0.0
    ttft_hit: np.ndarray = None        # TTFT over cache-hit requests
    ttft_miss: np.ndarray = None       # TTFT over decoded requests
    rejected: list = field(default_factory=list)   # RejectedRequest
    per_backend: list = field(default_factory=list)

    @property
    def throughput_tok_s(self) -> float:
        return self.total_tokens / self.span_s if self.span_s else 0.0

    @property
    def drained(self) -> bool:
        """Every submitted request surfaced an outcome (completion or
        typed rejection) — the no-drops invariant the soak asserts."""
        return self.n_completed + self.n_rejected == self.n_submitted

    def ttft_percentiles(self, which: str = "miss") -> dict:
        arr = self.ttft_hit if which == "hit" else self.ttft_miss
        if arr is None or arr.size == 0:
            return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"mean": float(arr.mean()),
                "p50": float(np.percentile(arr, 50)),
                "p90": float(np.percentile(arr, 90)),
                "p99": float(np.percentile(arr, 99))}


# ------------------------------------------------------- the gateway ----

class FleetGateway:
    """Event-driven front door over a fleet of serving backends.

    submit() -> uid enqueues on the fleet clock; step() advances the
    fleet by one event (control event, dispatch round, or one decode
    step on the earliest backend); run_until_drained() loops until
    every request has an outcome and returns a FleetReport. stream()
    yields one request's tokens live while driving the fleet."""

    def __init__(self, backends, *, heartbeat_s: float = 0.05,
                 cache_capacity: int = 128, max_attempts: int = 8,
                 retry_backoff_s: float = 0.02):
        self.backends: list[Backend] = [
            b if isinstance(b, Backend) else Backend(handle=b)
            for b in backends]
        self.heartbeat_s = float(heartbeat_s)
        self.max_attempts = int(max_attempts)
        self.retry_backoff_s = float(retry_backoff_s)
        self.cache = ResponseLRU(cache_capacity)
        self.clock_s = 0.0             # latest processed fleet event
        self.requests: dict[int, GatewayRequest] = {}
        self.pending: deque[int] = deque()     # gw uids awaiting dispatch
        self._ready_t: dict[int, float] = {}   # uid -> not-before time
        self._next_uid = 0
        self._fifo = deque(range(len(self.backends)))  # dispatch tiebreak
        self._events: list = []        # heap of (t, seq, fn)
        self._eseq = 0
        self.n_retries = 0
        self.rejected: list[RejectedRequest] = []
        self._on_token: list[Callable] = []    # streaming passthrough
        if self.backends and self.heartbeat_s > 0:
            self.at(self.heartbeat_s, self._heartbeat)

    # ------------------------------------------------- fleet events ----
    def at(self, t: float, fn: Callable):
        """Schedule a control event on the fleet clock (heartbeats,
        injected loss/rejoin, drains — anything scenario-shaped)."""
        heapq.heappush(self._events, (float(t), self._eseq, fn))
        self._eseq += 1

    def _heartbeat(self):
        """Probe every backend; detect losses (recall + redispatch
        in-flight work) and rejoins (breaker to half-open via its
        timer; `alive` flips back so dispatch may resume)."""
        now = self.clock_s
        for i, b in enumerate(self.backends):
            lost = getattr(b.handle, "lost", False)
            if lost and b.alive:
                b.alive = False
                b.breaker.trip(now)
                self._recall(i, now)
            elif not lost and not b.alive:
                b.alive = True         # rejoined: breaker still gates
        self.at(now + self.heartbeat_s, self._heartbeat)

    def _recall(self, i: int, now: float):
        """Pull a dead backend's in-flight requests back to the
        gateway queue; the backend's own state is cancelled so a
        rejoin starts clean. Partial streams restart from scratch on
        the new backend (the retry is a fresh decode)."""
        b = self.backends[i]
        if not b.inflight:
            return
        locals_, gw_uids = list(b.inflight), list(b.inflight.values())
        b.inflight.clear()
        b.handle.cancel(locals_)
        for uid in gw_uids:
            req = self.requests[uid]
            req.backend = None
            req.retries += 1
            req.epoch += 1
            req.tokens.clear()
            req.events.clear()
            req.first_token_time = None
            self.n_retries += 1
            self._ready_t[uid] = now
            self.pending.appendleft(uid)       # recalled work goes first

    # ---------------------------------------------------- admission ----
    def on_token(self, fn: Callable):
        """Register a streaming-passthrough callback
        fn(uid, token, t_s) invoked the moment a token is decoded (or
        replayed from cache)."""
        self._on_token.append(fn)

    def submit(self, prompt, max_new: int = 32,
               arrival_time: float = None) -> int:
        """Enqueue one request on the fleet clock; returns the gateway
        uid. A response-LRU hit completes immediately at arrival (zero
        decode work, the cached token stream replayed); an empty fleet
        rejects immediately (typed, never a hang)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if arrival_time is None:
            arrival_time = self.clock_s
        uid = self._next_uid
        self._next_uid += 1
        req = GatewayRequest(uid=uid, prompt=prompt, max_new=int(max_new),
                             arrival_time=float(arrival_time),
                             key=canonical_key(prompt, max_new))
        self.requests[uid] = req
        if not self.backends:
            self._reject(req, "empty_fleet", at=req.arrival_time)
            return uid
        cached = self.cache.get(req.key)
        if cached is not None:
            req.cache_hit = True
            req.tokens = list(cached)
            t = req.arrival_time
            req.events = [(t, tok) for tok in cached]
            req.first_token_time = t if cached else None
            req.finish_time = t
            req.done = True
            for fn in self._on_token:
                for tok in cached:
                    fn(uid, tok, t)
            return uid
        self._ready_t[uid] = req.arrival_time
        self.pending.append(uid)
        return uid

    def _reject(self, req: GatewayRequest, reason: str, at: float):
        req.done = True
        req.rejected = True
        req.reject_reason = reason
        req.finish_time = at
        self.rejected.append(RejectedRequest(req.uid, reason,
                                             req.attempts, at))

    # ----------------------------------------------------- dispatch ----
    def _pick_backend(self, now: float) -> Optional[int]:
        """Weighted least-loaded over eligible backends; FIFO
        tiebreak (least recently picked wins), so an idle homogeneous
        fleet round-robins deterministically."""
        best, best_score = None, None
        for i in self._fifo:
            b = self.backends[i]
            if not b.eligible(now):
                continue
            s = b.score()
            if best is None or s < best_score:
                best, best_score = i, s
        return best

    def _any_recoverable(self, now: float) -> bool:
        """Could some backend *become* eligible without gateway
        action? True while any live non-draining backend exists —
        its cap frees as work completes, its breaker half-opens on
        the fleet clock. Lost backends don't count (their rejoin is
        an external event the retry budget bounds the wait for)."""
        return any(b.alive and not b.draining for b in self.backends)

    def _dispatch_ready(self):
        """Dispatch every pending request that has arrived and has an
        eligible backend. Requests blocked only by concurrency caps
        stay queued for free (capacity frees via backend events);
        requests facing a fleet with no live backends consume a
        dispatch attempt and back off — a bounded budget, so the
        all-breakers-open case terminates in a typed rejection."""
        now = self.clock_s
        progressed = True
        while progressed and self.pending:
            progressed = False
            for _ in range(len(self.pending)):
                uid = self.pending.popleft()
                req = self.requests[uid]
                if self._ready_t[uid] > now:
                    self.pending.append(uid)
                    continue
                i = self._pick_backend(now)
                if i is None:
                    if self._any_recoverable(now):
                        # caps/breaker-timers will free up on their own
                        self.pending.append(uid)
                        continue
                    req.attempts += 1
                    if req.attempts >= self.max_attempts:
                        self._reject(req, "no_backend_available", at=now)
                        self._ready_t.pop(uid, None)
                    else:
                        self._ready_t[uid] = now + self.retry_backoff_s
                        self.pending.append(uid)
                        self.at(self._ready_t[uid], lambda: None)
                    continue
                b = self.backends[i]
                req.attempts += 1
                try:
                    local = b.handle.submit(req.prompt, req.max_new, now)
                except BackendUnavailable:
                    b.alive = False
                    b.breaker.record_failure(now)
                    self._recall(i, now)
                    req.retries += 1
                    self.n_retries += 1
                    self.pending.appendleft(uid)
                    progressed = True
                    continue
                b.breaker.on_dispatch()
                b.inflight[local] = uid
                b.n_dispatched += 1
                req.backend = i
                self._ready_t.pop(uid, None)
                self._fifo.remove(i)
                self._fifo.append(i)
                progressed = True

    # -------------------------------------------------- fleet clock ----
    def _wake_time(self) -> float:
        """Earliest *future* time gateway-side state changes on its
        own: a scheduled control event, a pending request's backoff
        expiry, or an open breaker's half-open transition (only
        relevant while requests are waiting). Strictly greater than
        the current clock, or +inf."""
        inf = float("inf")
        t = self._events[0][0] if self._events else inf
        for uid in self.pending:
            rt = self._ready_t[uid]
            if rt > self.clock_s:
                t = min(t, rt)
        if self.pending:
            for b in self.backends:
                if b.alive and not b.draining and b.breaker.state == OPEN:
                    rt = b.breaker.opened_at + b.breaker.open_timeout_s
                    if rt > self.clock_s:
                        t = min(t, rt)
        return t

    def _earliest_backend(self) -> Optional[int]:
        best, best_t = None, None
        for i, b in enumerate(self.backends):
            t = b.handle.next_event_time()
            if t is None:
                continue
            if best is None or t < best_t:
                best, best_t = i, t
        return best

    @property
    def has_work(self) -> bool:
        return any(not r.done for r in self.requests.values())

    def _harvest(self, i: int):
        """Step backend `i` once and forward its tokens/completions
        into the gateway's request state (the streaming passthrough
        moment)."""
        b = self.backends[i]
        r = b.handle.step()
        if r is None:
            return
        b.n_steps += 1
        # NOTE: the fleet clock does NOT jump to r.t_s (the step's
        # completion on the backend's own clock) — backends decode
        # concurrently, so the fleet clock tracks event *starts* and
        # stays <= every backend frontier; jumping it to a completion
        # would leapfrog pending arrivals past the other (idle)
        # backends and serialize the whole fleet behind one step.
        for local, tok in r.tokens.items():
            uid = b.inflight.get(local)
            if uid is None:
                continue
            req = self.requests[uid]
            req.tokens.append(int(tok))
            req.events.append((r.t_s, int(tok)))
            if req.first_token_time is None:
                req.first_token_time = r.t_s
            for fn in self._on_token:
                fn(uid, int(tok), r.t_s)
        for local in r.finished:
            uid = b.inflight.pop(local, None)
            if uid is None:
                continue
            req = self.requests[uid]
            req.done = True
            req.finish_time = r.t_s
            b.n_completed += 1
            b.breaker.record_success()
            self.cache.put(req.key, req.tokens)

    def step(self) -> bool:
        """Advance the fleet by one event: run due control events,
        dispatch what can land now, then either step the earliest-due
        backend or jump the clock to the next wake time. Returns
        False when fully drained (every request has an outcome and no
        backend holds work)."""
        if not self.has_work:
            return False
        while self._events and self._events[0][0] <= self.clock_s:
            _, _, fn = heapq.heappop(self._events)
            fn()
        self._dispatch_ready()
        t_wake = self._wake_time()
        i = self._earliest_backend()
        if i is not None:
            t_b = max(self.backends[i].handle.next_event_time(),
                      self.clock_s)
            if t_b <= t_wake:
                self.clock_s = t_b
                self._harvest(i)
                return True
        if t_wake != float("inf"):
            self.clock_s = t_wake
            return True
        # Nothing will ever wake us: no backend events, no control
        # events, no timers. Recall work hung on lost backends (the
        # no-heartbeat degenerate case) and reject what still cannot
        # land — a typed outcome beats a silent hang.
        for j, b in enumerate(self.backends):
            if getattr(b.handle, "lost", False) and b.inflight:
                b.alive = False
                self._recall(j, self.clock_s)
        self._dispatch_ready()
        if self._wake_time() == float("inf") \
                and self._earliest_backend() is None:
            for uid in list(self.pending):
                self._reject(self.requests[uid], "fleet_stalled",
                             at=self.clock_s)
                self._ready_t.pop(uid, None)
            self.pending.clear()
        return self.has_work

    # ------------------------------------------------ fleet control ----
    def fail_backend(self, i: int, at: float = None):
        """Model backend `i`'s host dying at fleet time `at` (now if
        None): submits start failing immediately; in-flight work hangs
        until the next heartbeat detects the loss and recalls it."""
        if at is None or at <= self.clock_s:
            self.backends[i].handle.lost = True
        else:
            self.at(at, lambda: setattr(self.backends[i].handle,
                                        "lost", True))

    def restore_backend(self, i: int, at: float = None):
        """Model the host coming back; the next heartbeat flips
        `alive` and the breaker's half-open canary readmits it."""
        if at is None or at <= self.clock_s:
            self.backends[i].handle.lost = False
        else:
            self.at(at, lambda: setattr(self.backends[i].handle,
                                        "lost", False))

    def drain_backend(self, i: int, at: float = None):
        """Draining: the backend finishes its in-flight requests and
        receives no new dispatches (rolling restarts without drops)."""
        if at is None or at <= self.clock_s:
            self.backends[i].draining = True
        else:
            self.at(at, lambda: setattr(self.backends[i], "draining",
                                        True))

    def undrain_backend(self, i: int):
        self.backends[i].draining = False

    # ----------------------------------------------------- draining ----
    def run_until_drained(self, max_events: int = 1000000) -> FleetReport:
        for _ in range(max_events):
            if not self.step():
                break
        return self.report()

    def stream(self, uid: int) -> Iterator[tuple]:
        """Drive the fleet until request `uid` finishes, yielding its
        (t_s, token) events as they are produced — the streaming
        passthrough, on the modeled clock. Cached responses replay
        instantly; rejected requests raise BackendUnavailable with
        the typed reason."""
        req = self.requests[uid]
        sent, epoch = 0, req.epoch
        while True:
            if req.epoch != epoch:     # recalled: the retry restarts
                sent, epoch = 0, req.epoch
            while sent < len(req.events):
                yield req.events[sent]
                sent += 1
            if req.done:
                break
            if not self.step():
                break
        if req.rejected:
            raise BackendUnavailable(
                f"request {uid} rejected: {req.reject_reason} "
                f"after {req.attempts} attempts")

    def report(self) -> FleetReport:
        reqs = list(self.requests.values())
        done = [r for r in reqs if r.done and not r.rejected]
        # span: the latest completion on any backend's timeline — the
        # fleet clock itself only tracks event starts (see _harvest)
        span = max([self.clock_s]
                   + [r.finish_time for r in reqs
                      if r.finish_time is not None])
        ttft_hit = np.array([r.ttft for r in done
                             if r.cache_hit and r.ttft is not None])
        ttft_miss = np.array([r.ttft for r in done
                              if not r.cache_hit and r.ttft is not None])
        per_backend = [
            {"weight": b.weight, "dispatched": b.n_dispatched,
             "completed": b.n_completed, "steps": b.n_steps,
             "breaker": b.breaker.state, "alive": b.alive,
             "draining": b.draining}
            for b in self.backends]
        return FleetReport(
            n_submitted=len(reqs),
            n_completed=len(done),
            n_rejected=len(self.rejected),
            n_retries=self.n_retries,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            total_tokens=sum(len(r.tokens) for r in done),
            span_s=span,
            ttft_hit=ttft_hit, ttft_miss=ttft_miss,
            rejected=list(self.rejected),
            per_backend=per_backend)

    def close(self):
        for b in self.backends:
            b.handle.close()


# ------------------------------------------------------ fleet builder ----

def local_fleet(cfg, params, plan, n: int, *, weights=None,
                max_concurrency: int = 8, share_jit: bool = True,
                **engine_kwargs) -> list:
    """`n` in-process ServeEngine replicas behind EngineBackend
    handles — the fleet a single host can stand up today; a multi-host
    deployment swaps in RPC handles over the same BackendHandle
    surface. Like the meshless dp replicas (DESIGN.md §5), the engines
    share jit caches (identical executables; independent scheduler /
    arena / key-chain / clock state) so fleet size never multiplies
    trace time. Lazy engine import keeps this module importable
    engine-free."""
    if weights is not None and len(weights) != n:
        raise ValueError(
            f"weights has {len(weights)} entries for {n} engines")
    from repro.serving.engine import ServeEngine
    engines = [ServeEngine(cfg, params, plan, **engine_kwargs)
               for _ in range(n)]
    if share_jit and engines and engines[0].decoder is not None:
        # replica-routed engines (dp>1) manage their own sharing; a
        # meshed engine's executables bind to its mesh — share only
        # the plain meshless single-replica case
        if engines[0].mesh is None:
            for e in engines[1:]:
                e.decoder._cache = engines[0].decoder._cache
                e._prefill_fns = engines[0]._prefill_fns
    weights = weights or [1.0] * n
    return [Backend(handle=EngineBackend(e), weight=float(w),
                    max_concurrency=max_concurrency)
            for e, w in zip(engines, weights)]


# ------------------------------------------------------- async facade ----

class AsyncGateway:
    """Asyncio front door over a FleetGateway: concurrent client
    coroutines await generations while one driver coroutine advances
    the fleet clock. The modeled clock still does the timing — the
    event loop only provides the concurrency surface a network server
    would mount (llm-farm's FastAPI /ask endpoint, made local)."""

    def __init__(self, gateway: FleetGateway):
        self.gw = gateway
        self._driving = False

    async def _drive(self):
        import asyncio
        if self._driving:
            return
        self._driving = True
        try:
            while self.gw.has_work:
                if not self.gw.step():
                    break
                await asyncio.sleep(0)     # yield to waiting clients
        finally:
            self._driving = False

    async def generate(self, prompt, max_new: int = 32,
                       arrival_time: float = None) -> list:
        """Submit and await the full token list (typed rejection
        raises BackendUnavailable)."""
        out = [tok async for tok in self.stream(prompt, max_new,
                                                arrival_time)]
        return out

    async def stream(self, prompt, max_new: int = 32,
                     arrival_time: float = None):
        """Async token iterator: yields each token as its backend
        step completes (or instantly on a response-LRU hit)."""
        import asyncio
        uid = self.gw.submit(prompt, max_new, arrival_time)
        req = self.gw.requests[uid]
        driver = asyncio.ensure_future(self._drive())
        sent, epoch = 0, req.epoch
        try:
            while True:
                if req.epoch != epoch:
                    sent, epoch = 0, req.epoch
                while sent < len(req.events):
                    yield req.events[sent][1]
                    sent += 1
                if req.done:
                    break
                if driver.done():
                    driver.result()    # crashed driver raises here
                await asyncio.sleep(0)
        finally:
            if req.done and not self.gw.has_work:
                await driver
            elif driver.done():
                driver.result()
        if req.rejected:
            raise BackendUnavailable(
                f"request {uid} rejected: {req.reject_reason}")
