"""Serving family registry (DESIGN.md §8).

`ServeEngine` used to hardcode `dense.make_model` behind a
`family in ("dense", "vlm")` assert; every family the engine can serve
is now one `ServingFamily` entry keyed on `cfg.family`, bundling the
four family-specific pieces of the stack:

* `make_model(cfg)` — the data-plane model (prefill + Model API);
* `make_decode_step(cfg)` — the traced decode executable with the
  uniform serving signature
  `(params, tokens, cache, plan, active_mask) -> (logits, cache,
  trace)`: `active_mask` keeps freed KV-arena lanes from steering
  selection, and `trace` is the per-layer activation trace the storage
  plane prices (dense: (L, G, kc) cold-cluster ids; moe: (L, E)
  kept-dispatch expert counts, or the two-level (L, E, 1+ncc) form
  when cfg.moe_intra_expert prices hot/cold clusters *inside* each
  expert — DESIGN.md §9);
* `build_plan(cfg, freqs=None, hw=None, backend="jnp",
  storage_dtype="fp16")` — the ExecutionPlan the bucketed decoder and
  storage plane consume (dense: the offline hot-first planner; moe:
  experts-as-clusters, `build_moe_plan`). `backend` picks the
  cold-path kernel the per-bucket plans carry ('jnp' | 'pallas',
  DESIGN.md §10); moe raises on 'pallas' (its cold path is expert
  dispatch). `storage_dtype` declares the cold bundles' on-storage
  dtype ('fp16' | 'int8' | 'int4-mixed', §7.6) — it rides on every
  bucket's HybridPlan and the storage plane prices it;
* `prepare_params(params, plan)` — the offline weight transform
  (dense: hot-first neuron permutation; moe: identity for
  whole-expert plans — the architecture already makes clusters
  explicit — and the per-expert hot-first permutation for two-level
  plans), followed by cold-bundle quantization to the plan's declared
  storage dtype (quant/storage.py; identity for fp16).

The storage plane keeps its own half of the registry
(`storage_plane.make_storage_view`) so it stays importable without the
engine. The `vlm` entry serves the LM backbone through the dense data
plane — exactly what the engine did before the registry existed (the
vision tower is a stub; serving prompts are token streams).

New families register with `register_family` and automatically join
the family-conformance battery (tests/test_family_conformance.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

__all__ = ["ServingFamily", "register_family", "serving_family",
           "servable_families", "default_archs"]


@dataclass(frozen=True)
class ServingFamily:
    """One servable model family's factory bundle."""
    family: str
    make_model: Callable           # (cfg) -> models.dense.Model
    make_decode_step: Callable     # (cfg) -> traced serving decode fn
    build_plan: Callable           # (cfg, freqs=None, hw=None,
                                   #  backend="jnp", storage_dtype=
                                   #  "fp16") -> ExecutionPlan
    prepare_params: Callable       # (params, plan) -> params
    default_arch: str = ""         # the family's representative config
    # cold-path backends build_plan accepts ('jnp' always; 'pallas'
    # only where the cold path is a cluster gather — moe's is expert
    # dispatch). The semantic trace registry enumerates decode
    # coverage from this instead of probing build_plan for the raise.
    backends: tuple = ("jnp",)


_REGISTRY: dict = {}


def register_family(fam: ServingFamily):
    _REGISTRY[fam.family] = fam
    return fam


def servable_families() -> tuple:
    return tuple(sorted(_REGISTRY))


def default_archs() -> dict:
    """family -> representative arch, straight from the registry (the
    single source for launch/serve.py --family and the conformance
    battery's coverage check)."""
    return {f: e.default_arch for f, e in sorted(_REGISTRY.items())}


def serving_family(cfg) -> ServingFamily:
    """Registry lookup for a config's family; unknown families raise
    with the servable set named (the old assert, made extensible)."""
    if cfg.family not in _REGISTRY:
        raise ValueError(
            f"family {cfg.family!r} ({cfg.name}) is not servable; "
            f"registered families: {servable_families()}")
    return _REGISTRY[cfg.family]


# ------------------------------------------------- built-in families ----

def _dense_build_plan(cfg, freqs=None, hw=None, backend="jnp",
                      storage_dtype="fp16"):
    from repro.core.planner import build_plan
    return build_plan(cfg, freqs, hw=hw, backend=backend,
                      storage_dtype=storage_dtype)


def _dense_prepare(params, plan):
    from repro.core.planner import permute_ffn_params
    from repro.quant.storage import quantize_plan_params
    params = permute_ffn_params(params, plan.neuron_order)
    return quantize_plan_params(params, plan)


def _dense_family(name: str, arch: str) -> ServingFamily:
    from repro.models import dense
    return ServingFamily(
        family=name,
        make_model=dense.make_model,
        make_decode_step=lambda cfg: dense.make_decode_step(
            cfg, collect_indices=True),
        build_plan=_dense_build_plan,
        prepare_params=_dense_prepare,
        default_arch=arch,
        backends=("jnp", "pallas"),
    )


def _moe_build_plan(cfg, freqs=None, hw=None, backend="jnp",
                    storage_dtype="fp16"):
    # freqs: within-expert activation frequencies (L, E*f) for the
    # two-level plan (cfg.moe_intra_expert); ignored for whole-expert
    if backend not in (None, "jnp"):
        raise ValueError(
            f"moe has no {backend!r} cold-path backend: its cold path "
            f"is expert dispatch (models/moe.py), not a cluster gather")
    from repro.core.planner import build_moe_plan
    return build_moe_plan(cfg, freqs, hw=hw, storage_dtype=storage_dtype)


def _moe_prepare(params, plan):
    # two-level plans carry a per-expert hot-first permutation; the
    # whole-expert plan's order is the identity (experts already ARE
    # the clusters), so permutation stays a no-op there. Cold-bundle
    # quantization (simulated, in place on the routed experts) follows
    # for non-fp16 plans.
    if any(getattr(p, "n_expert_hot", 0)
           for p in plan.plans.values()):
        from repro.core.planner import permute_moe_params
        params = permute_moe_params(params, plan.neuron_order)
    from repro.quant.storage import quantize_plan_params
    return quantize_plan_params(params, plan)


def _moe_family() -> ServingFamily:
    from repro.models import moe
    return ServingFamily(
        family="moe",
        make_model=moe.make_model,
        make_decode_step=lambda cfg: moe.make_decode_step(
            cfg, collect_indices=True),
        build_plan=_moe_build_plan,
        prepare_params=_moe_prepare,
        default_arch="deepseek-moe-16b",
    )


register_family(_dense_family("dense", "smollm-135m"))
# vlm serves its LM backbone through the dense data plane (the vision
# tower is a stub; engine prompts are token streams) — the pre-registry
# engine behavior, now stated instead of implied.
register_family(_dense_family("vlm", "qwen2-vl-2b"))
register_family(_moe_family())
