"""Storage-dtype bundle quantization for the serving plane (§7.6 + §4.4).

`HybridPlan.storage_dtype` declares how *cold* neuron bundles live on
the slow tier: 'fp16' (legacy fp accounting), 'int8' (per-channel int8
+ one scale per row) or 'int4-mixed' (the paper's hybrid scheme:
per-channel INT4 with the top-|w| outliers preserved in FP16).
`ServingFamily.prepare_params` routes through `quantize_plan_params`
so every consumer of the params sees one consistent story:

* `w` keeps full-precision values for the hot/pinned prefix (the paper
  keeps dense-activation weights high-precision on the NPU) and holds
  the *dequantized roundtrip* for cold rows — prefill, profiling and
  the hot compute of larger buckets all read what the storage actually
  holds;
* `wq` (int8 codes), `wsc` (fp32 per-row scales) and, for int4-mixed,
  `wout` (fp16 outlier sidecar) are the stored representation the cold
  paths gather from, dequantizing at the gather boundary — in the jnp
  chain and in the pallas fused kernel (int8 DMA into VMEM, dequant
  before the gated FFN) — so jnp and pallas decode stay
  token-identical.

The containers are full-size (all N rows) so `[n_hot:]` slicing stays
aligned with `w` for every batch bucket; rows below the quantization
boundary (the smallest bucket's hot prefix) are never read from them.
MoE plans quantize the routed experts' cold rows in place (simulated
quantization — the moe cold path is expert dispatch, not a cluster
gather), leaving shared experts fp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STORAGE_DTYPES = ("fp16", "int8", "int4-mixed")
OUTLIER_FRAC = 0.01       # §7.6: ~1% of weights preserved in FP16

# Declared token-level divergence bounds (the quality gate the
# conformance battery and the serving-quant bench both check): minimum
# teacher-forced argmax agreement between quantized and fp decode on
# the reduced random-init battery archs. Random-init weights are the
# worst case for per-channel int4 — trained checkpoints quantize far
# better (§7.6 reports negligible loss) — so these are floors, not
# expected quality.
TOKEN_AGREEMENT_BOUND = {"int8": 0.90, "int4-mixed": 0.60}


def check_storage_dtype(storage_dtype: str) -> str:
    if storage_dtype not in STORAGE_DTYPES:
        raise ValueError(
            f"unknown storage dtype {storage_dtype!r}; expected one of "
            f"{STORAGE_DTYPES}")
    return storage_dtype


def plan_storage_dtype(plan) -> str:
    """The single storage dtype an ExecutionPlan declares (every batch
    bucket must agree — the stored bytes don't change per batch)."""
    sds = {getattr(p, "storage_dtype", "fp16")
           for p in plan.plans.values()}
    if len(sds) != 1:
        raise ValueError(
            f"batch buckets disagree on storage_dtype: {sorted(sds)}")
    return check_storage_dtype(sds.pop())


def _topk_mask_batched(mag, k: int):
    """(M, S) magnitudes -> bool (M, S) with exactly k True per row
    (ties broken by lowest index — same contract as
    `quantize.exact_topk_mask`, batched)."""
    _, idx = jax.lax.top_k(mag, k)
    mask = jnp.zeros(mag.shape, bool)
    return mask.at[jnp.arange(mag.shape[0])[:, None], idx].set(True)


def quantize_bundles(w, storage_dtype: str,
                     outlier_frac: float = OUTLIER_FRAC,
                     batch_dims: int = 0):
    """Quantize bundle weights w (..., D) per channel (scale over the
    last dim) -> {'wq' int8, 'wsc' f32 (...,), ['wout' f16 (..., D)]}.

    int4-mixed keeps exactly k = round(outlier_frac * size) top-|w|
    outliers per weight matrix in the fp16 sidecar; `batch_dims` leading
    dims each get their own outlier budget (e.g. 1 for a stacked
    (L, N, R, D) tensor: per-layer budgets).

    Dequantize is `wq * wsc[..., None] (+ wout)` — outlier positions
    carry a zero int4 code, so the sidecar add is exact.
    """
    check_storage_dtype(storage_dtype)
    if storage_dtype == "fp16":
        raise ValueError("fp16 is the identity: nothing to quantize")
    w32 = jnp.asarray(w, jnp.float32)
    if storage_dtype == "int8":
        scale = jnp.max(jnp.abs(w32), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
        return {"wq": q, "wsc": scale.squeeze(-1)}
    lead = 1
    for d in w32.shape[:batch_dims]:
        lead *= d
    flat = jnp.abs(w32).reshape(lead, -1)
    k = max(1, int(round(flat.shape[1] * outlier_frac)))
    mask = _topk_mask_batched(flat, k).reshape(w32.shape)
    base = jnp.where(mask, 0.0, w32)
    scale = jnp.max(jnp.abs(base), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(base / scale), -8, 7).astype(jnp.int8)
    wout = jnp.where(mask, w32, 0.0).astype(jnp.float16)
    return {"wq": q, "wsc": scale.squeeze(-1), "wout": wout}


def dequantize_bundles(qd):
    """fp32 values of a `quantize_bundles` result — the exact formula
    both cold paths fuse at their gather boundary."""
    deq = qd["wq"].astype(jnp.float32) * qd["wsc"][..., None]
    if "wout" in qd:
        deq = deq + qd["wout"].astype(jnp.float32)
    return deq


def quant_boundary(plan) -> int:
    """First quantized neuron row: the smallest bucket's hot prefix.
    Every bucket's cold region [n_hot, N) lies inside [boundary, N), so
    one stored representation serves all buckets."""
    return min(p.n_hot for p in plan.plans.values())


def _quantize_ffn(params, plan, storage_dtype):
    """Dense/vlm: attach full-size wq/wsc(/wout) containers and write
    the dequantized roundtrip back into w's cold rows."""
    layers = params["layers"]
    ffn = layers["ffn"]
    w = ffn["w"]                                       # (L, N, R, D)
    n_q = quant_boundary(plan)
    qd = quantize_bundles(w, storage_dtype, batch_dims=1)
    deq = dequantize_bundles(qd).astype(w.dtype)
    w = jnp.concatenate([w[:, :n_q], deq[:, n_q:]], axis=1)
    new_ffn = dict(ffn, w=w, **qd)
    return dict(params, layers=dict(layers, ffn=new_ffn))


def _quantize_moe(params, plan, storage_dtype):
    """MoE: simulated in-place quantization of the routed experts' cold
    rows (whole-expert plans: every routed row; two-level plans: rows
    past the per-expert hot prefix). Shared experts stay fp."""
    layers = params["layers"]
    moe = layers["moe"]
    ex = moe["experts"]                                # (L, E, f, R, D)
    L, E, f = ex.shape[:3]
    n_q_e = min(getattr(p, "n_expert_hot", 0)
                for p in plan.plans.values())
    cold = ex[:, :, n_q_e:]
    qd = quantize_bundles(
        cold.reshape(L * E, *cold.shape[2:]), storage_dtype,
        batch_dims=1)                                  # per-expert budget
    deq = dequantize_bundles(qd).reshape(cold.shape).astype(ex.dtype)
    ex = jnp.concatenate([ex[:, :, :n_q_e], deq], axis=2)
    return dict(params, layers=dict(layers, moe=dict(moe, experts=ex)))


def quantize_plan_params(params, plan):
    """Quantize cold FFN bundles to the plan's declared storage dtype
    (identity for fp16). Called on *permuted* params — the hot-first
    order decides which rows are cold."""
    sd = plan_storage_dtype(plan)
    if sd == "fp16":
        return params
    layers = params.get("layers", {})
    if "ffn" in layers:
        return _quantize_ffn(params, plan, sd)
    if "moe" in layers:
        return _quantize_moe(params, plan, sd)
    raise ValueError("params carry neither a dense 'ffn' nor a 'moe' "
                     "layer stack; cannot quantize cold bundles")
