"""Quantization (paper §7.6): INT4 group-wise + mixed-precision outliers.

The paper's accuracy result hinges on its hybrid scheme: NPUs only do
per-channel INT4 (QNN's accuracy collapses on GSM8K, Table 7);
PowerInfer-2 keeps outlier weights in INT8/FP16 and per-channel-INT4
quantizes the rest (AWQ-inspired), matching llama.cpp's group-32
accuracy at NPU speed. All three schemes are implemented (simulated
quantization: values are quantized/dequantized; storage is int8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Smallest priced read block of the modeled storage tier (UFS 4.0 data
# unit, io_model.UFS40's first curve point). Quantized bundle sizes are
# padded to this granularity; the storage plane passes its own block
# size instead of relying on this default.
BUNDLE_ALIGN = 4096


def quantize_groupwise_int4(w, group: int = 32):
    """llama.cpp-style: one scale per `group` consecutive weights.

    w (..., D) with D % group == 0 -> {'q': int8 in [-8,7], 'scales'}.
    """
    shape = w.shape
    if shape[-1] % group:
        raise ValueError(
            f"groupwise int4 needs the channel dim to be a multiple of "
            f"group={group}; got D={shape[-1]}")
    wg = w.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    scale = jnp.max(jnp.abs(wg), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(wg / scale), -8, 7).astype(jnp.int8)
    return {"q": q.reshape(shape), "scales": scale.squeeze(-1),
            "group": group}


def dequantize_groupwise_int4(qw):
    q, scale, group = qw["q"], qw["scales"], qw["group"]
    shape = q.shape
    qg = q.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    return (qg * scale[..., None]).reshape(shape)


def quantize_per_channel_int4(w):
    """QNN-style: one scale per output channel (last-but... row)."""
    w32 = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w32), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    # round the fp32 copy: bf16/fp16 inputs must yield the same codes
    q = jnp.clip(jnp.round(w32 / scale), -8, 7).astype(jnp.int8)
    return {"q": q, "scales": scale.squeeze(-1)}


def dequantize_per_channel_int4(qw):
    return qw["q"].astype(jnp.float32) * qw["scales"][..., None]


def exact_topk_mask(mag, k: int):
    """Boolean mask selecting exactly the k largest entries of `mag`
    (ties broken by lowest flat index, `lax.top_k`'s order). A `>=
    threshold` mask keeps *more* than k under tied magnitudes, which
    silently inflates the stored-FP16 byte fraction past the priced
    `outlier_frac`."""
    flat = mag.reshape(-1)
    _, idx = jax.lax.top_k(flat, k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return mask.reshape(mag.shape)


def quantize_mixed(w, outlier_frac: float = 0.01):
    """PowerInfer-2's scheme (AWQ-inspired, §7.6): the top-|w| outliers
    are *preserved* in high precision (FP16), the rest is per-channel
    INT4 (the only granularity mobile NPUs support)."""
    w32 = w.astype(jnp.float32)
    k = max(1, int(w32.size * outlier_frac))
    outlier_mask = exact_topk_mask(jnp.abs(w32), k)
    base = jnp.where(outlier_mask, 0.0, w32)
    q4 = quantize_per_channel_int4(base)
    o_f16 = jnp.where(outlier_mask, w32, 0.0).astype(jnp.float16)
    return {"q4": q4, "outlier_mask": outlier_mask, "o_f16": o_f16}


def dequantize_mixed(qw):
    base = dequantize_per_channel_int4(qw["q4"])
    return jnp.where(qw["outlier_mask"], qw["o_f16"].astype(jnp.float32),
                     base)


def quant_error(w, scheme: str = "mixed", **kw) -> float:
    """Relative Frobenius error of a scheme — the Table 7 proxy metric."""
    w32 = jnp.asarray(w, jnp.float32)
    if scheme == "group32":
        deq = dequantize_groupwise_int4(quantize_groupwise_int4(w32, **kw))
    elif scheme == "per_channel":
        deq = dequantize_per_channel_int4(quantize_per_channel_int4(w32))
    elif scheme == "mixed":
        deq = dequantize_mixed(quantize_mixed(w32, **kw))
    else:
        raise ValueError(scheme)
    return float(jnp.linalg.norm(deq - w32) / (jnp.linalg.norm(w32) + 1e-9))


def bundle_nbytes_int4(d_model: int, gated: bool = True,
                       align: int = BUNDLE_ALIGN,
                       outlier_frac: float = 0.0) -> int:
    """Paper §4.4: a 4-bit Gate-Up-Down bundle is ~7.5KB for d=4096
    (2KB int4 weights + 0.5KB group scales per matrix), padded to the
    storage read granularity `align` — 4KB UFS data units, so the
    d=4096 bundle lands on 8KB, matching the paper's bundle-size table.
    `outlier_frac` adds the mixed scheme's FP16 outlier sidecar bytes
    (§7.6) before padding; `align=0` returns the raw (unpadded) size.
    """
    R = 3 if gated else 2
    per_matrix = d_model // 2 + d_model // 32 * 2   # int4 + fp16 group scales
    raw = R * per_matrix + int(round(outlier_frac * R * d_model)) * 2
    if not align:
        return raw
    return ((raw + align - 1) // align) * align


def bundle_nbytes(d_model: int, storage_dtype: str, rows: int = 3,
                  itemsize: int = 2, align: int = BUNDLE_ALIGN,
                  outlier_frac: float = 0.01) -> int:
    """Bytes of one neuron bundle (`rows` x d_model weights) as stored
    at `storage_dtype` — the single accounting the storage plane prices
    with (ROADMAP item 3: NeuronCache/ColdStore price the *declared*
    dtype, not fp bytes).

      fp16       rows * d_model * itemsize (legacy fp accounting,
                 unpadded — keeps fp benchmarks byte-identical)
      int8       per-channel int8 + one fp16 scale per row, padded
      int4-mixed per-channel int4 + group scales + FP16 outlier
                 sidecar (§7.6), padded — `bundle_nbytes_int4`
    """
    if storage_dtype in (None, "fp16"):
        return rows * d_model * itemsize
    if storage_dtype == "int8":
        raw = rows * (d_model + 2)
        return ((raw + align - 1) // align) * align if align else raw
    if storage_dtype == "int4-mixed":
        return bundle_nbytes_int4(d_model, gated=rows == 3, align=align,
                                  outlier_frac=outlier_frac)
    raise ValueError(
        f"unknown storage dtype {storage_dtype!r}; expected one of "
        f"'fp16', 'int8', 'int4-mixed'")


# ------------------------------------------------------- int8 KV cache ----
#
# Beyond-paper optimization (EXPERIMENTS.md §Roofline: every decode row
# is memory-bound and KV-cache traffic dominates at large batch): store
# K/V in int8 with per-(token, head) scales — 2x less cache traffic for
# <0.5% attention-output error. The dequantize fuses into the attention
# dots on TPU (operands stream int8 from HBM).

def quantize_kv(kv):
    """kv (..., T, KV, dh) -> {'q': int8, 'scale': f32 (..., T, KV, 1)}."""
    import jax.numpy as _jnp
    kv32 = kv.astype(_jnp.float32)
    scale = _jnp.max(_jnp.abs(kv32), axis=-1, keepdims=True) / 127.0
    scale = _jnp.maximum(scale, 1e-8)
    q = _jnp.clip(_jnp.round(kv32 / scale), -127, 127).astype(_jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_kv(qkv):
    return qkv["q"].astype(jnp.float32) * qkv["scale"]


def kv_quant_error(kv) -> float:
    """Relative error of the int8 KV roundtrip."""
    deq = dequantize_kv(quantize_kv(kv))
    kv32 = jnp.asarray(kv, jnp.float32)
    return float(jnp.linalg.norm(deq - kv32) / (jnp.linalg.norm(kv32) + 1e-9))
