"""Quantization (paper §7.6): INT4 group-wise + mixed-precision outliers.

The paper's accuracy result hinges on its hybrid scheme: NPUs only do
per-channel INT4 (QNN's accuracy collapses on GSM8K, Table 7);
PowerInfer-2 keeps outlier weights in INT8/FP16 and per-channel-INT4
quantizes the rest (AWQ-inspired), matching llama.cpp's group-32
accuracy at NPU speed. All three schemes are implemented (simulated
quantization: values are quantized/dequantized; storage is int8).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_groupwise_int4(w, group: int = 32):
    """llama.cpp-style: one scale per `group` consecutive weights.

    w (..., D) with D % group == 0 -> {'q': int8 in [-8,7], 'scales'}.
    """
    shape = w.shape
    wg = w.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    scale = jnp.max(jnp.abs(wg), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(wg / scale), -8, 7).astype(jnp.int8)
    return {"q": q.reshape(shape), "scales": scale.squeeze(-1),
            "group": group}


def dequantize_groupwise_int4(qw):
    q, scale, group = qw["q"], qw["scales"], qw["group"]
    shape = q.shape
    qg = q.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    return (qg * scale[..., None]).reshape(shape)


def quantize_per_channel_int4(w):
    """QNN-style: one scale per output channel (last-but... row)."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -8, 7).astype(jnp.int8)
    return {"q": q, "scales": scale.squeeze(-1)}


def dequantize_per_channel_int4(qw):
    return qw["q"].astype(jnp.float32) * qw["scales"][..., None]


def quantize_mixed(w, outlier_frac: float = 0.01):
    """PowerInfer-2's scheme (AWQ-inspired, §7.6): the top-|w| outliers
    are *preserved* in high precision (FP16), the rest is per-channel
    INT4 (the only granularity mobile NPUs support)."""
    w32 = w.astype(jnp.float32)
    flat = jnp.abs(w32).reshape(-1)
    k = max(1, int(flat.shape[0] * outlier_frac))
    thresh = jnp.sort(flat)[-k]
    outlier_mask = jnp.abs(w32) >= thresh
    base = jnp.where(outlier_mask, 0.0, w32)
    q4 = quantize_per_channel_int4(base)
    o_f16 = jnp.where(outlier_mask, w32, 0.0).astype(jnp.float16)
    return {"q4": q4, "outlier_mask": outlier_mask, "o_f16": o_f16}


def dequantize_mixed(qw):
    base = dequantize_per_channel_int4(qw["q4"])
    return jnp.where(qw["outlier_mask"], qw["o_f16"].astype(jnp.float32),
                     base)


def quant_error(w, scheme: str = "mixed", **kw) -> float:
    """Relative Frobenius error of a scheme — the Table 7 proxy metric."""
    w32 = jnp.asarray(w, jnp.float32)
    if scheme == "group32":
        deq = dequantize_groupwise_int4(quantize_groupwise_int4(w32, **kw))
    elif scheme == "per_channel":
        deq = dequantize_per_channel_int4(quantize_per_channel_int4(w32))
    elif scheme == "mixed":
        deq = dequantize_mixed(quantize_mixed(w32, **kw))
    else:
        raise ValueError(scheme)
    return float(jnp.linalg.norm(deq - w32) / (jnp.linalg.norm(w32) + 1e-9))


def bundle_nbytes_int4(d_model: int, gated: bool = True) -> int:
    """Paper §4.4: a 4-bit Gate-Up-Down bundle is ~7.5KB for d=4096
    (2KB int4 weights + 0.5KB scales per matrix), aligned to 8KB."""
    R = 3 if gated else 2
    per_matrix = d_model // 2 + d_model // 32 * 2   # int4 + fp16 group scales
    raw = R * per_matrix
    return ((raw + 4095) // 4096) * 4096            # 4KB alignment


# ------------------------------------------------------- int8 KV cache ----
#
# Beyond-paper optimization (EXPERIMENTS.md §Roofline: every decode row
# is memory-bound and KV-cache traffic dominates at large batch): store
# K/V in int8 with per-(token, head) scales — 2x less cache traffic for
# <0.5% attention-output error. The dequantize fuses into the attention
# dots on TPU (operands stream int8 from HBM).

def quantize_kv(kv):
    """kv (..., T, KV, dh) -> {'q': int8, 'scale': f32 (..., T, KV, 1)}."""
    import jax.numpy as _jnp
    scale = _jnp.max(_jnp.abs(kv.astype(_jnp.float32)), axis=-1,
                     keepdims=True) / 127.0
    scale = _jnp.maximum(scale, 1e-8)
    q = _jnp.clip(_jnp.round(kv / scale), -127, 127).astype(_jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_kv(qkv):
    return qkv["q"].astype(jnp.float32) * qkv["scale"]


def kv_quant_error(kv) -> float:
    """Relative error of the int8 KV roundtrip."""
    deq = dequantize_kv(quantize_kv(kv))
    kv32 = jnp.asarray(kv, jnp.float32)
    return float(jnp.linalg.norm(deq - kv32) / (jnp.linalg.norm(kv32) + 1e-9))
