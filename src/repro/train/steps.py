"""Generic training step over the uniform Model API.

Cross-entropy LM loss with label masking (labels < 0 are ignored —
used for VLM image positions and padding). Works for every family:
the batch dict carries whatever the model's forward expects.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW


def lm_loss(logits, labels):
    """logits (B,S,V), labels (B,S) int32 (-1 = masked)."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(model):
    def loss_fn(params, batch):
        logits = model.forward(params, batch)
        S_logits = logits.shape[1]
        labels = batch["labels"]
        if labels.shape[1] < S_logits:       # VLM: image positions unmasked
            pad = jnp.full((labels.shape[0], S_logits - labels.shape[1]),
                           -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return lm_loss(logits, labels)
    return loss_fn


def make_train_step(model, optimizer: AdamW):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step
