"""JAX version-compatibility shims, centralized.

The repo tracks the *current* jax API (explicit axis types, context
meshes via `jax.set_mesh`, `jax.shard_map` with `axis_names`); older
pins — including the oldest-supported CI leg — predate those names.
Every renamed/moved symbol the codebase relies on is resolved here
once, so the next upstream rename breaks one module (and a CI matrix
leg), not the default branch.

Shimmed surface:
  * AxisType            — `jax.sharding.AxisType` (new) or a stand-in
                          enum accepted (and ignored) by `make_mesh`.
  * make_mesh           — accepts `axis_types` on every version.
  * set_mesh            — context manager: `jax.set_mesh` when present,
                          otherwise a thread-local context mesh + the
                          classic `with mesh:` resource env.
  * current_mesh        — the mesh set by `set_mesh` (abstract on new
                          jax, concrete on old), or None.
  * shard_map           — `jax.shard_map(..., axis_names=, check_vma=)`
                          mapped onto `jax.experimental.shard_map`'s
                          `auto=`/`check_rep=` on old versions.
  * CompilerParams      — pallas TPU compiler params (renamed from
                          TPUCompilerParams across releases).
"""
from __future__ import annotations

import contextlib
import enum
import inspect
import threading

import jax


# ------------------------------------------------------------ AxisType ----

if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):          # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """`jax.make_mesh` that tolerates `axis_types` on every version.

    Old jax has no axis-type concept; dropping the argument is exact
    because this repo only ever requests Auto axes."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


# ------------------------------------------------------- context mesh ----

_tls = threading.local()


def _ctx_stack():
    if not hasattr(_tls, "mesh_stack"):
        _tls.mesh_stack = []
    return _tls.mesh_stack


# One probe decides both halves of the context-mesh shim: set_mesh and
# current_mesh must agree on where the ambient mesh lives, or versions
# in the gap (get_abstract_mesh exists, jax.set_mesh doesn't) would
# push onto a stack that current_mesh never reads.
_HAS_SET_MESH = hasattr(jax, "set_mesh")


@contextlib.contextmanager
def set_mesh(mesh):
    """Enter `mesh` as the ambient mesh (`jax.set_mesh` analogue).

    On old jax the concrete mesh goes on a thread-local stack (read by
    `current_mesh`) and also enters the classic `with mesh:` resource
    env so bare-PartitionSpec machinery keeps working."""
    if _HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
        return
    _ctx_stack().append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _ctx_stack().pop()


def current_mesh():
    """The ambient mesh set by `set_mesh`, or None outside any."""
    if _HAS_SET_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is None or getattr(m, "empty", True):
            return None
        return m
    stack = _ctx_stack()
    return stack[-1] if stack else None


# ---------------------------------------------------------- shard_map ----

def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    """`jax.shard_map` with the modern keyword surface on every version.

    axis_names: the *manual* axes (new-jax semantics). Old jax takes the
    complement as `auto=`; `check_vma` maps to `check_rep`."""
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # Old xla's spmd partitioner miscompiles partial-manual shard_map
    # (auto=...) — go fully manual instead. Axes absent from the specs
    # are per-device-replicated either way, and check_rep=False skips
    # the replication check that partial-manual would have discharged.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False if axis_names is not None
                      else check_vma)


# ------------------------------------------------------------- pallas ----

def pallas_tpu_compiler_params():
    """The pallas-TPU CompilerParams class under its current name."""
    from jax.experimental.pallas import tpu as pltpu
    cp = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cp is None:
        raise AttributeError(
            "no pallas TPU CompilerParams class found in this jax")
    return cp
