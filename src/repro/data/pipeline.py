"""Deterministic synthetic token pipeline.

Generates Zipf-distributed token streams with short-range Markov
structure (repeated n-grams), which is enough to (a) drive training
loss down measurably, (b) give the activation profiler non-uniform
neuron statistics, and (c) exercise the data path (sharded host ->
device batches) end to end. Fully offline, seeded, reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram_repeat: float = 0.3     # prob. of copying a recent token


class SyntheticTokens:
    """Iterator of {'tokens': (B,S), 'labels': (B,S)} numpy batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Zipf over the vocab, renormalized
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.p = p / p.sum()

    def _sequence(self, length):
        out = np.empty(length + 1, np.int32)
        base = self.rng.choice(self.cfg.vocab_size, size=length + 1, p=self.p)
        out[:] = base
        # inject n-gram copies for learnable structure
        copy = self.rng.random(length + 1) < self.cfg.ngram_repeat
        lag = self.rng.integers(1, 8, size=length + 1)
        for i in np.nonzero(copy)[0]:
            if i >= lag[i]:
                out[i] = out[i - lag[i]]
        return out

    def batch(self):
        cfg = self.cfg
        seqs = np.stack([self._sequence(cfg.seq_len)
                         for _ in range(cfg.batch_size)])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def __iter__(self):
        while True:
            yield self.batch()


def shard_batch(batch, mesh=None):
    """Host batch -> device arrays, batch dim sharded over pod+data."""
    if mesh is None:
        return jax.tree.map(jnp.asarray, batch)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import batch_axes
    ax = batch_axes(mesh)

    def put(x):
        spec = P(ax, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)
