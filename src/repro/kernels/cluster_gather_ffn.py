"""Pallas TPU kernel: gathered neuron-cluster FFN (the paper's cold path).

The TPU-native form of PowerInfer-2's neuron-cluster pipeline (§4.3):
the grid walks the *active* clusters selected by the predictor; a
scalar-prefetched index vector drives each BlockSpec's index_map, so
the Pallas pipeline DMA-streams exactly the activated clusters from
HBM ("flash" analogue) into VMEM ("DRAM" analogue) while the MXU
computes the previous cluster — compute/I-O overlap at cluster
granularity, which is precisely Fig 6(b) one level down the memory
hierarchy.

Weight layout matches the cold store: bundled (N, R, D) with R rows per
neuron (Gate/Up/Down) so one block fetch brings a whole cluster bundle
(§4.4 position-major bundling).

Blocks: w block (cluster_size, R, D) — cluster_size is a multiple of
128 in production configs, so the (B, D) x (D, cs) matmuls are
MXU-aligned. Output (B, D) accumulates in fp32 across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

# Renamed TPUCompilerParams -> CompilerParams across jax releases; the
# compat module resolves whichever this install provides.
CompilerParams = pallas_tpu_compiler_params()


def _kernel(idx_ref, x_ref, w_ref, o_ref, *, activation: str, gated: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                   # (B, D)
    wg = w_ref[:, 0, :]                              # (cs, D)
    g = jax.lax.dot_general(x, wg, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (B, cs)
    if activation == "silu":
        h = jax.nn.silu(g)
    elif activation == "relu2":
        h = jnp.square(jnp.maximum(g, 0.0))
    else:                                            # gelu / geglu
        h = jax.nn.gelu(g, approximate=True)
    if gated:
        u = jax.lax.dot_general(x, w_ref[:, 1, :], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = h * u
    wd = w_ref[:, -1, :]                             # (cs, D)
    y = jax.lax.dot_general(h.astype(wd.dtype), wd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (B, D)
    o_ref[...] += y


@functools.partial(jax.jit, static_argnames=("activation", "cluster_size",
                                             "interpret"))
def cluster_gather_ffn(x, w, cluster_idx, *, activation: str,
                       cluster_size: int, interpret: bool = True):
    """x (B, D); w (N, R, D) in HBM; cluster_idx (K,) int32 cluster ids.

    Returns (B, D) = sum over selected clusters of the bundled FFN.
    """
    B, D = x.shape
    N, R, _ = w.shape
    K = cluster_idx.shape[0]
    assert N % cluster_size == 0
    gated = R == 3

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((B, D), lambda i, idx: (0, 0)),
            # the gather: block row = the i-th *active* cluster id
            pl.BlockSpec((cluster_size, R, D),
                         lambda i, idx: (idx[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda i, idx: (0, 0)),
    )
    w_blocked = w.reshape(N // cluster_size * cluster_size, R, D)
    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, gated=gated),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(cluster_idx, x, w_blocked)
    return out.astype(x.dtype)
