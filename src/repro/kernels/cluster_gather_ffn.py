"""Pallas TPU kernels: gathered neuron-cluster FFN (the paper's cold path).

The TPU-native form of PowerInfer-2's neuron-cluster pipeline (§4.3),
in two tiers:

* `cluster_gather_ffn` — gather-only: a scalar-prefetched index vector
  drives each BlockSpec's index_map, so the Pallas pipeline DMA-streams
  exactly the activated clusters from HBM ("flash" analogue) into VMEM
  ("DRAM" analogue) while the MXU computes the previous cluster.
  Selection (predictor score -> top-k) still happens outside, in XLA.

* `fused_cold_ffn` — the whole cold path in ONE pallas_call: predictor
  scoring, batch-union top-k cluster selection, cluster gather and the
  gated FFN GEMMs. Selection has to live *inside* the kernel here, so
  the automatic scalar-prefetch pipeline can't drive the gather;
  instead the kernel keeps the selected ids in SMEM and issues its own
  double-buffered `make_async_copy` fetches from HBM-resident weights —
  the DMA for cluster c+1 is started before the MXU computes cluster c
  (wait -> compute -> already-running copy), which is exactly Fig 6(b)
  one level down the memory hierarchy and the kernel analogue of the
  storage plane's PrefetchExecutor. The grid walks neuron groups, so
  under shard_map each 'model' shard runs the same kernel over its
  local groups.

Weight layout matches the cold store: bundled (N, R, D) with R rows per
neuron (Gate/Up/Down) so one block fetch brings a whole cluster bundle
(§4.4 position-major bundling).

Blocks: w block (cluster_size, R, D) — cluster_size is a multiple of
128 in production configs, so the (B, D) x (D, cs) matmuls are
MXU-aligned. Output (B, D) accumulates in fp32 across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params
from repro.kernels import default_interpret
from repro.models.modules import activation_fn

# Renamed TPUCompilerParams -> CompilerParams across jax releases; the
# compat module resolves whichever this install provides.
CompilerParams = pallas_tpu_compiler_params()


def _kernel(idx_ref, x_ref, w_ref, o_ref, *, activation: str, gated: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                   # (B, D)
    wg = w_ref[:, 0, :]                              # (cs, D)
    g = jax.lax.dot_general(x, wg, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (B, cs)
    if activation == "silu":
        h = jax.nn.silu(g)
    elif activation == "relu2":
        h = jnp.square(jnp.maximum(g, 0.0))
    else:                                            # gelu / geglu
        h = jax.nn.gelu(g, approximate=True)
    if gated:
        u = jax.lax.dot_general(x, w_ref[:, 1, :], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = h * u
    wd = w_ref[:, -1, :]                             # (cs, D)
    y = jax.lax.dot_general(h.astype(wd.dtype), wd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (B, D)
    o_ref[...] += y


@functools.partial(jax.jit, static_argnames=("activation", "cluster_size",
                                             "interpret"))
def cluster_gather_ffn(x, w, cluster_idx, *, activation: str,
                       cluster_size: int,
                       interpret: bool | None = None):
    """x (B, D); w (N, R, D) in HBM; cluster_idx (K,) int32 cluster ids.

    Returns (B, D) = sum over selected clusters of the bundled FFN.
    """
    if interpret is None:
        interpret = default_interpret()
    B, D = x.shape
    N, R, _ = w.shape
    K = cluster_idx.shape[0]
    assert N % cluster_size == 0
    gated = R == 3

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((B, D), lambda i, idx: (0, 0)),
            # the gather: block row = the i-th *active* cluster id
            pl.BlockSpec((cluster_size, R, D),
                         lambda i, idx: (idx[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda i, idx: (0, 0)),
    )
    w_blocked = w.reshape(N // cluster_size * cluster_size, R, D)
    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, gated=gated),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(cluster_idx, x, w_blocked)
    return out.astype(x.dtype)


# --------------------------------------------------- fused cold path ----

# Masked rows must lose every batch-union max without poisoning the
# degenerate all-masked case: with -inf the iterative argmax below would
# keep re-selecting index 0, while jax.lax.top_k over an all--inf
# vector yields the distinct ids [0, 1, ...]. finfo.min sits below any
# finite score yet above the -inf a selected entry is knocked down to,
# so both paths pick identical ids in every case.
_NEG = float(jnp.finfo(jnp.float32).min)


def _fused_kernel(*refs, activation: str, gated: bool, cats: bool,
                  kc: int, nc_g: int, cs: int, quant: bool, mixed: bool):
    """One grid step = one neuron group: score -> top-k -> gathered FFN.

    x_ref (B, D) VMEM; w_hbm (G*nc_g*cs, R, D) stays in HBM (ANY) —
    clusters are pulled in by explicit double-buffered DMA; a_ref
    (D, r) / b_ref (r, nc_g*cs) the predictor slice for this group;
    mask_ref (B, 1) live-row mask; y_ref (B, D) fp32 accumulator over
    groups; idx_ref (G, kc) SMEM selected-cluster output.

    Quantized storage (§7.6, plan.storage_dtype != 'fp16'): w_hbm
    holds the *stored* int8 codes — the cluster DMA moves int8 (3-4x
    fewer HBM bytes per bundle) and dequantize happens in VMEM right
    before the gated FFN dots: codes * per-row scale (wsc_ref, this
    group's (nc_g*cs, R) block) plus, for int4-mixed, the FP16 outlier
    sidecar (wout_hbm, double-buffered alongside the codes). The
    formula matches sparse_ffn._gather_quant exactly, so jnp and
    pallas decode stay token-identical.
    """
    if quant and mixed:
        (x_ref, w_hbm, a_ref, b_ref, mask_ref, wsc_ref, wout_hbm,
         y_ref, idx_ref) = refs
    elif quant:
        (x_ref, w_hbm, a_ref, b_ref, mask_ref, wsc_ref,
         y_ref, idx_ref) = refs
        wout_hbm = None
    else:
        x_ref, w_hbm, a_ref, b_ref, mask_ref, y_ref, idx_ref = refs
        wsc_ref = wout_hbm = None
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem, obuf=None, osem=None):
        x = x_ref[...]                                    # (B, D)
        # -- predictor scoring (fp32, matching core.predictor) --
        h = jax.lax.dot_general(
            x.astype(jnp.float32), a_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        scores = jax.lax.dot_general(
            h, b_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (B, nc_g*cs)
        # -- batch-union cluster scores (paper fn.1 + §3.1) --
        union = jnp.where(mask_ref[...] > 0.0, scores, _NEG).max(axis=0)
        cscore = union.reshape(nc_g, cs).max(axis=-1)     # (nc_g,)

        # -- iterative top-k: argmax + knock-out reproduces
        #    jax.lax.top_k exactly (ties resolve to the lowest index) --
        def select(k, sc):
            c = jnp.argmax(sc).astype(jnp.int32)
            idx_ref[g, k] = c
            return sc.at[c].set(-jnp.inf)
        jax.lax.fori_loop(0, kc, select, cscore, unroll=True)

        # -- double-buffered gather + gated FFN --
        def code_dma(slot, k):
            c = idx_ref[g, k]
            row = (g * nc_g + c) * cs
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(row, cs)], buf.at[slot], sem.at[slot])

        def sidecar_dma(slot, k):
            # fp16 outlier sidecar rides its own DMA pair so the
            # int8 code fetch stays a single contiguous burst
            c = idx_ref[g, k]
            row = (g * nc_g + c) * cs
            return pltpu.make_async_copy(
                wout_hbm.at[pl.ds(row, cs)], obuf.at[slot],
                osem.at[slot])

        def dma_start(slot, k):
            code_dma(slot, k).start()
            if mixed:
                sidecar_dma(slot, k).start()

        def dma_wait(slot, k):
            code_dma(slot, k).wait()
            if mixed:
                sidecar_dma(slot, k).wait()

        dma_start(0, 0)                                   # warm-up fetch
        act = activation_fn(activation)

        def compute(k, _):
            slot = jax.lax.rem(k, 2)

            @pl.when(k + 1 < kc)
            def _prefetch():                              # overlap: c+1 DMA
                dma_start(jax.lax.rem(k + 1, 2), k + 1)

            dma_wait(slot, k)
            wk = buf[slot]                                # (cs, R, D)
            if quant:
                # dequantize in VMEM, before the FFN dots: stored int8
                # codes * this cluster's per-row scales (+ outliers)
                c = idx_ref[g, k]
                sc = jax.lax.dynamic_slice(
                    wsc_ref[...], (c * cs, 0), (cs, wk.shape[1]))
                wk = wk.astype(jnp.float32) * sc[:, :, None]
                if mixed:
                    wk = wk + obuf[slot].astype(jnp.float32)
                wk = wk.astype(x_ref.dtype)
            gg = jax.lax.dot_general(
                x, wk[:, 0], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)       # (B, cs)
            hh = act(gg)
            if gated:
                u = jax.lax.dot_general(
                    x, wk[:, 1], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                hh = hh * u
            if cats:
                # CATS token gating: each token keeps only neurons its
                # OWN predicted activation marks positive (§7.2.5) —
                # the batch union steers selection, not computation.
                c = idx_ref[g, k]
                tok = jax.lax.dynamic_slice(
                    scores, (0, c * cs), (scores.shape[0], cs))
                hh = hh * (tok > 0.0).astype(hh.dtype)
            wd = wk[:, -1]
            y_ref[...] += jax.lax.dot_general(
                hh.astype(wd.dtype), wd, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return 0

        jax.lax.fori_loop(0, kc, compute, 0)

    if mixed:
        pl.run_scoped(
            body,
            buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
            sem=pltpu.SemaphoreType.DMA((2,)),
            obuf=pltpu.VMEM((2, cs) + wout_hbm.shape[1:], wout_hbm.dtype),
            osem=pltpu.SemaphoreType.DMA((2,)))
    else:
        pl.run_scoped(
            body,
            buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
            sem=pltpu.SemaphoreType.DMA((2,)))


@functools.partial(jax.jit, static_argnames=(
    "activation", "cluster_size", "groups", "kc", "cats", "interpret"))
def fused_cold_ffn(x, w, A, Bp, mask, *, activation: str, cluster_size: int,
                   groups: int, kc: int, cats: bool = False,
                   interpret: bool | None = None, wsc=None, wout=None):
    """Fused cold path: score -> top-k -> gather -> FFN in one pallas_call.

    x (B, D); w (G*nc_g*cs, R, D) group-major cold bundles (HBM-resident
    — never staged through the block pipeline); A (D, r) / Bp
    (r, G*nc_g*cs) the cold predictor slice; mask (B, 1) float live-row
    mask (1.0 = row steers the batch union).

    Quantized storage: pass the int8 codes as `w` plus `wsc`
    (G*nc_g*cs, R) fp32 per-row scales (staged per group through the
    block pipeline) and, for int4-mixed, `wout` (G*nc_g*cs, R, D) fp16
    outlier sidecar (HBM-resident, DMA'd alongside the codes). The
    cluster DMA then moves int8 and the kernel dequantizes in VMEM
    before the FFN dots.

    Returns (y (B, D) fp32, idx (groups, kc) int32) — bitwise the same
    selection as the jnp path's jax.lax.top_k chain.
    """
    if interpret is None:
        interpret = default_interpret()
    B, D = x.shape
    Ntot, R, _ = w.shape
    assert Ntot % (groups * cluster_size) == 0
    nc_g = Ntot // (groups * cluster_size)
    assert 1 <= kc <= nc_g
    r = A.shape[1]
    quant = wsc is not None
    mixed = wout is not None
    in_specs = [
        pl.BlockSpec((B, D), lambda g: (0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),        # weights stay HBM
        pl.BlockSpec((D, r), lambda g: (0, 0)),
        pl.BlockSpec((r, nc_g * cluster_size),
                     lambda g: (0, g)),              # group's pred cols
        pl.BlockSpec((B, 1), lambda g: (0, 0)),
    ]
    operands = [x, w, A, Bp, mask]
    if quant:
        in_specs.append(pl.BlockSpec((nc_g * cluster_size, R),
                                     lambda g: (g, 0)))  # group's scales
        operands.append(wsc)
        if mixed:
            in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
            operands.append(wout)
    y, idx = pl.pallas_call(
        functools.partial(_fused_kernel, activation=activation,
                          gated=R == 3, cats=cats, kc=kc, nc_g=nc_g,
                          cs=cluster_size, quant=quant, mixed=mixed),
        grid=(groups,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((B, D), lambda g: (0, 0)),
                   pl.BlockSpec(memory_space=pltpu.SMEM)),
        out_shape=(jax.ShapeDtypeStruct((B, D), jnp.float32),
                   jax.ShapeDtypeStruct((groups, kc), jnp.int32)),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(*operands)
    return y, idx
