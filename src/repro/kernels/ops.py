"""Jitted public wrappers for the Pallas kernels.

Every wrapper's `interpret` is `None` = "resolve against
kernels.default_interpret()": interpret mode everywhere but a real TPU
(this container is CPU-only); on a real TPU the kernels compile as
written: MXU-aligned blocks, VMEM-resident accumulators,
scalar-prefetch / manual double-buffered DMA. Pass an explicit bool
only to force one mode (tests, the semantic trace registry).
"""
from __future__ import annotations


import jax.numpy as jnp

from repro.kernels.cluster_gather_ffn import cluster_gather_ffn, \
    fused_cold_ffn as _fused_cold_ffn_call
from repro.kernels.dense_ffn import dense_ffn


def cluster_gather_ffn_grouped(x, wc, cidx, *, activation: str,
                               interpret: bool | None = None):
    """Grouped (sharded-neuron-dim) form used by core.sparse_ffn.

    x (B, D); wc (G, nc_g, cs, R, D) cold clusters per group;
    cidx (G, kc) active cluster ids per group. Returns (B, D) fp32-acc
    sum over all groups' gathered clusters.

    Each group's clusters get a *global* cluster id (g * nc_g + local)
    so one pallas_call covers all groups — on a sharded mesh each
    shard calls this with only its local group (G=1) inside shard_map.
    """
    G, nc_g, cs, R, D = wc.shape
    w_flat = wc.reshape(G * nc_g * cs, R, D)
    gidx = (cidx + jnp.arange(G, dtype=cidx.dtype)[:, None] * nc_g).reshape(-1)
    return cluster_gather_ffn(x, w_flat, gidx, activation=activation,
                              cluster_size=cs, interpret=interpret)


def fused_cold_ffn(x, wc, A, Bp, *, activation: str, mode: str = "relu",
                   kc: int, active_mask=None,
                   interpret: bool | None = None,
                   wq=None, wsc=None, wout=None):
    """Fused cold path (kernels/cluster_gather_ffn.fused_cold_ffn):
    predictor score -> batch-union top-k -> double-buffered cluster
    gather -> gated FFN, one pallas_call.

    x (B, D); wc (G, nc_g, cs, R, D) cold clusters per group; A (D, r)
    and Bp (r, G*nc_g*cs) the predictor's cold slice; kc clusters kept
    per group. `mode == "cats"` applies the per-token score gating the
    jnp backend applies (§7.2.5); `active_mask` (B,) bool keeps dead
    KV-arena lanes out of the batch union.

    When the plan stores quantized bundles (§7.6) pass wq (int8 codes,
    same shape as wc), wsc ((G, nc_g, cs, R) fp32 scales) and, for
    int4-mixed, wout (fp16 outlier sidecar): the kernel then DMAs the
    int8 codes instead of the fp weights and dequantizes in VMEM before
    the FFN dots. Returns (y (B, D) fp32, cidx (G, kc) int32) — the
    same selection the jnp top_k chain makes, so the two backends
    decode token-identically.
    """
    G, nc_g, cs, R, D = wc.shape
    B = x.shape[0]
    if active_mask is None:
        mask = jnp.ones((B, 1), jnp.float32)
    else:
        mask = active_mask.astype(jnp.float32).reshape(B, 1)
    w_hbm = (wq if wq is not None else wc).reshape(G * nc_g * cs, R, D)
    return _fused_cold_ffn_call(
        x, w_hbm, A, Bp, mask,
        activation=activation, cluster_size=cs, groups=G, kc=kc,
        cats=mode == "cats", interpret=interpret,
        wsc=None if wq is None else wsc.reshape(G * nc_g * cs, R),
        wout=None if wout is None else wout.reshape(G * nc_g * cs, R, D))


__all__ = ["cluster_gather_ffn", "cluster_gather_ffn_grouped",
           "fused_cold_ffn", "dense_ffn"]
