"""Jitted public wrappers for the Pallas kernels.

`interpret` defaults to True because this container is CPU-only; on a
real TPU pass interpret=False (the kernels are written for TPU:
MXU-aligned blocks, VMEM-resident accumulators, scalar-prefetch DMA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cluster_gather_ffn import cluster_gather_ffn
from repro.kernels.dense_ffn import dense_ffn


def cluster_gather_ffn_grouped(x, wc, cidx, *, activation: str,
                               interpret: bool = True):
    """Grouped (sharded-neuron-dim) form used by core.sparse_ffn.

    x (B, D); wc (G, nc_g, cs, R, D) cold clusters per group;
    cidx (G, kc) active cluster ids per group. Returns (B, D) fp32-acc
    sum over all groups' gathered clusters.

    Each group's clusters get a *global* cluster id (g * nc_g + local)
    so one pallas_call covers all groups — on a sharded mesh each
    shard calls this with only its local group (G=1) inside shard_map.
    """
    G, nc_g, cs, R, D = wc.shape
    w_flat = wc.reshape(G * nc_g * cs, R, D)
    gidx = (cidx + jnp.arange(G, dtype=cidx.dtype)[:, None] * nc_g).reshape(-1)
    return cluster_gather_ffn(x, w_flat, gidx, activation=activation,
                              cluster_size=cs, interpret=interpret)


__all__ = ["cluster_gather_ffn", "cluster_gather_ffn_grouped", "dense_ffn"]
