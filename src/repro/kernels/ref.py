"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.modules import activation_fn


def _apply(x, wsel, activation):
    """x (B, D), wsel (K, R, D) -> (B, D), fp32 accumulation."""
    act = activation_fn(activation)
    g = jnp.einsum("bd,kd->bk", x, wsel[:, 0],
                   preferred_element_type=jnp.float32)
    if wsel.shape[1] == 3:
        u = jnp.einsum("bd,kd->bk", x, wsel[:, 1],
                       preferred_element_type=jnp.float32)
        h = act(g) * u
    else:
        h = act(g)
    return jnp.einsum("bk,kd->bd", h.astype(wsel.dtype), wsel[:, -1],
                      preferred_element_type=jnp.float32)


def cluster_gather_ffn_ref(x, w, cluster_idx, *, activation: str,
                           cluster_size: int):
    """Gathered sparse FFN oracle.

    x: (B, D); w: (N, R, D) bundled neuron weights; cluster_idx: (K,)
    int32 cluster ids (each cluster = cluster_size consecutive neurons).
    """
    N = w.shape[0]
    wc = w.reshape(N // cluster_size, cluster_size, *w.shape[1:])
    wsel = wc[cluster_idx].reshape(-1, *w.shape[1:])    # (K*cs, R, D)
    return _apply(x, wsel, activation).astype(x.dtype)


def dense_ffn_ref(x, w, *, activation: str):
    """Dense bundled FFN oracle. x (B, D), w (N, R, D)."""
    return _apply(x, w, activation).astype(x.dtype)
