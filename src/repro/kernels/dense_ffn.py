"""Pallas TPU kernel: dense bundled FFN (the paper's hot/NPU path).

Tiled over the neuron dim: each grid step streams one MXU-aligned
(block_n, R, D) weight tile HBM->VMEM (double-buffered by the Pallas
grid pipeline) and accumulates into the (B, D) output in fp32 — the
dense engine that consumes the planner's hot prefix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.cluster_gather_ffn import CompilerParams, _kernel


@functools.partial(jax.jit, static_argnames=("activation", "block_n",
                                             "interpret"))
def dense_ffn(x, w, *, activation: str, block_n: int = 512,
              interpret: bool | None = None):
    """x (B, D); w (N, R, D). Returns (B, D) full dense bundled FFN."""
    if interpret is None:
        interpret = default_interpret()
    B, D = x.shape
    N, R, _ = w.shape
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)
    gated = R == 3

    def kernel(x_ref, w_ref, o_ref):
        # reuse the gather kernel body with an implicit identity index
        _kernel(None, x_ref, w_ref, o_ref, activation=activation,
                gated=gated)

    out = pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((B, D), lambda i: (0, 0)),
            pl.BlockSpec((block_n, R, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x, w)
    return out.astype(x.dtype)
