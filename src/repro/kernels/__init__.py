# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def default_interpret() -> bool:
    """The one TPU-detection default every kernel wrapper resolves
    `interpret=None` against: interpret mode everywhere but a real TPU
    (this container is CPU-only). Lazy jax import keeps the package
    importable before jax configuration is final."""
    import jax
    return jax.default_backend() != "tpu"
