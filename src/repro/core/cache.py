"""Segmented in-memory neuron cache (paper §4.2).

Three regions with different granularity and policy:
  * fixed  — attention weights + KV cache; preloaded, never evicted.
  * hot    — dense matrices for the NPU/MXU path; LRU at *cluster*
             granularity (a cluster = `cluster_size` bundled neurons).
  * cold   — individually managed neurons for the sparse path; LRU at
             *neuron* granularity (co-activation after removing hot
             neurons is <20%, so bundling whole groups wastes I/O).

Evictions are discards (weights are read-only; no write-back).
`rebalance(batch_size)` grows the hot region for larger batches and
shrinks it back when sequences complete (paper Fig 2 dynamics).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_loaded: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0

    def reset(self):
        self.hits = self.misses = self.evictions = self.bytes_loaded = 0


class LRUSet:
    """LRU over integer keys with capacity in item count."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()

    def __contains__(self, k):
        return k in self._d

    def __len__(self):
        return len(self._d)

    def touch(self, k) -> bool:
        """Mark k used. Returns True if it was present (hit)."""
        if k in self._d:
            self._d.move_to_end(k)
            return True
        return False

    def admit(self, k) -> list:
        """Insert k; returns list of evicted keys."""
        evicted = []
        if k in self._d:
            self._d.move_to_end(k)
            return evicted
        while len(self._d) >= max(self.capacity, 1):
            old, _ = self._d.popitem(last=False)
            evicted.append(old)
        self._d[k] = True
        return evicted

    def resize(self, capacity: int) -> list:
        self.capacity = capacity
        evicted = []
        while len(self._d) > max(capacity, 0):
            old, _ = self._d.popitem(last=False)
            evicted.append(old)
        return evicted

    def keys(self):
        return list(self._d.keys())


class NeuronCache:
    """Per-layer segmented neuron cache.

    Keys: (layer, neuron_id) for cold entries; (layer, cluster_id) for
    hot entries. Capacities are in *neurons* (bytes_per_neuron converts).
    """

    def __init__(self, n_layers: int, neurons_per_layer: int,
                 cluster_size: int, capacity_neurons: int,
                 hot_fraction: float = 0.5, bytes_per_neuron: int = 0):
        self.n_layers = n_layers
        self.N = neurons_per_layer
        self.cluster_size = cluster_size
        self.capacity = capacity_neurons
        self.bytes_per_neuron = bytes_per_neuron
        n_hot = int(capacity_neurons * hot_fraction)
        self.hot = LRUSet(max(n_hot // cluster_size, 1))
        self.cold = LRUSet(max(capacity_neurons - n_hot, 1))
        self.stats = CacheStats()

    # -- hot region: cluster granularity ------------------------------
    def lookup_hot_cluster(self, layer: int, cluster_id: int) -> bool:
        hit = self.hot.touch((layer, cluster_id))
        self.stats.hits += self.cluster_size if hit else 0
        self.stats.misses += 0 if hit else self.cluster_size
        return hit

    def admit_hot_cluster(self, layer: int, cluster_id: int):
        ev = self.hot.admit((layer, cluster_id))
        self.stats.evictions += len(ev) * self.cluster_size
        self.stats.bytes_loaded += self.cluster_size * self.bytes_per_neuron

    # -- cold region: neuron granularity ------------------------------
    def lookup_cold(self, layer: int, neuron_ids) -> tuple:
        """Returns (hit_ids, miss_ids)."""
        hits, misses = [], []
        for nid in neuron_ids:
            (hits if self.cold.touch((layer, int(nid))) else misses).append(int(nid))
        self.stats.hits += len(hits)
        self.stats.misses += len(misses)
        return hits, misses

    def admit_cold(self, layer: int, neuron_ids):
        for nid in neuron_ids:
            ev = self.cold.admit((layer, int(nid)))
            self.stats.evictions += len(ev)
        self.stats.bytes_loaded += len(neuron_ids) * self.bytes_per_neuron

    # -- dynamic rebalancing (paper §4.2 last para) --------------------
    def rebalance(self, batch_size: int):
        """Grow hot region with batch size (more dense NPU work), shrink
        cold; and vice versa. Ratio ramps 0.5 -> 0.8 from batch 1 to 32."""
        import math
        t = min(math.log2(max(batch_size, 1)) / 5.0, 1.0)
        hot_frac = 0.5 + 0.3 * t
        n_hot = int(self.capacity * hot_frac)
        ev_h = self.hot.resize(max(n_hot // self.cluster_size, 1))
        ev_c = self.cold.resize(max(self.capacity - n_hot, 1))
        self.stats.evictions += len(ev_h) * self.cluster_size + len(ev_c)

    @property
    def resident_neurons(self) -> int:
        return len(self.hot) * self.cluster_size + len(self.cold)
