"""Neuron-cluster math (PowerInfer-2 §3.1).

A *neuron* is one FFN row-bundle (gate/up rows + down column — the
paper's §4.4 Gate-Up-Down bundle). A *neuron cluster* is `cluster_size`
consecutive neurons after the planner's frequency permutation; cluster
size is MXU-aligned (multiples of 128 on TPU; reduced in smoke tests).

The hot/cold split is a static prefix split over the permuted neuron
dim: [0, n_hot) = hot clusters (dense engine), [n_hot, N) = cold
clusters (predictor-gated gathered engine).
"""
from __future__ import annotations

from dataclasses import dataclass


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def round_down(n: int, m: int) -> int:
    return (n // m) * m


@dataclass(frozen=True)
class HybridPlan:
    """Static decode-path plan for one (batch-size bucket, layer kind).

    The paper swaps pre-built NPU graphs per batch bucket; we swap
    pre-jitted executables keyed by this plan (core/adaptation.py).
    Cold selection/gather is *cluster*-granular: `k_cold` neurons =
    (k_cold // cluster_size) clusters per group.
    """
    n_hot: int             # dense hot prefix (neurons)
    k_cold: int            # gathered cold budget per group (neurons)
    groups: int = 1        # neuron-dim shards (mesh model-axis size)
    backend: str = "jnp"   # 'jnp' | 'pallas'
    cluster_size: int = 128
    # Two-level MoE sparsity (intra-expert hot/cold, DESIGN.md §9):
    # per-expert hot prefix rows (0 = whole-expert MoE or dense plan)
    # and the pinned resident prefix when it differs from the per-step
    # hot compute — every routed expert's hot prefix stays resident
    # while only the activated experts compute theirs, so
    # n_hot = shared + n_act*n_expert_hot prices compute and
    # n_pinned = shared + E*n_expert_hot sizes residency.
    n_expert_hot: int = 0
    n_pinned: int = 0
    # On-storage dtype of the *cold* bundles (§7.6 hybrid quantization):
    # 'fp16' | 'int8' | 'int4-mixed'. The hot/pinned prefix always stays
    # fp (the paper keeps dense-activation weights high-precision on the
    # NPU); the storage plane prices cold I/O and residency at this
    # dtype and prepare_params quantizes the cold rows to match.
    storage_dtype: str = "fp16"

    @property
    def total_cold(self) -> int:
        return self.k_cold * self.groups

    @property
    def clusters_per_group(self) -> int:
        return self.k_cold // self.cluster_size

    @property
    def resident_hot(self) -> int:
        """Pinned resident hot prefix (neurons): n_pinned for two-level
        MoE plans, otherwise the computed hot prefix itself."""
        return self.n_pinned or self.n_hot


def make_plan(n_neurons: int, hot_ratio: float, cold_active_ratio: float,
              cluster_size: int, groups: int = 1,
              backend: str = "jnp",
              storage_dtype: str = "fp16") -> HybridPlan:
    """Build a hybrid plan with cluster- and group-aligned sizes.

    The cold suffix (n_neurons - n_hot) must be a multiple of
    groups*cluster_size so each mesh shard owns whole clusters; any
    remainder is absorbed into the hot prefix (dense is always safe).
    """
    align = cluster_size * groups
    n_cold = round_down(int(n_neurons * (1.0 - hot_ratio)), align)
    n_hot = n_neurons - n_cold
    k_total = round_down(int(n_cold * cold_active_ratio), align)
    k_total = max(k_total, align) if n_cold >= align else 0
    return HybridPlan(n_hot=n_hot, k_cold=k_total // groups,
                      groups=groups, backend=backend,
                      cluster_size=cluster_size,
                      storage_dtype=storage_dtype)


def scale_plan_for_batch(base: HybridPlan, n_neurons: int, batch: int,
                         cluster_size: int) -> HybridPlan:
    """Sparsity-aware adaptation (§4.1.3): larger effective batch ->
    denser activation union -> larger hot share, smaller cold budget.

    Mirrors the paper's measurement (Fig 2): hot share grows from the
    base ratio at batch 1 toward ~70% at batch >= 32; beyond that the
    union saturates and everything moves to the dense engine.
    """
    import math
    base_ratio = base.n_hot / max(n_neurons, 1)
    # log-linear ramp from base_ratio (b=1) to 0.7 (b=32), capped.
    t = min(math.log2(max(batch, 1)) / 5.0, 1.0)
    hot_ratio = base_ratio + (0.7 - base_ratio) * t
    cold_ratio = (base.total_cold / max(n_neurons - base.n_hot, 1)) * (1.0 + t)
    return make_plan(n_neurons, hot_ratio, min(cold_ratio, 1.0),
                     cluster_size, base.groups, base.backend,
                     storage_dtype=base.storage_dtype)
