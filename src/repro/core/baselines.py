"""Baseline system models the paper compares against (§7.1).

The paper evaluates against llama.cpp (dense compute, mmap offloading)
and LLMFlash (sparsity prediction + row-column bundling + neuron cache,
matrix-level overlap). Both are implemented here as engine
configurations over the same substrate, so benchmark deltas isolate the
paper's contributions exactly (bundle / cache / pipeline / hybrid —
the Fig 14 ablation ladder).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SystemSpec:
    name: str
    use_predictor: bool       # sparsity-aware (skip inactive neurons)
    use_bundling: bool        # Gate-Up-Down position-major fetches
    use_cache: bool           # neuron cache (vs re-read per token)
    pipeline: str             # 'none' (sync I/O) | 'matrix' | 'cluster'
    hybrid_engines: bool      # dense-hot + sparse-cold co-execution
    two_phase: bool = False   # gate-first cold loading
    # Without the segmented hot/cold split (§4.2), LLMFlash-style
    # co-activation bundles re-load hot neurons redundantly across
    # bundles — effective cache capacity shrinks.
    cache_efficiency: float = 1.0
    # Systems without a pinned hot region stream *all* activated
    # neurons (hot included) through the cache.
    pinned_hot: bool = False


LLAMACPP = SystemSpec(
    name="llama.cpp-mmap",
    use_predictor=False, use_bundling=False, use_cache=True,
    pipeline="none", hybrid_engines=False)

LLMFLASH = SystemSpec(
    name="llmflash",
    use_predictor=True, use_bundling=True, use_cache=True,
    pipeline="matrix", hybrid_engines=False, cache_efficiency=0.4)

POWERINFER2 = SystemSpec(
    name="powerinfer-2",
    use_predictor=True, use_bundling=True, use_cache=True,
    pipeline="cluster", hybrid_engines=True, two_phase=True,
    pinned_hot=True)

# Fig 14 ablation ladder (each adds one mechanism)
ABLATION_LADDER = (
    SystemSpec("baseline", True, False, False, "none", False),
    SystemSpec("+bundle", True, True, False, "none", False),
    SystemSpec("+cache", True, True, True, "none", False,
               pinned_hot=True),
    SystemSpec("+pipeline", True, True, True, "cluster", False,
               pinned_hot=True),
    SystemSpec("+xpu", True, True, True, "cluster", True, two_phase=True,
               pinned_hot=True),
)

ALL_SYSTEMS = (LLAMACPP, LLMFLASH, POWERINFER2)
