"""Online activation predictor (PowerInfer-2 §3.2 / PowerInfer §4).

A low-rank two-matrix MLP per FFN layer scores each neuron's activation
probability for the current hidden state:

    score(x) = x @ A @ B          A: (d_model, r)   B: (r, n_neurons)

The predictor is the gate of the *cold* path: only top-k-scored cold
neurons are gathered and computed. The offline planner (core/planner.py)
trains/It calibrates it against observed activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.modules import dense_init
from repro.sharding import constrain


def init_predictor(key, d_model: int, n_neurons: int, rank: int, dtype):
    ka, kb = jax.random.split(key)
    return {
        "A": dense_init(ka, (d_model, rank), dtype),
        "B": dense_init(kb, (rank, n_neurons), dtype),
    }


def predictor_spec():
    # B's neuron dim is sharded over 'model', matching the FFN weights,
    # so each shard scores exactly the neurons it owns.
    return {"A": P(None, None), "B": P(None, "model")}


def predict_scores(params, x):
    """x (..., d_model) -> neuron scores (..., n_neurons), fp32."""
    h = jnp.einsum("...d,dr->...r", x.astype(jnp.float32),
                   params["A"].astype(jnp.float32))
    s = jnp.einsum("...r,rn->...n", h, params["B"].astype(jnp.float32))
    return constrain(s, P(None, "model")) if s.ndim == 2 else s


def predict_proba(params, x):
    return jax.nn.sigmoid(predict_scores(params, x))
