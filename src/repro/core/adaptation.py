"""Dynamic CPU/NPU-ratio adaptation (paper §4.1.3).

The NPU executes static graphs: PowerInfer-2 pre-builds one graph per
(batch size, hot ratio) and swaps them asynchronously while attention
runs. The XLA analogue is exact: we pre-jit one decode executable per
batch bucket (static shapes) and swap executables as the live batch
size changes. `BucketedDecoder` tracks sequence creation/completion and
serves the right executable with zero-recompile switches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict

import jax

from repro.core.clusters import HybridPlan
from repro.core.planner import ExecutionPlan


def bucket_for(batch: int, buckets=(1, 2, 4, 8, 16, 32)) -> int:
    for b in buckets:
        if batch <= b:
            return b
    return buckets[-1]


@dataclass
class BucketedDecoder:
    """Pre-jitted decode executables per batch bucket.

    make_step(plan) must return a decode callable
    (params, tokens, cache) -> (logits, cache) specialized to the plan;
    it is jitted once per bucket and cached (the paper's pre-generated
    NPU graph table, §5 Batch-Adaptive Planning).
    """
    plan_source: ExecutionPlan
    make_step: Callable[[HybridPlan], Callable]
    buckets: tuple = (1, 2, 4, 8, 16, 32)
    _cache: Dict[int, tuple] = field(default_factory=dict)
    switches: int = 0
    _last_bucket: int = -1

    def prewarm(self):
        for b in self.buckets:
            self.executable_for(b)

    def executable_for(self, batch: int):
        b = bucket_for(batch, self.buckets)
        if b not in self._cache:
            plan = self.plan_source.plan_for_batch(b)
            self._cache[b] = (plan, jax.jit(self.make_step(plan)))
        if b != self._last_bucket:
            self.switches += 1
            self._last_bucket = b
        return self._cache[b]

    def live_plans(self):
        return {b: p for b, (p, _) in self._cache.items()}


@dataclass
class BatchTracker:
    """Tracks live decoding sequences (Best-of-N / continuous batching):
    the *effective* batch size falls as sequences hit EOS (paper Fig 13)."""
    active: int = 0
    history: list = field(default_factory=list)

    def start(self, n: int = 1):
        self.active += n
        self.history.append(self.active)

    def finish(self, n: int = 1):
        self.active = max(0, self.active - n)
        self.history.append(self.active)
