"""Dynamic CPU/NPU-ratio adaptation (paper §4.1.3).

The NPU executes static graphs: PowerInfer-2 pre-builds one graph per
(batch size, hot ratio) and swaps them asynchronously while attention
runs. The XLA analogue is exact: we pre-jit one decode executable per
batch bucket (static shapes) and swap executables as the live batch
size changes. `BucketedDecoder` tracks sequence creation/completion and
serves the right executable with zero-recompile switches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict

import jax

from repro.core.clusters import HybridPlan
from repro.core.planner import ExecutionPlan


# the serving bucket ladder: one pre-jitted executable per bucket.
# Shared by bucket_for, BucketedDecoder and the semantic analysis
# trace registry's representative-bucket coverage.
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


def bucket_for(batch: int, buckets=DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if batch <= b:
            return b
    return buckets[-1]


def mesh_key(mesh):
    """Hashable executable-table key for a device mesh (None = no mesh)."""
    if mesh is None:
        return None
    return tuple(zip(mesh.axis_names, tuple(dict(mesh.shape).values())))


@dataclass
class BucketedDecoder:
    """Pre-jitted decode executables per (batch bucket × mesh shape).

    make_step(plan) must return a decode callable
    (params, tokens, cache) -> (logits, cache) specialized to the plan;
    it is jitted once per key and cached (the paper's pre-generated
    NPU graph table, §5 Batch-Adaptive Planning). With a `mesh`, the
    executable is traced and run inside that mesh context, so the
    sparse-FFN shard_map path and all sharding constraints bind to it —
    tensor-parallel and single-device executables coexist in the table.

    `backend` ('jnp' | 'pallas' | None) overrides each bucket plan's
    cold-path backend before tracing: every executable in the table
    runs the chosen kernel path (DESIGN.md §10), regardless of how the
    offline planner built the per-bucket plans.
    """
    plan_source: ExecutionPlan
    make_step: Callable[[HybridPlan], Callable]
    buckets: tuple = DEFAULT_BUCKETS
    mesh: object = None
    backend: str = None
    _cache: Dict[tuple, tuple] = field(default_factory=dict)
    switches: int = 0
    _last_key: tuple = ()

    def prewarm(self):
        for b in self.buckets:
            self.executable_for(b)

    def executable_for(self, batch: int):
        b = bucket_for(batch, self.buckets)
        key = (b, mesh_key(self.mesh))
        if key not in self._cache:
            plan = self.plan_source.plan_for_batch(b)
            if self.backend and plan.backend != self.backend:
                import dataclasses
                plan = dataclasses.replace(plan, backend=self.backend)
            fn = jax.jit(self.make_step(plan))
            if self.mesh is not None:
                fn = self._bind_mesh(fn, self.mesh)
            self._cache[key] = (plan, fn)
        if key != self._last_key:
            self.switches += 1
            self._last_key = key
        return self._cache[key]

    @staticmethod
    def _bind_mesh(fn, mesh):
        from repro.compat import set_mesh

        def call(*args, **kwargs):
            with set_mesh(mesh):
                return fn(*args, **kwargs)
        return call

    def live_plans(self):
        return {b: p for (b, _), (p, _) in self._cache.items()}


@dataclass
class BatchTracker:
    """Tracks live decoding sequences (Best-of-N / continuous batching):
    the *effective* batch size falls as sequences hit EOS (paper Fig 13)."""
    active: int = 0
    history: list = field(default_factory=list)

    def start(self, n: int = 1):
        self.active += n
        self.history.append(self.active)

    def finish(self, n: int = 1):
        self.active = max(0, self.active - n)
        self.history.append(self.active)
