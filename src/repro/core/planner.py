"""Offline execution planner (paper §5).

Pipeline: profile -> classify -> plan.

1. `profile_activations` runs the model over a profiling corpus and
   tracks per-neuron activation frequencies (the paper uses 10M+ tokens
   of Wikipedia/RefinedWeb; our corpus is the synthetic data pipeline).
2. `classify_neurons` sorts neurons by frequency into a hot-first
   permutation and sizes the hot prefix per batch-size bucket:
   the batch-b activation probability of a neuron with per-token
   frequency f is 1-(1-f)^b (the Fig 2 union effect), and the hot set
   is additionally capped by I/O-aware sizing — hot neurons are
   prefetched during the previous attention block, so
   n_hot <= seq_bw * t_attn / bytes_per_neuron (§5 "carefully balances").
3. `build_plan` emits an ExecutionPlan: the permutation, per-bucket
   HybridPlans, and the hardware profile used.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.clusters import HybridPlan, make_plan, round_down
from repro.models.modules import rms_norm, activation_fn


@dataclass(frozen=True)
class HardwareProfile:
    """Target-device characteristics consumed by the planner."""
    name: str = "tpu-v5e-host"
    seq_bw: float = 4e9            # bytes/s sequential (slow-tier read)
    rand_bw: float = 1e9           # bytes/s random
    attn_time_s: float = 2e-3      # per-layer attention time (prefetch window)
    dense_engine_flops: float = 197e12   # MXU ("NPU analogue")
    sparse_engine_flops: float = 20e12   # gathered path effective


# The paper's device (OnePlus 12, Snapdragon 8 Gen 3 + UFS 4.0).
# NPU ~11 TFLOP/s effective (§2.3.1: 770 tok/s prefill on a 7B ~ 2*7G*770);
# 6 CPU cores ~60 GFLOP/s fp16 NEON (12 tok/s in-memory decode on ~3B
# active params). Used by benchmarks that reproduce the paper's figures.
PHONE = HardwareProfile(
    name="snapdragon-8gen3",
    seq_bw=4e9, rand_bw=1e9, attn_time_s=2e-3,
    dense_engine_flops=11e12, sparse_engine_flops=60e9)


@dataclass
class ExecutionPlan:
    arch: str
    n_neurons: int
    cluster_size: int
    # hot-first neuron permutation per layer, (L, N) int32
    neuron_order: np.ndarray
    # per-token activation frequency per layer, (L, N) float32 (permuted)
    frequencies: np.ndarray
    # batch-bucket -> HybridPlan
    plans: dict
    hardware: HardwareProfile

    def plan_for_batch(self, batch: int) -> HybridPlan:
        buckets = sorted(self.plans)
        for b in buckets:
            if batch <= b:
                return self.plans[b]
        return self.plans[buckets[-1]]

    def save(self, path):
        obj = {
            "arch": self.arch, "n_neurons": self.n_neurons,
            "cluster_size": self.cluster_size,
            "neuron_order": self.neuron_order.tolist(),
            "frequencies": self.frequencies.tolist(),
            "plans": {str(b): asdict(p) for b, p in self.plans.items()},
            "hardware": asdict(self.hardware),
        }
        with open(path, "w") as f:
            json.dump(obj, f)

    @staticmethod
    def load(path) -> "ExecutionPlan":
        with open(path) as f:
            obj = json.load(f)
        return ExecutionPlan(
            arch=obj["arch"], n_neurons=obj["n_neurons"],
            cluster_size=obj["cluster_size"],
            neuron_order=np.asarray(obj["neuron_order"], np.int32),
            frequencies=np.asarray(obj["frequencies"], np.float32),
            plans={int(b): HybridPlan(**p) for b, p in obj["plans"].items()},
            hardware=HardwareProfile(**obj["hardware"]),
        )


# ------------------------------------------------------------ profiling ----

def _act_threshold(mode: str) -> float:
    # relu-family: exact zeros; cats: |h| below tau contributes ~nothing
    return 0.0 if mode == "relu" else 0.1


def ffn_activation_counts(ffn_params, x, activation: str, mode: str):
    """x (B,S,D) -> per-neuron activation counts (N,) over B*S tokens."""
    w = ffn_params["w"]
    act = activation_fn(activation)
    g = jnp.einsum("bsd,nd->bsn", x, w[:, 0])
    h = act(g)
    if w.shape[1] == 3:
        u = jnp.einsum("bsd,nd->bsn", x, w[:, 1])
        h = h * u
    tau = _act_threshold(mode)
    active = jnp.abs(h) > tau
    return active.sum(axis=(0, 1)).astype(jnp.int32)


def profile_activations(params, cfg: ModelConfig, token_batches):
    """Dense-family profiling forward: returns (counts (L,N), n_tokens).

    Re-implements the dense layer walk with an activation tap; works for
    any model whose layers are {ln1, attn, ln2, ffn} stacks (dense, vlm
    backbone). Other families use family-specific adapters or the
    synthetic profile (see `synthetic_frequencies`).
    """
    from repro.models import blocks as B
    from repro.models import dense as D
    from repro.models.attention import rope_angles

    @jax.jit
    def run(params, tokens):
        x = D.embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), cfg.d_head // 2, cfg.rope_theta)

        def body(h, lp):
            a, _ = B.attn_full(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, angles, causal=True,
                               window=cfg.sliding_window)
            h = h + a
            xin = rms_norm(h, lp["ln2"], cfg.norm_eps)
            cnt = ffn_activation_counts(lp["ffn"], xin, cfg.activation,
                                        cfg.sparse_ffn.mode)
            from repro.core.sparse_ffn import ffn_dense
            h = h + ffn_dense(lp["ffn"], xin, cfg.activation)
            return h, cnt

        _, counts = jax.lax.scan(body, x, params["layers"])
        return counts                                   # (L, N)

    total = np.zeros((cfg.num_layers, cfg.d_ff), np.int64)
    n_tokens = 0
    for tokens in token_batches:
        total += np.asarray(run(params, tokens))
        n_tokens += tokens.shape[0] * tokens.shape[1]
    return total, n_tokens


def profile_ffn_inputs(params, cfg: ModelConfig, token_batches):
    """Collect per-layer FFN inputs and activation indicators.

    Returns (X (L, T, D), H (L, T, N) bool) over all profiling tokens —
    the training set for predictor calibration (PowerInfer trains its
    online predictors offline; §3.2)."""
    from repro.models import blocks as B
    from repro.models import dense as D
    from repro.models.attention import rope_angles
    from repro.core.sparse_ffn import ffn_dense
    from repro.models.modules import activation_fn

    tau = _act_threshold(cfg.sparse_ffn.mode)

    @jax.jit
    def run(params, tokens):
        x = D.embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), cfg.d_head // 2, cfg.rope_theta)

        def body(h, lp):
            a, _ = B.attn_full(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, angles, causal=True,
                               window=cfg.sliding_window)
            h = h + a
            xin = rms_norm(h, lp["ln2"], cfg.norm_eps)
            w = lp["ffn"]["w"]
            act = activation_fn(cfg.activation)
            g = jnp.einsum("bsd,nd->bsn", xin, w[:, 0])
            hh = act(g)
            if w.shape[1] == 3:
                hh = hh * jnp.einsum("bsd,nd->bsn", xin, w[:, 1])
            active = jnp.abs(hh) > tau
            h = h + ffn_dense(lp["ffn"], xin, cfg.activation)
            return h, (xin, active)

        _, (xs, acts) = jax.lax.scan(body, x, params["layers"])
        return xs, acts                            # (L,B,S,D), (L,B,S,N)

    Xs, Hs = [], []
    for tokens in token_batches:
        xs, acts = run(params, tokens)
        L = xs.shape[0]
        Xs.append(np.asarray(xs).reshape(L, -1, cfg.d_model))
        Hs.append(np.asarray(acts).reshape(L, -1, cfg.d_ff))
    return np.concatenate(Xs, 1), np.concatenate(Hs, 1)


def calibrate_predictor(params, cfg: ModelConfig, token_batches,
                        ridge: float = 1e-2):
    """Fit each layer's low-rank activation predictor by ridge
    regression on real (FFN input, activation indicator) pairs, then
    truncate to rank r via SVD. Returns params with trained predictors.
    """
    rank = cfg.sparse_ffn.predictor_rank
    X, H = profile_ffn_inputs(params, cfg, token_batches)
    L, T, Dm = X.shape
    A_l, B_l = [], []
    for l in range(L):
        Xl = X[l].astype(np.float64)
        Yl = (H[l].astype(np.float64) * 2.0 - 1.0)     # ±1 targets
        G = Xl.T @ Xl + ridge * T * np.eye(Dm)
        W = np.linalg.solve(G, Xl.T @ Yl)              # (D, N)
        U, S, Vt = np.linalg.svd(W, full_matrices=False)
        r = min(rank, len(S))
        A_l.append((U[:, :r] * np.sqrt(S[:r])))
        B_l.append((np.sqrt(S[:r])[:, None] * Vt[:r]))
    ffn = params["layers"]["ffn"]
    dtype = ffn["pred"]["A"].dtype
    pad_r = ffn["pred"]["A"].shape[-1]

    def pad(mats, axis):
        out = []
        for m in mats:
            if m.shape[axis] < pad_r:
                w = [(0, 0), (0, 0)]
                w[axis] = (0, pad_r - m.shape[axis])
                m = np.pad(m, w)
            out.append(m)
        return np.stack(out)

    new_pred = {"A": jnp.asarray(pad(A_l, 1), dtype),
                "B": jnp.asarray(pad(B_l, 0), dtype)}
    new_ffn = dict(ffn, pred=new_pred)
    return dict(params, layers=dict(params["layers"], ffn=new_ffn))


def predictor_quality(params, cfg: ModelConfig, token_batches) -> float:
    """Recall of the predictor's top-k vs true active neurons (layer 0)."""
    from repro.core.predictor import predict_scores
    X, H = profile_ffn_inputs(params, cfg, token_batches)
    pred = jax.tree.map(lambda a: a[0], params["layers"]["ffn"]["pred"])
    scores = np.asarray(predict_scores(pred, jnp.asarray(X[0])))
    recalls = []
    for t in range(min(64, X.shape[1])):
        k = max(int(H[0, t].sum()), 1)
        top = np.argsort(-scores[t])[:k]
        recalls.append(H[0, t][top].mean())
    return float(np.mean(recalls))


def synthetic_frequencies(cfg: ModelConfig, seed: int = 0,
                          zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-shaped activation frequencies for families without a
    profiling adapter (the paper's Fig 2 skew: <1% of neurons are hot
    at batch 1, hot spots dominate)."""
    rng = np.random.default_rng(seed)
    L, N = cfg.num_layers, max(cfg.d_ff, 1)
    rank = np.arange(1, N + 1, dtype=np.float64)
    base = 1.0 / rank ** zipf_a
    base = base / base.max() * 0.95
    freqs = np.stack([rng.permutation(base) for _ in range(L)])
    return freqs.astype(np.float32)


# --------------------------------------------------------- classification ----

def classify_neurons(freqs: np.ndarray, cfg: ModelConfig,
                     hw: HardwareProfile,
                     batch_buckets=(1, 2, 4, 8, 16, 32),
                     groups: int = 1, backend: str = "jnp",
                     storage_dtype: str = "fp16"):
    """freqs (L, N) per-token activation frequency -> (order, plans).

    Hot threshold: union activation probability at the bucket's batch
    size exceeds 0.5. I/O cap: the hot prefix must be prefetchable
    within one attention block at sequential bandwidth — priced at the
    declared storage dtype, so int4 bundles shift the hot/cold boundary
    outward (more neurons fit the same prefetch window, §7.6).
    """
    L, N = freqs.shape
    order = np.argsort(-freqs, axis=1).astype(np.int32)     # hot-first
    sorted_f = np.take_along_axis(freqs, order, axis=1)
    mean_f = sorted_f.mean(axis=0)                          # (N,) layer-avg

    sc = cfg.sparse_ffn
    io_cap = hot_io_cap(cfg, hw, storage_dtype)

    plans = {}
    for b in batch_buckets:
        union = 1.0 - (1.0 - mean_f) ** b
        n_hot = int((union > 0.5).sum())
        n_hot = min(n_hot, io_cap, N)
        hot_ratio = n_hot / N
        # cold budget: expected active cold fraction at this batch size
        cold_union = union[n_hot:] if n_hot < N else np.array([0.0])
        cold_ratio = float(np.clip(cold_union.mean() * 2.0, 0.02, 1.0))
        plans[b] = make_plan(N, hot_ratio, cold_ratio, sc.cluster_size,
                             groups=groups, backend=backend,
                             storage_dtype=storage_dtype)
    return order, np.ascontiguousarray(sorted_f), plans


def _bundle_bytes(cfg: ModelConfig, storage_dtype: str = "fp16") -> int:
    from repro.core.sparse_ffn import ffn_rows
    from repro.quant.quantize import bundle_nbytes
    R = ffn_rows(cfg.activation)
    itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
    return bundle_nbytes(cfg.d_model, storage_dtype, rows=R,
                         itemsize=itemsize)


def hot_io_cap(cfg: ModelConfig, hw: HardwareProfile,
               storage_dtype: str = "fp16") -> int:
    """I/O-aware hot-prefix cap (§5 "carefully balances"): the pinned
    hot region must be prefetchable within one attention block at
    sequential bandwidth. Shared by the dense classifier and the
    two-level MoE plan (there the cap bounds the *total* pinned
    prefix: shared experts + every routed expert's hot rows).
    The prefetch stream is priced at `storage_dtype` bundle bytes —
    int4-mixed bundles are 3x smaller at deployment d_model, so the
    same attention window prefetches ~3x more neurons."""
    return int(hw.seq_bw * hw.attn_time_s
               / max(_bundle_bytes(cfg, storage_dtype), 1))


# ------------------------------------------------------------- assembly ----

def permute_ffn_params(params, order: np.ndarray):
    """Reorder each layer's FFN bundle rows (and predictor columns)
    hot-first, matching the plan. params['layers']['ffn'] leaves are
    stacked (L, ...)."""
    def permute_layer(w, ord_l):
        return w[ord_l]

    layers = params["layers"]
    ffn = layers["ffn"]
    w = np.asarray(ffn["w"])                                # (L, N, R, D)
    w = np.stack([w[l][order[l]] for l in range(w.shape[0])])
    new_ffn = dict(ffn, w=jnp.asarray(w))
    if "pred" in ffn:
        Bm = np.asarray(ffn["pred"]["B"])                   # (L, r, N)
        Bm = np.stack([Bm[l][:, order[l]] for l in range(Bm.shape[0])])
        new_ffn["pred"] = dict(ffn["pred"], B=jnp.asarray(Bm))
    new_layers = dict(layers, ffn=new_ffn)
    return dict(params, layers=new_layers)


def build_plan(cfg: ModelConfig, freqs: np.ndarray = None,
               hw: HardwareProfile = None, groups: int = 1,
               backend: str = "jnp",
               storage_dtype: str = "fp16") -> ExecutionPlan:
    hw = hw or HardwareProfile()
    if freqs is None:
        freqs = synthetic_frequencies(cfg)
    order, sorted_f, plans = classify_neurons(freqs, cfg, hw,
                                              groups=groups, backend=backend,
                                              storage_dtype=storage_dtype)
    return ExecutionPlan(
        arch=cfg.name, n_neurons=freqs.shape[1],
        cluster_size=cfg.sparse_ffn.cluster_size,
        neuron_order=order, frequencies=sorted_f, plans=plans, hardware=hw)


def moe_synthetic_frequencies(cfg: ModelConfig, seed: int = 0,
                              zipf_a: float = 1.2) -> np.ndarray:
    """Within-expert per-token activation frequencies (L, E*f),
    *conditional on the expert being routed* — the MoE analogue of
    `synthetic_frequencies`, used when no profiled frequencies are
    supplied to the two-level `build_moe_plan`.

    Shape: a hot band of ~1.5*hot_ratio*f neurons whose frequency
    ramps 0.95 -> 0.3 (so the >0.5 union threshold lands near the
    config's declared per-expert hot share at batch 1 and the hot
    prefix *grows* with the per-expert batch, Fig 2), then a zipf
    cold tail."""
    rng = np.random.default_rng(seed)
    L, E, f = cfg.num_layers, cfg.num_experts, max(cfg.d_ff, 1)
    band = int(np.clip(round(1.5 * cfg.sparse_ffn.hot_ratio * f), 1, f))
    hot = np.linspace(0.95, 0.3, band)
    rank = np.arange(1, f - band + 1, dtype=np.float64)
    tail = 0.25 / rank ** zipf_a
    base = np.concatenate([hot, tail])
    freqs = np.stack([np.concatenate([rng.permutation(base)
                                      for _ in range(E)])
                      for _ in range(L)])
    return freqs.astype(np.float32)


def permute_moe_params(params, order: np.ndarray):
    """Per-expert hot-first reorder of the stacked expert bundles
    (L, E, f, R, D) — the MoE half of `permute_ffn_params`. Only the
    routed experts' rows move (the router is per-expert, the shared
    experts keep the identity prefix the flat order assigns them), so
    MoE layer outputs are unchanged up to fp reassociation."""
    layers = params["layers"]
    moe = layers["moe"]
    ex = np.asarray(moe["experts"])                         # (L, E, f, R, D)
    L, E, f = ex.shape[:3]
    S = order.shape[1] - E * f
    ro = (order[:, S:].reshape(L, E, f) - S
          - (np.arange(E, dtype=np.int32) * f)[None, :, None])
    ex = np.take_along_axis(ex, ro[..., None, None], axis=2)
    new_moe = dict(moe, experts=jnp.asarray(ex))
    return dict(params, layers=dict(layers, moe=new_moe))


def build_moe_plan(cfg: ModelConfig, freqs: np.ndarray = None,
                   hw: HardwareProfile = None,
                   batch_buckets=(1, 2, 4, 8, 16, 32),
                   storage_dtype: str = "fp16") -> ExecutionPlan:
    """Execution plan for the MoE family.

    Whole-expert mode (DESIGN.md §8, `cfg.moe_intra_expert=False`):
    the flat serving neuron space is [shared experts | routed experts]
    with one cluster per routed expert (cluster_size = d_ff), so the
    storage plane prices expert residency exactly like dense
    cold-cluster residency. Per batch bucket, the cold budget is the
    *expected batch union* of routed experts — 1-(1-k/E)^b per expert,
    the Fig 2 union effect at expert granularity — clamped to [k, E]
    experts. No neuron permutation is needed: the architecture already
    makes the clusters explicit, so `neuron_order` is the identity.

    Two-level mode (DESIGN.md §9, the paper's TurboSparse-Mixtral
    case): expert gating *composes with* intra-expert hot/cold
    clusters. The flat space keeps each routed expert contiguous but
    permutes its d_ff rows hot-first (`freqs` (L, E*f) within-expert
    activation frequencies; synthetic zipf when None). Per bucket, the
    expert union above picks n_act experts; the per-expert hot prefix
    is then sized by the same Fig-2 union math `classify_neurons`
    applies — at the per-active-expert token count b_e = ceil(b*k /
    n_act) — and capped by the shared `hot_io_cap` budget (the total
    pinned prefix, shared + E hot prefixes, must prefetch within one
    attention block). The plan prices hot compute per *activated*
    expert (n_hot = S + n_act*n_hot_e) while pinning every expert's
    hot prefix (n_pinned = S + E*n_hot_e)."""
    hw = hw or HardwareProfile()
    f, E, k = cfg.d_ff, cfg.num_experts, cfg.experts_per_token
    if not E or not k:
        raise ValueError(f"{cfg.name} is not a MoE config "
                         f"(num_experts={E}, experts_per_token={k})")
    S = cfg.num_shared_experts * f
    N = cfg.moe_flat_neurons
    L = cfg.num_layers

    def expert_union(b):
        union = 1.0 - (1.0 - k / E) ** b
        return min(max(int(round(E * union)), min(k, E)), E)

    if not cfg.moe_intra_expert:
        plans = {b: HybridPlan(n_hot=S, k_cold=expert_union(b) * f,
                               groups=1, cluster_size=f,
                               storage_dtype=storage_dtype)
                 for b in batch_buckets}
        # shared experts always fire; each routed expert at rate ~k/E
        fr = np.concatenate([np.ones((S,), np.float32),
                             np.full((E * f,), k / E, np.float32)])
        fr = np.tile(fr, (L, 1))
        order = np.tile(np.arange(N, dtype=np.int32), (L, 1))
        return ExecutionPlan(
            arch=cfg.name, n_neurons=N, cluster_size=f,
            neuron_order=order, frequencies=fr, plans=plans, hardware=hw)

    # ---- two-level: expert union x intra-expert hot/cold ----
    cs = cfg.sparse_ffn.cluster_size
    if f % cs:
        raise ValueError(
            f"{cfg.name}: d_ff={f} must be a multiple of the "
            f"intra-expert cluster size {cs}")
    if freqs is None:
        freqs = moe_synthetic_frequencies(cfg)
    freqs = np.asarray(freqs, np.float32)
    if freqs.shape != (L, E * f):
        raise ValueError(
            f"two-level MoE frequencies must be (L, E*f) = "
            f"({L}, {E * f}); got {freqs.shape}")
    per_exp = freqs.reshape(L, E, f)
    order_e = np.argsort(-per_exp, axis=2).astype(np.int32)  # hot-first
    sorted_f = np.take_along_axis(per_exp, order_e, axis=2)
    mean_f = sorted_f.mean(axis=(0, 1))         # (f,) layer+expert profile
    cap_e = max((hot_io_cap(cfg, hw, storage_dtype) - S) // E, 0)

    plans = {}
    for b in batch_buckets:
        n_act = expert_union(b)
        b_e = max(int(np.ceil(b * k / n_act)), 1)  # tokens/active expert
        union = 1.0 - (1.0 - mean_f) ** b_e
        n_hot_e = int((union > 0.5).sum())
        n_hot_e = max(min(round_down(n_hot_e, cs),
                          round_down(cap_e, cs), f - cs), 0)
        cold_union = union[n_hot_e:]
        cold_ratio = float(np.clip(cold_union.mean() * 2.0, 0.02, 1.0))
        k_cold_e = max(round_down(int((f - n_hot_e) * cold_ratio), cs), cs)
        plans[b] = HybridPlan(
            n_hot=S + n_act * n_hot_e, k_cold=n_act * k_cold_e,
            groups=1, cluster_size=cs,
            n_expert_hot=n_hot_e, n_pinned=S + E * n_hot_e,
            storage_dtype=storage_dtype)

    # flat order: identity shared prefix, then each expert's rows
    # hot-first within its contiguous block (prepare_params applies
    # this with permute_moe_params, so flat id == physical row)
    routed = (order_e + (np.arange(E, dtype=np.int32) * f)[None, :, None]
              + S).reshape(L, E * f)
    shared = np.tile(np.arange(S, dtype=np.int32), (L, 1))
    order = np.concatenate([shared, routed], axis=1).astype(np.int32)
    fr = np.concatenate([np.ones((L, S), np.float32),
                         sorted_f.reshape(L, E * f)], axis=1)
    return ExecutionPlan(
        arch=cfg.name, n_neurons=N, cluster_size=cs,
        neuron_order=order, frequencies=fr, plans=plans, hardware=hw)
