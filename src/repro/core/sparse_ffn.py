"""Hybrid hot/cold FFN — the paper's technique as a composable JAX module.

Weight layout (paper §4.4 "flexible neuron loading"): one bundled tensor
`w` of shape (N, R, D) — neuron-major so that neuron *i*'s Gate row,
Up row and Down column are contiguous (R=3 for gated FFNs, R=2 for
ungated: [fc1, fc2]). This is exactly the paper's position-major
Gate-Up-Down bundle: one fetch per neuron brings all of it.

Three compute paths:
  * ffn_dense   — full dense FFN; train / prefill ("NPU-centric", §4.1.1)
                  and the hot prefix of decode.
  * ffn_hybrid  — decode: dense hot prefix + predictor-gated gathered
                  cold clusters (§4.1.2). Cold neurons are re-densified
                  into MXU-aligned gathered tiles (TPU adaptation of the
                  paper's CPU sparse path — see DESIGN.md §2).
  * Pallas backend — plan.backend='pallas' routes the WHOLE cold path
                  (predictor score -> batch-union top-k -> cluster
                  gather -> gated FFN, incl. CATS token gating) through
                  one fused kernel, kernels/cluster_gather_ffn.
                  fused_cold_ffn: in-kernel selection drives
                  double-buffered HBM->VMEM cluster DMA — the paper's
                  neuron-cluster-level I/O pipeline at VMEM granularity
                  (DESIGN.md §10). Composes with the shard_map cold
                  path below (each shard runs the kernel over its local
                  groups) and selects the same clusters as the jnp
                  backend bit-for-bit, so decode is token-identical.

Distribution: the neuron dim is grouped as (groups, N/groups) with the
group dim sharded over the mesh 'model' axis; predictor scoring, top-k
selection and gathering are all per-group, so the cold path needs *no*
collective beyond the FFN's usual output reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.clusters import HybridPlan
from repro.core.predictor import init_predictor, predictor_spec, predict_scores
from repro.models.modules import dense_init, activation_fn
from repro.sharding import constrain, BATCH


def ffn_rows(activation: str) -> int:
    return 2 if activation == "gelu" else 3


def init_ffn(key, d_model: int, d_ff: int, activation: str, dtype,
             predictor_rank: int = 0):
    """Bundled FFN params (+ optional activation predictor)."""
    kw, kp = jax.random.split(key)
    R = ffn_rows(activation)
    w = dense_init(kw, (d_ff, R, d_model), dtype)
    params = {"w": w}
    if predictor_rank:
        params["pred"] = init_predictor(kp, d_model, d_ff, predictor_rank, dtype)
    return params


def ffn_spec(has_predictor: bool):
    spec = {"w": P("model", None, None)}
    if has_predictor:
        spec["pred"] = predictor_spec()
    return spec


def _apply_bundle(w, x, activation: str):
    """Dense FFN over a (n, R, D) bundle slice. x (..., D) -> (..., D)."""
    act = activation_fn(activation)
    g = jnp.einsum("...d,nd->...n", x, w[:, 0])
    if w.shape[1] == 3:
        u = jnp.einsum("...d,nd->...n", x, w[:, 1])
        h = act(g) * u
    else:
        h = act(g)
    return jnp.einsum("...n,nd->...d", h, w[:, -1])


def ffn_dense(params, x, activation: str):
    """Full dense FFN (the prefill/train path; paper §4.1.1)."""
    w = params["w"]
    act = activation_fn(activation)
    g = jnp.einsum("...d,nd->...n", x, w[:, 0])
    g = constrain(g, P(BATCH, *([None] * (g.ndim - 2)), "model"))
    if w.shape[1] == 3:
        u = jnp.einsum("...d,nd->...n", x, w[:, 1])
        h = act(g) * u
    else:
        h = act(g)
    y = jnp.einsum("...n,nd->...d", h, w[:, -1])
    return constrain(y, P(BATCH, *([None] * (y.ndim - 1))))


def _gather_quant(wq, wsc, wout, cidx):
    """Gather selected cold clusters from the stored quantized
    representation and dequantize at the gather boundary (§7.6):
    int8 codes * per-row scale (+ fp16 outlier sidecar for
    int4-mixed) — the exact formula the pallas fused kernel applies
    after its int8 DMA, so backends stay token-identical.

    wq (G, nc_g, cs, R, D) int8; wsc (G, nc_g, cs, R) f32;
    wout same shape as wq or None; cidx (G, kc) -> (G, kc, cs, R, D).
    """
    q = jnp.take_along_axis(wq, cidx[:, :, None, None, None], axis=1)
    sc = jnp.take_along_axis(wsc, cidx[:, :, None, None], axis=1)
    deq = q.astype(jnp.float32) * sc[..., None]
    if wout is not None:
        o = jnp.take_along_axis(wout, cidx[:, :, None, None, None],
                                axis=1)
        deq = deq + o.astype(jnp.float32)
    return deq


def _quant_operands(params, n_hot: int, shape) -> dict:
    """Cold slices of the stored quantized containers, shaped for the
    fused kernel ((G, nc_g, cs, R, D) codes / (G, nc_g, cs, R) scales);
    empty for fp16 plans."""
    if "wq" not in params:
        return {}
    ops = {"wq": params["wq"][n_hot:].reshape(shape),
           "wsc": params["wsc"][n_hot:].reshape(shape[:-1])}
    if "wout" in params:
        ops["wout"] = params["wout"][n_hot:].reshape(shape)
    return ops


def _use_shard_map(groups: int) -> bool:
    from repro.sharding import current_mesh
    m = current_mesh()
    if m is None or "model" not in m.axis_names or groups <= 1:
        return False
    n = dict(m.shape).get("model", 1)
    return n > 1 and groups % n == 0


def _cold_path_shard_map(params, x, activation: str, mode: str,
                         plan: HybridPlan, n_hot: int, n_cold: int,
                         active_mask=None):
    """Shard-local cold path: each 'model' shard scores its own neuron
    slice, picks each local group's top clusters, gathers them locally,
    computes the partial FFN output and psums once per layer.
    x (B, D) -> ((B, D), (G, kc)).

    The mesh 'model' axis (size n) owns G/n whole groups per shard —
    group-granular selection is therefore *exactly* the single-device
    math, shard-decomposed: no cross-shard candidate ever competes in a
    top-k, so 1-, 2-, 4- and 8-way runs pick identical clusters.

    active_mask (B,) bool: rows excluded from the batch-union predictor
    scoring (free KV-arena slots decode garbage lanes; they must not
    steer cluster selection for live requests)."""
    from jax.sharding import PartitionSpec as PS
    from repro.compat import shard_map
    from repro.sharding import current_mesh

    mesh = current_mesh()
    G, cs, kc = plan.groups, plan.cluster_size, plan.clusters_per_group
    n_model = dict(mesh.shape)["model"]
    g_loc = G // n_model                              # groups per shard
    nc_g = n_cold // G // cs
    w = params["w"]
    R, D = w.shape[1], w.shape[2]
    act = activation_fn(activation)
    wc = w[n_hot:].reshape(G * nc_g, cs, R, D)        # row-sharded 'model'
    A = params["pred"]["A"]
    Bp = params["pred"]["B"][:, n_hot:]               # (r, Nc) col-sharded
    quant = "wq" in params

    def _local_quant(qops):
        """Shard-local quantized cold containers, kernel-shaped."""
        q = {"wq": qops[0].reshape(g_loc, nc_g, cs, R, D),
             "wsc": qops[1].reshape(g_loc, nc_g, cs, R)}
        if len(qops) == 3:
            q["wout"] = qops[2].reshape(g_loc, nc_g, cs, R, D)
        return q

    def local(xl, wcl, Al, Bl, maskl, *qops):
        # xl (B, D) replicated over model; wcl (g_loc*nc_g, cs, R, D)
        # local clusters; Bl (r, Nc_local) local predictor columns;
        # qops: the shard-local quantized containers when the plan
        # stores int8/int4-mixed bundles.
        if plan.backend == "pallas":
            # the fused kernel IS the shard-local math: selection never
            # crosses groups, so running it over the shard's g_loc
            # groups (same psum / id all_gather) keeps every mesh size
            # token-identical to the jnp backend.
            from repro.kernels import ops as kops
            y, idx = kops.fused_cold_ffn(
                xl, wcl.reshape(g_loc, nc_g, cs, R, D), Al, Bl,
                activation=activation, mode=mode, kc=kc,
                active_mask=maskl,
                **(_local_quant(qops) if quant else {}))
            return (jax.lax.psum(y.astype(jnp.float32), "model"),
                    jax.lax.all_gather(idx, "model").reshape(G, kc))
        h = jnp.einsum("bd,dr->br", xl.astype(jnp.float32),
                       Al.astype(jnp.float32))
        scores = jnp.einsum("br,rn->bn", h, Bl.astype(jnp.float32))
        union = jnp.where(maskl[:, None], scores,
                          -jnp.inf).max(axis=0)       # (Nc_local,)
        cscore = union.reshape(g_loc * nc_g, cs).max(axis=-1)
        _, idx = jax.lax.top_k(cscore.reshape(g_loc, nc_g),
                               kc)                    # (g_loc, kc)
        if quant:
            lq = _local_quant(qops)
            gath = _gather_quant(lq["wq"], lq["wsc"], lq.get("wout"),
                                 idx).astype(w.dtype)
        else:
            gath = jnp.take_along_axis(
                wcl.reshape(g_loc, nc_g, cs, R, D),
                idx[:, :, None, None, None], axis=1)  # (g_loc,kc,cs,R,D)
        gath = gath.reshape(g_loc * kc * cs, R, D)
        g = jnp.einsum("bd,kd->bk", xl, gath[:, 0])
        if R == 3:
            u = jnp.einsum("bd,kd->bk", xl, gath[:, 1])
            hh = act(g) * u
        else:
            hh = act(g)
        if mode == "cats":
            tok = scores.reshape(-1, g_loc, nc_g, cs)
            tok = jnp.take_along_axis(tok, idx[None, :, :, None], axis=2)
            hh = hh * (tok.reshape(hh.shape) > 0.0).astype(hh.dtype)
        y = jnp.einsum("bk,kd->bd", hh.astype(w.dtype), gath[:, -1])
        # psum in f32: XLA:CPU's AllReducePromotion pass crashes on
        # bf16 all-reduce inside partial-manual shard_map (and f32
        # reduction is numerically better anyway).
        return (jax.lax.psum(y.astype(jnp.float32), "model"),
                jax.lax.all_gather(idx, "model").reshape(G, kc))

    if active_mask is None:
        active_mask = jnp.ones((x.shape[0],), bool)
    operands = [x, wc, A, Bp, active_mask]
    in_specs = [PS(None, None), PS("model", None, None, None),
                PS(None, None), PS(None, "model"), PS(None)]
    if quant:
        # stored containers shard exactly like the fp cold rows
        operands += [params["wq"][n_hot:].reshape(G * nc_g, cs, R, D),
                     params["wsc"][n_hot:].reshape(G * nc_g, cs, R)]
        in_specs += [PS("model", None, None, None),
                     PS("model", None, None)]
        if "wout" in params:
            operands.append(
                params["wout"][n_hot:].reshape(G * nc_g, cs, R, D))
            in_specs.append(PS("model", None, None, None))
    fn = shard_map(
        local, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(PS(None, None), PS(None, None)),
        axis_names={"model"}, check_vma=False)
    return fn(*operands)


def ffn_hybrid(params, x, activation: str, mode: str, plan: HybridPlan,
               return_indices: bool = False, active_mask=None):
    """Decode-phase hybrid FFN (paper §4.1.2). x: (B, D).

    hot prefix  -> dense matmul (MXU; the NPU engine analogue)
    cold suffix -> predictor scores -> batch-union -> per-group top-k
                   clusters -> gathered dense tiles (the CPU engine
                   analogue, re-densified for the MXU).

    active_mask (B,) bool, optional: rows excluded from the batch-union
    selection (the serving engine's free KV-arena slots). Masked rows
    still produce an output but never steer which clusters activate.
    """
    w = params["w"]                                   # (N, R, D)
    N, R, D = w.shape
    B = x.shape[0]
    n_hot, G, kg = plan.n_hot, plan.groups, plan.k_cold
    y = jnp.zeros((B, D), jnp.float32)

    if n_hot > 0:
        y += _apply_bundle(w[:n_hot], x, activation).astype(jnp.float32)

    n_cold = N - n_hot
    cs = plan.cluster_size
    kc = plan.clusters_per_group                      # active clusters/group
    cidx = jnp.zeros((G, max(kc, 1)), jnp.int32)
    if n_cold > 0 and kc > 0 and "pred" in params and _use_shard_map(G):
        # §Perf iteration C4: the grouped-pjit formulation below lowers
        # to a per-shard materialize-and-select chain (each layer read
        # the full local cold weights several times in f32). shard_map
        # keeps predictor scoring, top-k and the cluster gather strictly
        # shard-local; only the output psum crosses shards.
        y_cold, cidx = _cold_path_shard_map(
            params, x, activation, mode, plan, n_hot, n_cold, active_mask)
        y += y_cold.astype(jnp.float32)
    elif n_cold > 0 and kc > 0 and "pred" in params:
        nc_g = n_cold // G // cs                      # cold clusters per group
        if plan.backend == "pallas":
            # the fused kernel computes scoring, batch-union top-k,
            # gather, FFN and CATS token gating itself — same math as
            # the jnp chain below (selection bit-identical, output
            # within fp tolerance), one pallas_call per layer.
            from repro.kernels import ops as kops
            wc = w[n_hot:].reshape(G, nc_g, cs, R, D)
            y_cold, cidx = kops.fused_cold_ffn(
                x, wc, params["pred"]["A"],
                params["pred"]["B"][:, n_hot:],
                activation=activation, mode=mode, kc=kc,
                active_mask=active_mask,
                **_quant_operands(params, n_hot, (G, nc_g, cs, R, D)))
            y += y_cold.astype(jnp.float32)
            y = constrain(y.astype(x.dtype), P(BATCH, None))
            if return_indices:
                return y, cidx
            return y
        scores = predict_scores(params["pred"], x)[:, n_hot:]   # (B, Nc) fp32
        quant = "wq" in params
        # Batch union (paper fn.1: a neuron is active if any token in
        # the batch triggers it), then *cluster*-granular selection —
        # the neuron cluster is the basic unit (§3.1).
        if active_mask is not None:
            union = jnp.where(active_mask[:, None], scores,
                              -jnp.inf).max(axis=0)             # (Nc,)
        else:
            union = scores.max(axis=0)                          # (Nc,)
        cscore = union.reshape(G, nc_g, cs).max(axis=-1)        # (G, nc_g)
        cscore = constrain(cscore, P("model", None))
        _, cidx = jax.lax.top_k(cscore, kc)                     # (G, kc)
        if quant:
            # gather the *stored* int8 codes and dequantize right at
            # the gather boundary (cast back to w.dtype so downstream
            # compute matches the in-place roundtrip held by w)
            wq = params["wq"][n_hot:].reshape(G, nc_g, cs, R, D)
            wq = constrain(wq, P("model", None, None, None, None))
            wsc = params["wsc"][n_hot:].reshape(G, nc_g, cs, R)
            wout = params.get("wout")
            if wout is not None:
                wout = wout[n_hot:].reshape(G, nc_g, cs, R, D)
            gath = _gather_quant(wq, wsc, wout, cidx).astype(w.dtype)
        else:
            wc = w[n_hot:].reshape(G, nc_g, cs, R, D)
            wc = constrain(wc, P("model", None, None, None, None))
            gath = jnp.take_along_axis(
                wc, cidx[:, :, None, None, None], axis=1)  # (G,kc,cs,R,D)
        gath = gath.reshape(G, kc * cs, R, D)
        act = activation_fn(activation)
        g = jnp.einsum("bd,gkd->bgk", x, gath[:, :, 0])
        if R == 3:
            u = jnp.einsum("bd,gkd->bgk", x, gath[:, :, 1])
            h = act(g) * u
        else:
            h = act(g)
        if mode == "cats":
            # CATS-style (§7.2.5): gate each token's contribution by
            # its own predicted activation for the selected neurons.
            tok = scores.reshape(B, G, nc_g, cs)
            tok = jnp.take_along_axis(
                tok, cidx[None, :, :, None], axis=2)    # (B,G,kc,cs)
            h = h * (tok.reshape(B, G, kc * cs) > 0.0).astype(h.dtype)
        y_cold = jnp.einsum("bgk,gkd->bd", h.astype(w.dtype), gath[:, :, -1])
        y += y_cold.astype(jnp.float32)

    y = constrain(y.astype(x.dtype), P(BATCH, None))
    if return_indices:
        return y, cidx       # (G, kc) selected cold cluster ids per group
    return y


def ffn_apply(params, x, activation: str, sparse_cfg, plan: HybridPlan | None,
              return_indices: bool = False, active_mask=None):
    """Uniform entry: dense when plan is None (train/prefill) else hybrid."""
    if plan is None or not sparse_cfg.enabled:
        y = ffn_dense(params, x, activation)
        return (y, None) if return_indices else y
    squeeze = x.ndim == 3
    xx = x.reshape(-1, x.shape[-1]) if squeeze else x
    out = ffn_hybrid(params, xx, activation, sparse_cfg.mode, plan,
                     return_indices=return_indices, active_mask=active_mask)
    if return_indices:
        y, cidx = out
        return (y.reshape(x.shape) if squeeze else y), cidx
    return out.reshape(x.shape) if squeeze else out
