"""Neuron-cluster-level pipeline (paper §4.3, Fig 6).

Two parts:

1. A deterministic discrete-event simulator comparing the two pipeline
   policies of Fig 6 — `matrix` (barrier between matrices: compute may
   only run clusters of the lowest incomplete matrix) and `cluster`
   (PowerInfer-2: no barrier; compute immediately moves to any ready
   cluster of any matrix). Driven by measured compute times + the
   StorageModel's I/O times; reproduces the paper's bubble-elimination
   claim and Table 4's compute/I-O split.

2. A real async prefetch executor: ONE I/O thread (the paper pins a
   single I/O core because UFS has a single command queue; the host-DMA
   analogue keeps one stream) overlapping host->device fetches with
   compute in the serving engine.
"""
from __future__ import annotations

import heapq
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass


# ------------------------------------------------- discrete-event sim ----

@dataclass(frozen=True)
class ClusterTask:
    matrix: int           # which matrix (Gate/Up/Down of layer l, ...)
    cluster: int          # index within the matrix
    comp_time: float      # seconds of compute
    io_time: float = 0.0  # seconds of I/O (0 = already in memory)


@dataclass
class PipelineResult:
    makespan: float
    compute_busy: float       # summed busy seconds across workers
    io_busy: float
    n_workers: int
    policy: str

    @property
    def compute_util(self) -> float:
        return self.compute_busy / (self.makespan * self.n_workers)

    @property
    def io_fraction(self) -> float:
        """Fraction of the critical path attributable to I/O stalls
        (Table 2/4 style: 1 - compute share of wall time)."""
        per_worker = self.compute_busy / self.n_workers
        return max(0.0, 1.0 - per_worker / self.makespan)


def _greedy_compute(tasks, ready, workers, floor=0.0):
    """List-schedule tasks on workers; each task starts at
    max(ready[task], worker_free, floor). Returns (busy, completion).

    Each round picks the task minimizing (start, matrix, cluster) with
    start = max(ready, earliest-free worker, floor) and assigns it to
    that worker. Two heaps — tasks keyed by ready time and workers
    keyed by free time — make each pick O(log n) instead of the naive
    rescan of all pending tasks (O(n^2 * W) overall); the schedule, and
    therefore the makespan, is identical.
    """
    busy = 0.0
    last = floor
    future = []            # (ready_time, matrix, cluster, task)
    for t in tasks:
        r = max(ready[(t.matrix, t.cluster)], floor)
        future.append((r, t.matrix, t.cluster, t))
    heapq.heapify(future)
    avail = []             # ready now: (matrix, cluster, task)
    wheap = list(workers)
    heapq.heapify(wheap)
    while future or avail:
        wfree = heapq.heappop(wheap)
        now = max(wfree, floor)
        while future and future[0][0] <= now:
            _, m, c, t = heapq.heappop(future)
            heapq.heappush(avail, (m, c, t))
        if avail:
            _, _, task = heapq.heappop(avail)
            start = now
        else:                       # idle until the next task is ready
            start, _, _, task = heapq.heappop(future)
        end = start + task.comp_time
        heapq.heappush(wheap, end)
        busy += task.comp_time
        last = max(last, end)
    workers[:] = wheap              # free-time multiset for the caller
    return busy, last


def simulate_pipeline(tasks, n_compute: int = 4,
                      policy: str = "cluster") -> PipelineResult:
    """Simulate compute workers + ONE I/O worker (single UFS queue).

    policy='matrix'  — Fig 6(a): isolated matrix units. I/O for matrix
                       m's missing clusters only *starts* once matrix
                       m-1 has fully computed, and compute may only run
                       the current matrix's clusters.
    policy='cluster' — Fig 6(b): PowerInfer-2. The I/O thread streams
                       misses ahead in matrix order; compute takes any
                       ready cluster from any matrix (no barrier).
    """
    assert policy in ("matrix", "cluster")
    tasks = sorted(tasks, key=lambda t: (t.matrix, t.cluster))
    n_matrices = max(t.matrix for t in tasks) + 1 if tasks else 0
    io_busy = sum(t.io_time for t in tasks)
    workers = [0.0] * n_compute

    if policy == "cluster":
        # I/O issued serially ahead of compute, in matrix order
        ready = {}
        t_io = 0.0
        for t in tasks:
            if t.io_time > 0:
                t_io += t.io_time
                ready[(t.matrix, t.cluster)] = t_io
            else:
                ready[(t.matrix, t.cluster)] = 0.0
        busy, makespan = _greedy_compute(tasks, ready, workers)
        return PipelineResult(makespan=makespan, compute_busy=busy,
                              io_busy=io_busy, n_workers=n_compute,
                              policy=policy)

    # matrix policy: strict per-matrix units for both I/O and compute
    compute_busy = 0.0
    t_prev = 0.0       # completion time of the previous matrix
    io_free = 0.0
    for m in range(n_matrices):
        unit = [t for t in tasks if t.matrix == m]
        ready = {}
        io_free = max(io_free, t_prev)
        for t in unit:
            if t.io_time > 0:
                io_free += t.io_time
                ready[(t.matrix, t.cluster)] = io_free
            else:
                ready[(t.matrix, t.cluster)] = t_prev
        busy, t_prev = _greedy_compute(unit, ready, workers, floor=t_prev)
        compute_busy += busy
    return PipelineResult(makespan=t_prev, compute_busy=compute_busy,
                          io_busy=io_busy, n_workers=n_compute,
                          policy="matrix")


def make_decode_tasks(n_matrices: int, clusters_per_matrix: int,
                      in_memory_fraction: float, comp_time: float,
                      io_time: float, seed: int = 0):
    """Build a Fig-6-style workload: a fraction of clusters is cached,
    the rest need random I/O."""
    import random
    rng = random.Random(seed)
    tasks = []
    for m in range(n_matrices):
        for c in range(clusters_per_matrix):
            cached = rng.random() < in_memory_fraction
            tasks.append(ClusterTask(m, c, comp_time,
                                     0.0 if cached else io_time))
    return tasks


# ------------------------------------------------ async prefetcher ----

class PrefetchExecutor:
    """Single I/O thread overlapping cold-store fetches with compute.

    submit() returns a Future; the serving engine submits layer l+1's
    predicted-miss fetches before computing layer l (the cluster-level
    pipeline: compute of one matrix overlaps I/O of the next).
    """

    def __init__(self):
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="neuron-io")
        self._lock = threading.Lock()
        self.submitted = 0

    def submit(self, fn, *args, **kwargs):
        with self._lock:
            self.submitted += 1
        return self._pool.submit(fn, *args, **kwargs)

    def shutdown(self):
        self._pool.shutdown(wait=True)
