"""PowerInfer-2 core: neuron clusters, hybrid hot/cold FFN, activation
predictor, offline planner, segmented neuron cache, cold store, and the
neuron-cluster-level pipeline."""
from repro.core.clusters import HybridPlan, make_plan, scale_plan_for_batch
from repro.core.predictor import init_predictor, predict_scores, predict_proba
from repro.core.sparse_ffn import init_ffn, ffn_dense, ffn_hybrid, ffn_apply
from repro.core.planner import (
    ExecutionPlan, HardwareProfile, build_plan, profile_activations,
    classify_neurons, permute_ffn_params, synthetic_frequencies)
from repro.core.cache import NeuronCache, CacheStats
from repro.core.coldstore import ColdStore
from repro.core.pipeline import (
    ClusterTask, simulate_pipeline, make_decode_tasks, PrefetchExecutor)
from repro.core.adaptation import BucketedDecoder, BatchTracker, bucket_for
from repro.core import baselines

__all__ = [
    "HybridPlan", "make_plan", "scale_plan_for_batch",
    "init_predictor", "predict_scores", "predict_proba",
    "init_ffn", "ffn_dense", "ffn_hybrid", "ffn_apply",
    "ExecutionPlan", "HardwareProfile", "build_plan",
    "profile_activations", "classify_neurons", "permute_ffn_params",
    "synthetic_frequencies", "NeuronCache", "CacheStats", "ColdStore",
    "ClusterTask", "simulate_pipeline", "make_decode_tasks",
    "PrefetchExecutor", "BucketedDecoder", "BatchTracker", "bucket_for",
    "baselines",
]
