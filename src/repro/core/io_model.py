"""Storage-tier performance models (paper §2.3.2, adapted per DESIGN.md §2).

The paper measures UFS 4.0; a TPU pod's slow tier is host DRAM behind
DMA/PCIe. Both are modeled with the same interface so benchmarks can
reproduce the paper's UFS numbers *and* report the TPU-adapted tier.

Numbers for `UFS40` come straight from the paper:
  * sequential: 450 MB/s @4KB -> 4 GB/s @512KB
  * random:     1 GB/s @4KB/128MB range, 3.5 GB/s @512KB
  * range sensitivity: 4KB random drops below 850 MB/s at 512MB range
  * core dependence: big 1076 / mid 1008 / little 762 MB/s
  * single command queue: concurrency degrades up to 40%
"""
from __future__ import annotations

from dataclasses import dataclass
import bisect


def _interp(points, x):
    """Piecewise-linear interpolation on sorted (x, y) points."""
    xs = [p[0] for p in points]
    if x <= xs[0]:
        return points[0][1]
    if x >= xs[-1]:
        return points[-1][1]
    i = bisect.bisect_left(xs, x)
    (x0, y0), (x1, y1) = points[i - 1], points[i]
    t = (x - x0) / (x1 - x0)
    return y0 + t * (y1 - y0)


@dataclass(frozen=True)
class StorageModel:
    """Bandwidth model: read time for (bytes, block_size, access kind)."""
    name: str
    # (block_size_bytes, MB/s) curves
    seq_curve: tuple = ()
    rand_curve: tuple = ()
    base_latency_us: float = 100.0
    range_derate: float = 1.0      # multiplier for large scattered ranges
    core_derate: float = 1.0       # paper Table 1: which core runs I/O
    queue_derate: float = 1.0      # >1 issuing core contention

    def bandwidth(self, block_size: int, random: bool) -> float:
        """Bytes/second for the given access pattern."""
        curve = self.rand_curve if random else self.seq_curve
        mbps = _interp(curve, block_size)
        return mbps * 1e6 * self.range_derate * self.core_derate \
            * self.queue_derate

    def read_time(self, nbytes: int, block_size: int, random: bool) -> float:
        """Seconds to read `nbytes` in `block_size` chunks.

        The bandwidth curves are *measured throughput at that block
        size* (paper §2.3.2), so per-op latency is already amortized
        into them — no separate latency term.
        """
        if nbytes <= 0:
            return 0.0
        bw = self.bandwidth(block_size, random)
        return nbytes / bw


UFS40 = StorageModel(
    name="ufs4.0",
    seq_curve=((4096, 450.0), (65536, 1800.0), (262144, 3200.0),
               (524288, 4000.0)),
    rand_curve=((4096, 1000.0), (8192, 1100.0), (24576, 1900.0),
                (65536, 2400.0), (524288, 3500.0)),
    base_latency_us=80.0,
)

UFS31 = StorageModel(
    name="ufs3.1",
    seq_curve=((4096, 300.0), (65536, 1100.0), (524288, 2100.0)),
    rand_curve=((4096, 550.0), (24576, 1000.0), (524288, 1800.0)),
    base_latency_us=110.0,
)

# TPU-adapted slow tier: host DRAM over PCIe-class DMA. Sequential and
# random converge for large blocks; latency dominates small transfers.
HOST_DMA = StorageModel(
    name="host-dma",
    seq_curve=((4096, 4000.0), (65536, 20000.0), (524288, 50000.0)),
    rand_curve=((4096, 2000.0), (65536, 15000.0), (524288, 45000.0)),
    base_latency_us=20.0,
)


# ------------------------------------------------ kernel calibration ----

@dataclass(frozen=True)
class KernelCalibration:
    """Measured kernel throughput -> planner/storage-plane constants.

    `HardwareProfile.dense_engine_flops` / `sparse_engine_flops` were
    hand-set deployment constants; this closes the loop with the
    *executed* kernels instead: `benchmarks/bench_kernels.py` times the
    dense FFN and the fused cold-path kernel (score -> top-k ->
    double-buffered gather -> FFN) per serving bucket, aggregates the
    measured rates here, and writes the result into its
    BENCH_kernels.json artifact. `hardware()` then produces the
    HardwareProfile the storage plane prices with — on a real TPU the
    same harness yields real device rates; on this CPU container the
    rates are interpret-mode (structural, not wall-clock-representative,
    which is why `source` is carried along and reported).
    """
    dense_flops_per_s: float       # measured dense (hot-prefix) engine
    sparse_flops_per_s: float      # measured fused gathered cold path
    gather_bytes_per_s: float      # weight bytes/s the cold path moved
    source: str = "uncalibrated"   # e.g. "interpret-cpu jax 0.4.37"

    @staticmethod
    def from_rows(rows) -> "KernelCalibration":
        """Aggregate per-bucket bench rows (dicts carrying
        dense_flops/t_dense_s, cold_flops/t_pallas_cold_s and
        gather_bytes) into one calibration: total work over total
        measured time, so big buckets weigh proportionally."""
        dense_t = sum(r["t_dense_s"] for r in rows)
        cold_t = sum(r["t_pallas_cold_s"] for r in rows)
        return KernelCalibration(
            dense_flops_per_s=sum(r["dense_flops"] for r in rows)
            / max(dense_t, 1e-12),
            sparse_flops_per_s=sum(r["cold_flops"] for r in rows)
            / max(cold_t, 1e-12),
            gather_bytes_per_s=sum(r["gather_bytes"] for r in rows)
            / max(cold_t, 1e-12),
            source=rows[0].get("source", "uncalibrated") if rows
            else "uncalibrated")

    @staticmethod
    def from_bench_json(path) -> "KernelCalibration":
        """Load the calibration block a bench_kernels --json run wrote."""
        import json
        with open(path) as f:
            obj = json.load(f)
        return KernelCalibration(**obj["calibration"])

    def hardware(self, base=None):
        """A HardwareProfile whose engine rates are the measured ones
        (seq/rand storage bandwidths and the attention window stay the
        base profile's — they are storage-tier, not kernel, numbers)."""
        from dataclasses import replace
        from repro.core.planner import HardwareProfile  # lazy: no cycle
        base = base or HardwareProfile()
        return replace(base,
                       name=f"{base.name}+kernels[{self.source}]",
                       dense_engine_flops=self.dense_flops_per_s,
                       sparse_engine_flops=self.sparse_flops_per_s)


def with_core(model: StorageModel, core: str) -> StorageModel:
    """Paper Table 1: I/O throughput depends on the issuing core."""
    derate = {"big": 1.0, "mid": 0.94, "little": 0.71}[core]
    from dataclasses import replace
    return replace(model, core_derate=derate)


def with_queue_contention(model: StorageModel, n_issuers: int) -> StorageModel:
    """Paper §2.3.2: UFS has a single command queue; multiple issuing
    cores degrade throughput by up to 40%."""
    from dataclasses import replace
    derate = 1.0 if n_issuers <= 1 else max(0.6, 1.0 - 0.1 * (n_issuers - 1))
    return replace(model, queue_derate=derate)
