"""Host-DRAM cold store with the paper's bundled neuron layout (§4.4).

Weights live position-major: record i = (gate row i, up row i, down
column i) — one contiguous fetch brings a whole neuron bundle (the
paper measured 80% Gate/Up/Down co-activation). The store also models
the paper's two I/O refinements:

  * two-phase loading (4-bit models): fetch Gate first; fetch Up/Down
    only if the Gate activation is non-zero (saves ~20% of bundle bytes
    on non-co-activated neurons);
  * block-size-aware reads: bundle fetches are split into the block
    size that maximizes the storage model's bandwidth.

On a pod the "flash" is host DRAM: fetch() returns real numpy rows and
a *modeled* I/O time from the configured StorageModel, so the serving
engine and the pipeline benchmarks get both data and timing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.io_model import StorageModel, UFS40


@dataclass
class FetchResult:
    rows: np.ndarray          # (k, R, D) bundle rows
    nbytes: int
    io_time: float            # modeled seconds
    n_ops: int


class ColdStore:
    """Per-layer bundled neuron store backed by host memory."""

    def __init__(self, bundles_per_layer, storage: StorageModel = UFS40,
                 two_phase: bool = False, block_size: int = 24576,
                 bundle_bytes_override: int = None,
                 count_scale: float = 1.0):
        """bundles_per_layer: list of np arrays (N, R, D) — one per layer,
        already permuted hot-first by the planner.

        bundle_bytes_override / count_scale let a reduced model's store
        price I/O at deployment-size constants (serving.TimingProfile).
        """
        self.layers = [np.asarray(b) for b in bundles_per_layer]
        self.storage = storage
        self.two_phase = two_phase
        self.block_size = block_size
        self.bundle_bytes_override = bundle_bytes_override
        self.count_scale = count_scale
        self.total_fetches = 0
        self.total_bytes = 0
        self.total_io_time = 0.0

    def bundle_bytes(self, layer: int = 0) -> int:
        if self.bundle_bytes_override:
            return int(self.bundle_bytes_override)
        b = self.layers[layer]
        return int(b[0].nbytes)

    def fetch(self, layer: int, neuron_ids, gate_active=None) -> FetchResult:
        """Random-read the given neuron bundles.

        gate_active: optional bool per id (two-phase loading §4.4) —
        inactive gates skip the Up/Down half of the bundle.
        """
        ids = np.asarray(neuron_ids, dtype=np.int64)
        rows = self.layers[layer][ids]
        per_bundle = self.bundle_bytes(layer)
        n_eff = len(ids) * self.count_scale
        if self.two_phase and gate_active is not None:
            act = np.asarray(gate_active, dtype=bool)
            # gate = 1/R of the bundle; up/down only when active
            R = rows.shape[1]
            nbytes = int(per_bundle / R * n_eff
                         + per_bundle * (R - 1) / R * act.sum()
                         * self.count_scale)
            n_ops = int(n_eff) + int(act.sum() * self.count_scale)
        else:
            nbytes = int(per_bundle * n_eff)
            n_ops = int(n_eff)
        t = self.storage.read_time(nbytes, min(self.block_size, per_bundle),
                                   random=True)
        self.total_fetches += n_ops
        self.total_bytes += nbytes
        self.total_io_time += t
        return FetchResult(rows=rows, nbytes=nbytes, io_time=t, n_ops=n_ops)

    def fetch_sequential(self, layer: int) -> FetchResult:
        """Stream a whole layer (prefill / hot-region preload, §4.1.1)."""
        rows = self.layers[layer]
        nbytes = int(rows.nbytes)
        t = self.storage.read_time(nbytes, 524288, random=False)
        self.total_bytes += nbytes
        self.total_io_time += t
        return FetchResult(rows=rows, nbytes=nbytes, io_time=t, n_ops=1)

    def reset_stats(self):
        self.total_fetches = 0
        self.total_bytes = 0
        self.total_io_time = 0.0
