"""Dense decoder-only transformer (llama / nemotron / qwen families).

Provides the generic embed->scan(blocks)->logits machinery reused by the
VLM (custom embeddings + M-RoPE angles) and the hybrid model's attention
blocks. Layer params are stacked (leading L dim) and scanned.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.clusters import HybridPlan
from repro.models import blocks
from repro.models.attention import rope_angles
from repro.models.kv_cache import init_full_cache, init_ring_cache, write_pos
from repro.models.modules import (
    dtype_of, dense_init, embed_init, rms_norm, stack_layer_params)
from repro.sharding import constrain, BATCH


@dataclass(frozen=True)
class Model:
    """Uniform model API used by tests, the launcher and the engine."""
    cfg: ModelConfig
    init: Callable                 # (key) -> params
    param_spec: Callable           # () -> pytree of PartitionSpec
    forward: Callable              # (params, batch, plan=None) -> logits
    prefill: Callable              # (params, batch) -> (logits, cache)
    decode_step: Callable          # (params, tokens, cache, plan) -> (logits, cache)
    init_cache: Callable           # (batch, seq_len) -> cache
    cache_spec: Callable           # (batch, seq_len) -> pytree of PartitionSpec


# ----------------------------------------------------------------- init ----

def init_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": blocks.init_ffn_block(k2, cfg, dtype),
    }


def layer_spec(cfg: ModelConfig):
    return {"ln1": P(None), "attn": blocks.attn_spec(cfg),
            "ln2": P(None), "ffn": blocks.ffn_block_spec(cfg)}


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": stack_layer_params(kl, cfg.num_layers,
                                     lambda k: init_layer(k, cfg, dtype)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_padded), dtype)
    return params


def params_spec(cfg: ModelConfig):
    ls = jax.tree.map(lambda s: P(None, *s), layer_spec(cfg),
                      is_leaf=lambda s: isinstance(s, P))
    spec = {"embed": P("model", None), "out_norm": P(None), "layers": ls}
    if not cfg.tie_embeddings:
        spec["lm_head"] = P(None, "model")
    return spec


# -------------------------------------------------------------- forward ----

def embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, P(BATCH, None, None)).astype(dtype_of(cfg.compute_dtype))


def lm_logits(params, cfg, x):
    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.vocab_padded != cfg.vocab_size:
        # mask the padding classes (vocab padded for shardability)
        invalid = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(invalid, jnp.asarray(-1e30, logits.dtype), logits)
    return constrain(logits, P(BATCH, None, "model"))


def forward_from_embeds(params, cfg: ModelConfig, x, angles, *,
                        window=0, plan=None, collect_kv=False):
    """Scan the layer stack over full-sequence embeddings."""

    def body(h, lp):
        a, kv = blocks.attn_full(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cfg, angles, causal=True, window=window)
        h = h + a
        f = blocks.apply_ffn_block(lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                                   cfg, plan)
        h = h + f
        return h, (kv if collect_kv else None)

    x, kvs = blocks.scan_layers(body, x, params["layers"], remat=cfg.remat)
    return x, kvs


def make_forward(cfg: ModelConfig, angles_fn=None, embed_fn=None):
    dh_half = cfg.d_head // 2

    def forward(params, batch, plan: Optional[HybridPlan] = None):
        x = (embed_fn(params, cfg, batch) if embed_fn
             else embed_tokens(params, cfg, batch["tokens"]))
        S = x.shape[1]
        angles = (angles_fn(batch, S) if angles_fn
                  else rope_angles(jnp.arange(S), dh_half, cfg.rope_theta))
        x, _ = forward_from_embeds(params, cfg, x, angles,
                                   window=cfg.sliding_window, plan=plan)
        return lm_logits(params, cfg, x)

    return forward


# -------------------------------------------------------- prefill/decode ----

def make_cache_fns(cfg: ModelConfig):
    kv, dh = cfg.num_kv_heads, cfg.d_head
    W = cfg.sliding_window

    def init_cache(batch, seq_len, dtype=None):
        dtype = dtype or dtype_of(cfg.param_dtype)
        if W and W < seq_len:
            return init_ring_cache(cfg.num_layers, batch, W, kv, dh, dtype)
        return init_full_cache(cfg.num_layers, batch, seq_len, kv, dh, dtype)

    def cache_spec(batch=None, seq_len=None):
        # k/v: (L, B, T, KV, dh) — batch over data, cache seq over model.
        return {"k": P(None, BATCH, "model", None, None),
                "v": P(None, BATCH, "model", None, None),
                "kv_pos": P(BATCH, "model"),
                "length": P(BATCH)}

    return init_cache, cache_spec


def make_prefill(cfg: ModelConfig, forward_embed=None, angles_fn=None):
    dh_half = cfg.d_head // 2
    init_cache, _ = make_cache_fns(cfg)
    W = cfg.sliding_window

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = (angles_fn(batch, S) if angles_fn
                  else rope_angles(jnp.arange(S), dh_half, cfg.rope_theta))
        x, kvs = forward_from_embeds(params, cfg, x, angles,
                                     window=W, plan=None, collect_kv=True)
        k, v = kvs                                     # (L, B, S, KV, dh)
        if W and W < S:
            assert S % W == 0, "prefill length must align the ring window"
            k, v = k[:, :, S - W:], v[:, :, S - W:]
            kv_pos = jnp.broadcast_to(jnp.arange(S - W, S), (B, W)).astype(jnp.int32)
        else:
            T = max_len or S
            pad = T - S
            if pad:
                zeros = jnp.zeros(k.shape[:2] + (pad,) + k.shape[3:], k.dtype)
                k = jnp.concatenate([k, zeros], axis=2)
                v = jnp.concatenate([v, zeros], axis=2)
            kv_pos = jnp.where(jnp.arange(T) < S, jnp.arange(T), -1)
            kv_pos = jnp.broadcast_to(kv_pos, (B, T)).astype(jnp.int32)
        cache = {"k": k, "v": v, "kv_pos": kv_pos,
                 "length": jnp.full((B,), S, jnp.int32)}
        return lm_logits(params, cfg, x[:, -1:]), cache

    return prefill


def make_decode_step(cfg: ModelConfig, angles_decode_fn=None,
                     collect_indices: bool = False):
    """collect_indices=True additionally returns the per-layer selected
    cold cluster ids (L, G, kc) — the real activation trace consumed by
    the serving engine's neuron cache / cold store / pipeline."""
    dh_half = cfg.d_head // 2
    W = cfg.sliding_window

    def decode_step(params, tokens, cache, plan: Optional[HybridPlan] = None,
                    active_mask=None):
        """tokens (B,1) -> (logits (B,1,V), cache'[, cluster_ids]).

        active_mask (B,) bool: live rows for the sparse-FFN batch-union
        selection; None = all rows live (the static-batch path)."""
        pos = cache["length"]                          # (B,)
        x = embed_tokens(params, cfg, tokens)
        angles = (angles_decode_fn(pos, dh_half) if angles_decode_fn
                  else rope_angles(pos[:, None], dh_half, cfg.rope_theta))
        kv_pos = write_pos(cache["kv_pos"], pos)

        def body(h, xs):
            lp, kc, vc = xs
            a, kc, vc = blocks.attn_decode(
                lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, angles,
                kc, vc, kv_pos, pos, window=W)
            h = h + a
            f = blocks.apply_ffn_block(
                lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg, plan,
                return_indices=collect_indices, active_mask=active_mask)
            if collect_indices:
                f, cidx = f
            h = h + f
            return h, ((kc, vc, cidx) if collect_indices else (kc, vc))

        x, ys = blocks.scan_over(body, x, (params["layers"],
                                           cache["k"], cache["v"]))
        if collect_indices:
            k, v, cidx = ys
        else:
            k, v = ys
            cidx = None
        new_cache = dict(cache, k=k, v=v, kv_pos=kv_pos, length=pos + 1)
        logits = lm_logits(params, cfg, x)
        if collect_indices:
            return logits, new_cache, cidx
        return logits, new_cache

    return decode_step


def make_model(cfg: ModelConfig) -> Model:
    init_cache, cache_spec = make_cache_fns(cfg)
    return Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        param_spec=lambda: params_spec(cfg),
        forward=make_forward(cfg),
        prefill=make_prefill(cfg),
        decode_step=make_decode_step(cfg),
        init_cache=init_cache,
        cache_spec=cache_spec,
    )
