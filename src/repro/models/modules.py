"""Pure-JAX parameter pytree helpers (no flax dependency).

Parameters are nested dicts of jnp arrays. Each model provides
`init(key) -> params` and a parallel `param_spec() -> pytree of
PartitionSpec` used by the launcher for pjit shardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal init with 1/sqrt(fan_in) scale (last-but-one dim)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def stack_layer_params(key, n_layers: int, init_one):
    """Initialize n_layers layers with vmapped init -> leaves (L, ...)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), params)


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name!r}")
