"""build_model(config) — family dispatcher for the uniform Model API."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.dense import Model


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "dense":
        from repro.models import dense as fam
    elif cfg.family == "moe":
        from repro.models import moe as fam
    elif cfg.family == "ssm":
        from repro.models import ssm as fam
    elif cfg.family == "hybrid":
        from repro.models import rglru as fam
    elif cfg.family == "encdec":
        from repro.models import encdec as fam
    elif cfg.family == "vlm":
        from repro.models import vlm as fam
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return fam.make_model(cfg)
