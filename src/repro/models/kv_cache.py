"""Decode-time state caches.

Three kinds, all pure pytrees so they thread through jit / scan:
  * full KV cache     — (B, S_max, KV, dh) buffers, append at `length`.
  * ring KV cache     — (B, W, KV, dh) sliding-window buffers (slot = pos % W)
                        with explicit per-slot absolute positions.
  * recurrent state   — SSM / RG-LRU states + causal-conv tails.

`kv_pos` is materialized for both cache kinds so decode_attention masks
uniformly (-1 = empty slot).

`KVSlotArena` (DESIGN.md §6) wraps the full cache as a fixed-slot arena
for continuous batching: requests are admitted into free slots and
freed on completion without reshaping live rows; the arena only changes
shape at decoder bucket boundaries. A replica-routed engine
(DESIGN.md §5) owns one arena per 'data'-axis replica, each placed on
that replica's (1, n_model) submesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def init_full_cache(n_layers, batch, s_max, kv_heads, d_head, dtype):
    return {
        "k": jnp.zeros((n_layers, batch, s_max, kv_heads, d_head), dtype),
        "v": jnp.zeros((n_layers, batch, s_max, kv_heads, d_head), dtype),
        "kv_pos": jnp.full((batch, s_max), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def init_ring_cache(n_layers, batch, window, kv_heads, d_head, dtype):
    return {
        "k": jnp.zeros((n_layers, batch, window, kv_heads, d_head), dtype),
        "v": jnp.zeros((n_layers, batch, window, kv_heads, d_head), dtype),
        "kv_pos": jnp.full((batch, window), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def cache_slot(cache_k_layer, pos):
    """Write slot index for each batch element. pos (B,)."""
    T = cache_k_layer.shape[1]
    return pos % T            # full cache: pos < S_max so identity


def write_kv(k_layer, v_layer, k_new, v_new, pos):
    """Insert one token per batch row at slot pos % T (vmapped)."""
    T = k_layer.shape[1]
    slot = pos % T

    def upd(buf, new, s):
        # buf (T,KV,dh), new (1,KV,dh)
        return jax.lax.dynamic_update_slice(buf, new, (s, 0, 0))

    k_layer = jax.vmap(upd)(k_layer, k_new, slot)
    v_layer = jax.vmap(upd)(v_layer, v_new, slot)
    return k_layer, v_layer


def write_pos(kv_pos, pos):
    """Update per-slot absolute positions after inserting token at `pos`."""
    T = kv_pos.shape[1]
    slot = pos % T

    def upd(row, s, p):
        return jax.lax.dynamic_update_slice(row, p[None], (s,))

    return jax.vmap(upd)(kv_pos, slot, pos)


# ------------------------------------------------------- slot arena ----

@jax.jit
def _write_row(cache, row, slot):
    """Overwrite arena slot `slot` with a single-request cache row.

    row: full-cache pytree with batch dim 1 and the arena's seq length.
    `slot` is a traced scalar, so one executable serves every slot.
    """
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], row["k"],
                                          (0, slot, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], row["v"],
                                          (0, slot, 0, 0, 0)),
        "kv_pos": jax.lax.dynamic_update_slice(cache["kv_pos"],
                                               row["kv_pos"], (slot, 0)),
        "length": jax.lax.dynamic_update_slice(cache["length"],
                                               row["length"], (slot,)),
    }


class KVSlotArena:
    """Fixed-slot KV arena with a free list (continuous batching).

    Physical layout is the ordinary full cache — (L, n_slots, T, KV,
    dh) buffers — but rows are *slots* owned by live requests. Admitting
    a request writes its prefilled KV into a free slot (one
    dynamic_update_slice; live rows untouched); completion just returns
    the slot to the free list. Freed slots keep decoding as masked
    "zombie" lanes whose outputs are ignored, so the decode executable
    shape never changes inside a bucket. `resize` — the only operation
    that reshapes the buffers — is invoked by the engine solely at
    decoder bucket-boundary crossings.
    """

    def __init__(self, n_layers, n_slots, max_len, kv_heads, d_head, dtype,
                 mesh=None):
        self.dims = (n_layers, kv_heads, d_head)
        self.max_len = max_len
        self.dtype = dtype
        self.mesh = mesh
        self.cache = self._shard(init_full_cache(
            n_layers, n_slots, max_len, kv_heads, d_head, dtype))
        self.free = list(range(n_slots))
        self.slot_of: dict = {}          # uid -> slot
        self.writes = 0
        self.resizes = 0

    def _shard(self, cache):
        """Place the arena on the mesh, KV heads over 'model' (the
        tensor-parallel head axis; per-device KV memory shrinks 1/n).
        Non-dividing head counts fall back to replication via
        _filter_spec, so any mesh is safe."""
        if self.mesh is None:
            return cache
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding import _filter_spec
        spec = {"k": P(None, None, None, "model", None),
                "v": P(None, None, None, "model", None),
                "kv_pos": P(None, None), "length": P(None)}
        return {
            k: jax.device_put(v, NamedSharding(
                self.mesh, _filter_spec(spec[k], self.mesh, shape=v.shape)))
            for k, v in cache.items()}

    @property
    def n_slots(self) -> int:
        return self.cache["k"].shape[1]

    @property
    def n_free(self) -> int:
        return len(self.free)

    def alloc(self, uid) -> int:
        if not self.free:
            raise RuntimeError(
                f"KV arena exhausted: {len(self.slot_of)} live requests "
                f"hold all {self.n_slots} slots (admission must stay "
                f"within the decoder bucket)")
        if uid in self.slot_of:
            raise ValueError(f"request {uid} already owns slot "
                             f"{self.slot_of[uid]}")
        slot = self.free.pop(0)
        self.slot_of[uid] = slot
        return slot

    def release(self, uid) -> int:
        slot = self.slot_of.pop(uid)
        self.free.append(slot)
        self.free.sort()
        return slot

    def write(self, uid, row_cache):
        """Install a prefilled request (batch-1 cache row) in uid's slot."""
        slot = self.slot_of[uid]
        self.cache = _write_row(self.cache, row_cache, jnp.int32(slot))
        self.writes += 1
        return slot

    def rows_for(self, uids):
        return [self.slot_of[u] for u in uids]

    def resize(self, new_n_slots: int, uid_order):
        """Gather live rows (in uid_order) into a new arena of
        `new_n_slots` slots; live requests are renumbered 0..k-1."""
        rows = [self.slot_of[u] for u in uid_order]
        k_live = len(rows)
        assert k_live <= new_n_slots, (k_live, new_n_slots)
        nl, kv, dh = self.dims
        new = init_full_cache(nl, new_n_slots, self.max_len, kv, dh,
                              self.dtype)
        if k_live:
            idx = jnp.asarray(rows, jnp.int32)
            pad = new_n_slots - k_live
            gat = {
                "k": self.cache["k"].take(idx, axis=1),
                "v": self.cache["v"].take(idx, axis=1),
                "kv_pos": self.cache["kv_pos"].take(idx, axis=0),
                "length": self.cache["length"].take(idx, axis=0),
            }
            if pad:
                new = {
                    "k": jnp.concatenate([gat["k"], new["k"][:, k_live:]], 1),
                    "v": jnp.concatenate([gat["v"], new["v"][:, k_live:]], 1),
                    "kv_pos": jnp.concatenate(
                        [gat["kv_pos"], new["kv_pos"][k_live:]], 0),
                    "length": jnp.concatenate(
                        [gat["length"], new["length"][k_live:]], 0),
                }
            else:
                new = gat
        self.cache = self._shard(new)
        self.slot_of = {u: i for i, u in enumerate(uid_order)}
        self.free = list(range(k_live, new_n_slots))
        self.resizes += 1
