"""Decode-time state caches.

Three kinds, all pure pytrees so they thread through jit / scan:
  * full KV cache     — (B, S_max, KV, dh) buffers, append at `length`.
  * ring KV cache     — (B, W, KV, dh) sliding-window buffers (slot = pos % W)
                        with explicit per-slot absolute positions.
  * recurrent state   — SSM / RG-LRU states + causal-conv tails.

`kv_pos` is materialized for both cache kinds so decode_attention masks
uniformly (-1 = empty slot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_full_cache(n_layers, batch, s_max, kv_heads, d_head, dtype):
    return {
        "k": jnp.zeros((n_layers, batch, s_max, kv_heads, d_head), dtype),
        "v": jnp.zeros((n_layers, batch, s_max, kv_heads, d_head), dtype),
        "kv_pos": jnp.full((batch, s_max), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def init_ring_cache(n_layers, batch, window, kv_heads, d_head, dtype):
    return {
        "k": jnp.zeros((n_layers, batch, window, kv_heads, d_head), dtype),
        "v": jnp.zeros((n_layers, batch, window, kv_heads, d_head), dtype),
        "kv_pos": jnp.full((batch, window), -1, jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def cache_slot(cache_k_layer, pos):
    """Write slot index for each batch element. pos (B,)."""
    T = cache_k_layer.shape[1]
    return pos % T            # full cache: pos < S_max so identity


def write_kv(k_layer, v_layer, k_new, v_new, pos):
    """Insert one token per batch row at slot pos % T (vmapped)."""
    T = k_layer.shape[1]
    slot = pos % T

    def upd(buf, new, s):
        # buf (T,KV,dh), new (1,KV,dh)
        return jax.lax.dynamic_update_slice(buf, new, (s, 0, 0))

    k_layer = jax.vmap(upd)(k_layer, k_new, slot)
    v_layer = jax.vmap(upd)(v_layer, v_new, slot)
    return k_layer, v_layer


def write_pos(kv_pos, pos):
    """Update per-slot absolute positions after inserting token at `pos`."""
    T = kv_pos.shape[1]
    slot = pos % T

    def upd(row, s, p):
        return jax.lax.dynamic_update_slice(row, p[None], (s,))

    return jax.vmap(upd)(kv_pos, slot, pos)
