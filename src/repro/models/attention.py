"""Attention substrate: RoPE / M-RoPE, GQA, qk-norm, sliding window,
pure-JAX flash attention (chunked online softmax) and decode-step attention.

Shapes: q (B, Sq, H, dh); k/v (B, Skv, KV, dh); GQA groups G = H // KV.
RoPE is applied *before* caching, so cached K carries absolute positions.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.modules import rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------- RoPE ----

def rope_angles(positions, d_half: int, theta: float):
    """positions (..., S) -> angles (..., S, d_half)."""
    inv = 1.0 / (theta ** (jnp.arange(d_half, dtype=jnp.float32) / d_half))
    return positions[..., None].astype(jnp.float32) * inv


def mrope_angles(positions3, sections, theta: float):
    """M-RoPE (Qwen2-VL, arXiv:2409.12191).

    positions3: (3, B, S) — temporal / height / width position streams.
    sections: split of d_half, e.g. (16, 24, 24). Each section s_i uses
    position stream i with its own slice of the inverse-frequency bank.
    Returns angles (B, S, d_half).
    """
    d_half = sum(sections)
    inv = 1.0 / (theta ** (jnp.arange(d_half, dtype=jnp.float32) / d_half))
    chunks = []
    off = 0
    for i, sec in enumerate(sections):
        p = positions3[i]                                  # (B, S)
        chunks.append(p[..., None].astype(jnp.float32) * inv[off:off + sec])
        off += sec
    return jnp.concatenate(chunks, axis=-1)


def apply_rotary(x, angles):
    """x (B, S, H, dh), angles (B, S, dh//2) or (S, dh//2)."""
    dt = x.dtype
    d_half = x.shape[-1] // 2
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]                    # (B, S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :d_half].astype(jnp.float32), x[..., d_half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(dt)


# ------------------------------------------------------------ qk-norm ----

def maybe_qk_norm(q, k, params, eps):
    """Per-head RMS norm on q and k (Qwen3 style) if weights present."""
    if params is None:
        return q, k
    return (rms_norm(q, params["q_norm"], eps),
            rms_norm(k, params["k_norm"], eps))


# ----------------------------------------------- flash attention (jnp) ----

def _block_mask(qpos, kpos, *, causal: bool, window: int):
    """qpos (qb,), kpos (kb,) absolute positions -> (qb, kb) bool mask."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


# Cost-probe override (launch/dryrun --probe): force single-chunk flash
# so no while loop hides FLOPs from XLA's cost analysis. Never set in
# production paths — single-chunk materializes the (Sq, Skv) scores.
FLASH_FULL_BLOCKS = False


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    q_block=512, kv_block=1024):
    """Chunked online-softmax attention, fp32 accumulators.

    Never materializes the (Sq, Skv) score matrix: scans q chunks
    (outer) and kv chunks (inner), carrying (m, l, acc). `q_offset` is
    the absolute position of q[0] relative to k[0] (0 for self-attn
    over the same sequence).
    """
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if FLASH_FULL_BLOCKS:
        q_block, kv_block = Sq, Skv
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    assert Sq % qb == 0 and Skv % kb == 0, (Sq, qb, Skv, kb)
    nq, nk = Sq // qb, Skv // kb
    scale = dh ** -0.5

    qr = q.reshape(B, nq, qb, KV, G, dh)
    kr = k.reshape(B, nk, kb, KV, dh)
    vr = v.reshape(B, nk, kb, KV, dh)

    def q_chunk(carry, inputs):
        i, qc = inputs                                     # qc (B,qb,KV,G,dh)
        qpos = q_offset + i * qb + jnp.arange(qb)

        def kv_chunk(state, inputs):
            j, kc, vc = inputs
            m_prev, l_prev, acc = state
            kpos = j * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(qpos, kpos, causal=causal, window=window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            # NOTE (§Perf): converting the v-chunk up (kb x dh) is
            # cheaper than converting p down (qb x kb) when qb > dh —
            # the opposite trade from decode_attention.
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p, vc.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_chunk, (m0, l0, a0),
            (jnp.arange(nk), kr.swapaxes(0, 1), vr.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,KV,G,qb,dh)
        return carry, out.transpose(0, 3, 1, 2, 4)         # (B,qb,KV,G,dh)

    _, outs = jax.lax.scan(q_chunk, None,
                           (jnp.arange(nq), qr.swapaxes(0, 1)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


# ------------------------------------------------------ decode (Sq=1) ----

def decode_attention(q, k_cache, v_cache, kv_pos, pos, *, window=0):
    """One-token attention against a cache.

    q: (B, 1, H, dh) (RoPE already applied at `pos`).
    k_cache/v_cache: (B, T, KV, dh) — full buffer or ring buffer.
    kv_pos: (B, T) absolute position of each slot, -1 = empty.
    pos: (B,) current absolute position of the query token.
    """
    B, _, H, dh = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qr = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window:
        valid &= (pos[:, None] - kv_pos) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # PV in the cache dtype with fp32 accumulation: casting p *down*
    # (scores-sized) instead of V *up* (cache-sized) halves the decode
    # memory traffic (§Perf iteration 1 — the convert was the top
    # bytes-accessed op in the lowered HLO).
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, dh).astype(q.dtype)
