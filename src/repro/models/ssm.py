"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Attention-free, FFN-free (d_ff=0): the PowerInfer-2 hot/cold FFN
technique is inapplicable here (DESIGN.md §Arch-applicability); the
arch is implemented without it, as the brief requires.

Train/prefill use the chunked SSD algorithm (block-diagonal intra-chunk
"attention" + low-rank inter-chunk recurrence); decode is the O(1)
recurrent update h' = exp(dt*A) h + dt*B x, y = C h + D x.

Projections are stored separately (wz/wx/wB/wC/wdt) so the inner
(d_inner) dim shards cleanly over the mesh 'model' axis; B/C (state dim)
are replicated — the scan stays collective-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks, dense
from repro.models.modules import (
    dtype_of, dense_init, embed_init, rms_norm, stack_layer_params)
from repro.sharding import constrain, BATCH


# ------------------------------------------------------------ SSD core ----

def segsum(x):
    """x (..., l) -> lower-triangular pairwise segment sums (..., l, l)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(X, A, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.

    X: (b, s, h, p) inputs (already dt-scaled);  A: (b, s, h) log-decay
    per step (dt * A);  B, C: (b, s, n) shared across heads (n_groups=1).
    Returns (Y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = X.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    Xc = X.reshape(b, c, chunk, h, p)
    # decay accumulations in fp32 (bf16 cumsum over long chunks drifts)
    Ac = A.reshape(b, c, chunk, h).transpose(0, 3, 1, 2).astype(jnp.float32)
    Bc = B.reshape(b, c, chunk, n)
    Cc = C.reshape(b, c, chunk, n)
    A_cum = jnp.cumsum(Ac, axis=-1)                         # (b,h,c,l)

    # 1. intra-chunk (block-diagonal) term
    L = jnp.exp(segsum(Ac))                                 # (b,h,c,l,l)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, Xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)         # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, Xc)

    # 3. inter-chunk recurrence (include initial state as chunk -1)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), X.dtype)
    states = jnp.concatenate([init_state[:, None].transpose(0, 1, 2, 3, 4),
                              states], axis=1)              # (b,c+1,h,p,n)
    chunk_decay = A_cum[..., -1]                            # (b,h,c)
    dec = jnp.exp(segsum(jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))))
    # dec (b,h,c+1,c+1): weight of chunk-z state at chunk-c boundary
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dec, states)
    prev_states = new_states[:, :-1]                        # (b,c,h,p,n)
    final_state = new_states[:, -1]

    # 4. state -> output within each chunk
    state_decay = jnp.exp(A_cum)                            # (b,h,c,l)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    Y = (Y_diag + Y_off).reshape(b, s, h, p).astype(X.dtype)
    return Y, final_state.astype(X.dtype)


def ssd_step(state, x, dA, dt, B, C):
    """One recurrent step. state (b,h,p,n); x (b,h,p); dA (b,h) = dt*A;
    dt (b,h); B, C (b,n). Returns (state', y (b,h,p))."""
    decay = jnp.exp(dA)[..., None, None]                    # (b,h,1,1)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dt, x, B)
    state = state * decay + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, C)
    return state, y


# --------------------------------------------------------- conv helper ----

def causal_conv(x, w, b, tail=None):
    """Depthwise causal conv. x (B,S,C), w (W,C), b (C,).

    tail (B,W-1,C) carries state across steps; returns (y, new_tail).
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    # (B, S, C) windows: sum_w xp[:, i+w] * w[w]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_tail = xp[:, -(W - 1):]
    return jax.nn.silu(y + b), new_tail


# ----------------------------------------------------------- the model ----

def init_layer(key, cfg: ModelConfig, dtype):
    d, di, n, h = (cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state,
                   cfg.ssm_heads)
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((d,), dtype),
        "wz": dense_init(ks[0], (d, di), dtype),
        "wx": dense_init(ks[1], (d, di), dtype),
        "wB": dense_init(ks[2], (d, n), dtype),
        "wC": dense_init(ks[3], (d, n), dtype),
        "wdt": dense_init(ks[4], (d, h), dtype),
        "conv_w": dense_init(ks[5], (W, di + 2 * n), dtype, scale=0.5),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),              # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),       # softplus ~ 0.12
        "gn": jnp.zeros((di,), dtype),
        "wo": dense_init(ks[6], (di, d), dtype),
    }


def layer_spec(cfg: ModelConfig):
    return {
        "ln": P(None),
        "wz": P(None, "model"), "wx": P(None, "model"),
        "wB": P(None, None), "wC": P(None, None), "wdt": P(None, None),
        "conv_w": P(None, None), "conv_b": P(None),
        "A_log": P(None), "D": P(None), "dt_bias": P(None),
        "gn": P("model"), "wo": P("model", None),
    }


def _proj(lp, x, cfg):
    """x (B,S,D) -> z, xin, B, C, dt (pre-conv)."""
    z = jnp.einsum("bsd,de->bse", x, lp["wz"])
    xin = jnp.einsum("bsd,de->bse", x, lp["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, lp["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, lp["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, lp["wdt"]).astype(jnp.float32)
        + lp["dt_bias"])
    return z, xin, Bm, Cm, dt


def _layer_full(lp, x, cfg: ModelConfig, init_state=None):
    """Full-sequence mamba2 block. Returns (out, (final_state, conv_tail))."""
    b, s, d = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xi = rms_norm(x, lp["ln"], cfg.norm_eps)
    z, xin, Bm, Cm, dt = _proj(lp, xi, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, tail = causal_conv(conv_in, lp["conv_w"], lp["conv_b"])
    di = cfg.ssm_d_inner
    xin, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + n],
                   conv_out[..., di + n:])
    A = -jnp.exp(lp["A_log"])                               # (h,)
    Xh = (xin.reshape(b, s, h, p)
          * dt[..., None].astype(xin.dtype))                # dt-scaled input
    Ah = (dt * A).astype(xin.dtype)                         # (b,s,h)
    Y, fstate = ssd_chunked(Xh, Ah, Bm, Cm, min(cfg.ssm_chunk, s),
                            init_state)
    Y = Y + lp["D"].astype(Y.dtype)[None, None, :, None] \
        * xin.reshape(b, s, h, p)
    y = Y.reshape(b, s, di) * jax.nn.silu(z)
    y = rms_norm(y, lp["gn"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, lp["wo"])
    return x + constrain(out, P(BATCH, None, None)), (fstate, tail)


def _layer_step(lp, x, cfg: ModelConfig, state, tail):
    """One-token mamba2 step. x (B,1,D)."""
    b = x.shape[0]
    state_dtype = state.dtype
    h, p, n, di = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_d_inner
    xi = rms_norm(x, lp["ln"], cfg.norm_eps)
    z, xin, Bm, Cm, dt = _proj(lp, xi, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, tail = causal_conv(conv_in, lp["conv_w"], lp["conv_b"], tail)
    xin, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + n],
                   conv_out[..., di + n:])
    A = -jnp.exp(lp["A_log"])
    dt1 = dt[:, 0]                                          # (b,h)
    state, yh = ssd_step(state.astype(jnp.float32),
                         xin[:, 0].reshape(b, h, p), dt1 * A,
                         dt1.astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32),
                         Cm[:, 0].astype(jnp.float32))
    yh = yh + lp["D"].astype(yh.dtype)[None, :, None] \
        * xin[:, 0].reshape(b, h, p).astype(jnp.float32)
    y = (yh.reshape(b, 1, di)).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, lp["gn"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, lp["wo"])
    return (x + out).astype(x.dtype), (state.astype(state_dtype), tail)


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    ke, kl = jax.random.split(key)
    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": stack_layer_params(kl, cfg.num_layers,
                                     lambda k: init_layer(k, cfg, dtype)),
    }


def params_spec(cfg: ModelConfig):
    ls = jax.tree.map(lambda s: P(None, *s), layer_spec(cfg),
                      is_leaf=lambda s: isinstance(s, P))
    return {"embed": P("model", None), "out_norm": P(None), "layers": ls}


def make_model(cfg: ModelConfig) -> dense.Model:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di, W = cfg.ssm_d_inner, cfg.ssm_conv_width

    def init_cache(batch, seq_len=0, dtype=None):
        dtype = dtype or dtype_of(cfg.param_dtype)
        return {
            "ssm": jnp.zeros((cfg.num_layers, batch, h, p, n), dtype),
            "conv": jnp.zeros((cfg.num_layers, batch, W - 1, di + 2 * n), dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def cache_spec(batch=None, seq_len=None):
        return {"ssm": P(None, BATCH, "model", None, None),
                "conv": P(None, BATCH, None, "model"),
                "length": P(BATCH)}

    def forward(params, batch, plan=None):
        x = dense.embed_tokens(params, cfg, batch["tokens"])

        def body(hh, lp):
            hh, _ = _layer_full(lp, hh, cfg)
            return hh, None

        x, _ = blocks.scan_layers(body, x, params["layers"], remat=cfg.remat)
        return dense.lm_logits(params, cfg, x)

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = dense.embed_tokens(params, cfg, tokens)

        def body(hh, lp):
            hh, st = _layer_full(lp, hh, cfg)
            return hh, st

        x, (states, tails) = blocks.scan_layers(body, x, params["layers"],
                                                remat=cfg.remat)
        cache = {"ssm": states, "conv": tails,
                 "length": jnp.full((B,), S, jnp.int32)}
        return dense.lm_logits(params, cfg, x[:, -1:]), cache

    def decode_step(params, tokens, cache, plan=None):
        x = dense.embed_tokens(params, cfg, tokens)

        def body(hh, xs):
            lp, st, tl = xs
            hh, (st, tl) = _layer_step(lp, hh, cfg, st, tl)
            return hh, (st, tl)

        x, (states, tails) = blocks.scan_over(
            body, x, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache = dict(cache, ssm=states, conv=tails,
                         length=cache["length"] + 1)
        return dense.lm_logits(params, cfg, x), new_cache

    return dense.Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        param_spec=lambda: params_spec(cfg),
        forward=forward,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_spec=cache_spec,
    )
