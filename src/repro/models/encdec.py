"""Encoder-decoder audio backbone (SeamlessM4T-v2, arXiv:2308.11596).

Transformer backbone only: the mel-spectrogram + conformer codec
frontend is a stub — `input_specs()`/batches supply precomputed frame
embeddings (B, num_frames, d_model). RoPE replaces Seamless's learned
positions (TPU-friendly; recorded in DESIGN.md §2).

Decoder layers: causal self-attention (cached at decode) + cross
attention to the encoder memory (K/V precomputed once at prefill) +
FFN carrying the PowerInfer-2 hybrid technique.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks, dense
from repro.models.attention import rope_angles
from repro.models.kv_cache import write_pos
from repro.models.modules import (
    dtype_of, dense_init, embed_init, rms_norm, stack_layer_params)
from repro.sharding import BATCH


def init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": blocks.init_ffn_block(k2, cfg, dtype),
    }


def init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "lnx": jnp.zeros((cfg.d_model,), dtype),
        "xattn": blocks.init_attn(k2, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": blocks.init_ffn_block(k3, cfg, dtype),
    }


def enc_layer_spec(cfg):
    return {"ln1": P(None), "attn": blocks.attn_spec(cfg),
            "ln2": P(None), "ffn": blocks.ffn_block_spec(cfg)}


def dec_layer_spec(cfg):
    return {"ln1": P(None), "attn": blocks.attn_spec(cfg),
            "lnx": P(None), "xattn": blocks.attn_spec(cfg),
            "ln2": P(None), "ffn": blocks.ffn_block_spec(cfg)}


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
        "enc_layers": stack_layer_params(
            kenc, cfg.num_encoder_layers,
            lambda k: init_enc_layer(k, cfg, dtype)),
        "dec_layers": stack_layer_params(
            kdec, cfg.num_layers, lambda k: init_dec_layer(k, cfg, dtype)),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_padded), dtype),
    }


def params_spec(cfg: ModelConfig):
    enc = jax.tree.map(lambda s: P(None, *s), enc_layer_spec(cfg),
                       is_leaf=lambda s: isinstance(s, P))
    dec = jax.tree.map(lambda s: P(None, *s), dec_layer_spec(cfg),
                       is_leaf=lambda s: isinstance(s, P))
    return {"embed": P("model", None), "enc_norm": P(None),
            "out_norm": P(None), "enc_layers": enc, "dec_layers": dec,
            "lm_head": P(None, "model")}


def encode(params, cfg: ModelConfig, frames):
    """frames (B, F, D) stub embeddings -> encoder memory (B, F, D)."""
    x = frames.astype(dtype_of(cfg.compute_dtype))
    F = x.shape[1]
    angles = rope_angles(jnp.arange(F), cfg.d_head // 2, cfg.rope_theta)

    def body(h, lp):
        a, _ = blocks.attn_full(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                                cfg, angles, causal=False)
        h = h + a
        f = blocks.apply_ffn_block(lp["ffn"],
                                   rms_norm(h, lp["ln2"], cfg.norm_eps),
                                   cfg, None)
        return h + f, None

    x, _ = blocks.scan_layers(body, x, params["enc_layers"], remat=cfg.remat)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def cross_memory(params, cfg, memory):
    """Precompute per-decoder-layer cross K/V from encoder memory."""
    B, F, _ = memory.shape
    kv, dh = cfg.num_kv_heads, cfg.d_head

    def body(_, lp):
        k = jnp.einsum("bfd,de->bfe", memory, lp["xattn"]["wk"]).reshape(
            B, F, kv, dh)
        v = jnp.einsum("bfd,de->bfe", memory, lp["xattn"]["wv"]).reshape(
            B, F, kv, dh)
        return None, (k, v)

    _, (mk, mv) = blocks.scan_over(body, None, params["dec_layers"])
    return mk, mv                                          # (L,B,F,KV,dh)


def _dec_layer_full(lp, x, cfg, angles, mem_k, mem_v, plan):
    a, kv = blocks.attn_full(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                             cfg, angles, causal=True,
                             window=cfg.sliding_window)
    x = x + a
    c = blocks.cross_attn(lp["xattn"], rms_norm(x, lp["lnx"], cfg.norm_eps),
                          mem_k, mem_v, cfg)
    x = x + c
    f = blocks.apply_ffn_block(lp["ffn"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                               cfg, plan)
    return x + f, kv


def make_model(cfg: ModelConfig) -> dense.Model:
    dh_half = cfg.d_head // 2
    kv, dh = cfg.num_kv_heads, cfg.d_head
    W = cfg.sliding_window

    def forward(params, batch, plan=None):
        memory = encode(params, cfg, batch["frames"])
        tokens = batch["tokens"]
        x = dense.embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)
        mk, mv = cross_memory(params, cfg, memory)

        def body(h, xs):
            lp, k, v = xs
            h, _ = _dec_layer_full(lp, h, cfg, angles, k, v, plan)
            return h, None

        x, _ = blocks.scan_layers(body, x, params["dec_layers"], mk, mv,
                                  remat=cfg.remat)
        return dense.lm_logits(params, cfg, x)

    def prefill(params, batch, max_len=None):
        memory = encode(params, cfg, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = dense.embed_tokens(params, cfg, tokens)
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)
        mk, mv = cross_memory(params, cfg, memory)

        def body(h, xs):
            lp, k, v = xs
            h, kvp = _dec_layer_full(lp, h, cfg, angles, k, v, None)
            return h, kvp

        x, (k, v) = blocks.scan_layers(body, x, params["dec_layers"],
                                       mk, mv, remat=cfg.remat)
        if W and W < S:
            assert S % W == 0
            k, v = k[:, :, S - W:], v[:, :, S - W:]
            kv_pos = jnp.broadcast_to(jnp.arange(S - W, S), (B, W))
        else:
            T = max_len or S
            pad = T - S
            if pad:
                z = jnp.zeros(k.shape[:2] + (pad,) + k.shape[3:], k.dtype)
                k = jnp.concatenate([k, z], 2)
                v = jnp.concatenate([v, z], 2)
            kv_pos = jnp.broadcast_to(
                jnp.where(jnp.arange(T) < S, jnp.arange(T), -1), (B, T))
        cache = {"k": k, "v": v, "mem_k": mk, "mem_v": mv,
                 "kv_pos": kv_pos.astype(jnp.int32),
                 "length": jnp.full((B,), S, jnp.int32)}
        return dense.lm_logits(params, cfg, x[:, -1:]), cache

    def decode_step(params, tokens, cache, plan=None):
        pos = cache["length"]
        x = dense.embed_tokens(params, cfg, tokens)
        angles = rope_angles(pos[:, None], dh_half, cfg.rope_theta)
        kv_pos = write_pos(cache["kv_pos"], pos)

        def body(h, xs):
            lp, kc, vc, mk, mv = xs
            a, kc, vc = blocks.attn_decode(
                lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                angles, kc, vc, kv_pos, pos, window=W)
            h = h + a
            c = blocks.cross_attn(lp["xattn"],
                                  rms_norm(h, lp["lnx"], cfg.norm_eps),
                                  mk, mv, cfg)
            h = h + c
            f = blocks.apply_ffn_block(
                lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg, plan)
            return h + f, (kc, vc)

        x, (k, v) = blocks.scan_over(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["mem_k"], cache["mem_v"]))
        new_cache = dict(cache, k=k, v=v, kv_pos=kv_pos, length=pos + 1)
        return dense.lm_logits(params, cfg, x), new_cache

    def init_cache(batch, seq_len, dtype=None):
        dtype = dtype or dtype_of(cfg.param_dtype)
        T = min(W, seq_len) if W else seq_len
        L, F = cfg.num_layers, cfg.num_frames
        return {
            "k": jnp.zeros((L, batch, T, kv, dh), dtype),
            "v": jnp.zeros((L, batch, T, kv, dh), dtype),
            "mem_k": jnp.zeros((L, batch, F, kv, dh), dtype),
            "mem_v": jnp.zeros((L, batch, F, kv, dh), dtype),
            "kv_pos": jnp.full((batch, T), -1, jnp.int32),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def cache_spec(batch=None, seq_len=None):
        return {"k": P(None, BATCH, "model", None, None),
                "v": P(None, BATCH, "model", None, None),
                "mem_k": P(None, BATCH, None, "model", None),
                "mem_v": P(None, BATCH, None, "model", None),
                "kv_pos": P(BATCH, "model"), "length": P(BATCH)}

    return dense.Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        param_spec=lambda: params_spec(cfg),
        forward=forward,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_spec=cache_spec,
    )
