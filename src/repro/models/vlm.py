"""VLM backbone (Qwen2-VL, arXiv:2409.12191): M-RoPE + GQA decoder.

LM backbone only: the ViT/SigLIP vision tower + projector is a stub —
batches supply patch embeddings (B, P, d_model), which are interleaved
ahead of the text tokens. M-RoPE gives image patches 3D (t, h, w)
rotary positions on a sqrt(P) grid; text tokens use equal (t,h,w)
positions continuing after the image.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dense
from repro.models.attention import mrope_angles
from repro.models.modules import dtype_of


def build_positions(cfg: ModelConfig, batch_size: int, n_img: int,
                    n_text: int):
    """(3, B, S) M-RoPE position streams for [image ; text] layout."""
    grid = max(int(n_img ** 0.5), 1)
    idx = jnp.arange(n_img)
    t_img = jnp.zeros((n_img,), jnp.int32)
    h_img = (idx // grid).astype(jnp.int32)
    w_img = (idx % grid).astype(jnp.int32)
    start = grid  # text positions continue after the image grid extent
    t_txt = start + jnp.arange(n_text, dtype=jnp.int32)
    pos = jnp.stack([
        jnp.concatenate([t_img, t_txt]),
        jnp.concatenate([h_img, t_txt]),
        jnp.concatenate([w_img, t_txt]),
    ])                                                     # (3, S)
    return jnp.broadcast_to(pos[:, None], (3, batch_size, pos.shape[1]))


def make_model(cfg: ModelConfig) -> dense.Model:
    assert sum(cfg.mrope_sections) == cfg.d_head // 2, cfg.mrope_sections
    P_img = cfg.num_image_tokens

    def embed_fn(params, _cfg, batch):
        tok = dense.embed_tokens(params, cfg, batch["tokens"])
        img = batch["patch_embeds"].astype(dtype_of(cfg.compute_dtype))
        return jnp.concatenate([img, tok], axis=1)

    def angles_fn(batch, S):
        B = batch["tokens"].shape[0]
        n_text = S - P_img
        pos3 = build_positions(cfg, B, P_img, n_text)
        return mrope_angles(pos3, cfg.mrope_sections, cfg.rope_theta)

    def angles_decode_fn(pos, dh_half):
        # text token at cache index `pos` (counts image slots): its
        # M-RoPE position is grid + text_index, matching build_positions.
        grid = max(int(P_img ** 0.5), 1)
        p = pos - P_img + grid
        pos3 = jnp.broadcast_to(p[None, :, None], (3,) + p.shape + (1,))
        return mrope_angles(pos3, cfg.mrope_sections, cfg.rope_theta)

    base_forward = dense.make_forward(cfg, angles_fn=angles_fn,
                                      embed_fn=embed_fn)
    decode_step = dense.make_decode_step(cfg, angles_decode_fn=angles_decode_fn)
    init_cache, cache_spec = dense.make_cache_fns(cfg)

    def prefill(params, batch, max_len=None):
        # Reuse the dense prefill but with multimodal embeds + angles:
        # dense.make_prefill embeds tokens itself, so we wrap forward's
        # machinery directly here.
        tok = batch["tokens"]
        B = tok.shape[0]
        x = embed_fn(params, cfg, batch)
        S = x.shape[1]
        angles = angles_fn(batch, S)
        x, kvs = dense.forward_from_embeds(params, cfg, x, angles,
                                           window=cfg.sliding_window,
                                           plan=None, collect_kv=True)
        k, v = kvs
        W = cfg.sliding_window
        if W and W < S:
            assert S % W == 0
            k, v = k[:, :, S - W:], v[:, :, S - W:]
            kv_pos = jnp.broadcast_to(jnp.arange(S - W, S), (B, W))
        else:
            T = max_len or S
            pad = T - S
            if pad:
                z = jnp.zeros(k.shape[:2] + (pad,) + k.shape[3:], k.dtype)
                k = jnp.concatenate([k, z], 2)
                v = jnp.concatenate([v, z], 2)
            kv_pos = jnp.broadcast_to(
                jnp.where(jnp.arange(T) < S, jnp.arange(T), -1), (B, T))
        cache = {"k": k, "v": v, "kv_pos": kv_pos.astype(jnp.int32),
                 "length": jnp.full((B,), S, jnp.int32)}
        return dense.lm_logits(params, cfg, x[:, -1:]), cache

    return dense.Model(
        cfg=cfg,
        init=lambda key: dense.init_params(key, cfg),
        param_spec=lambda: dense.params_spec(cfg),
        forward=base_forward,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_spec=cache_spec,
    )
