"""Shared transformer building blocks: attention block (full + decode),
FFN block wiring (dense or PowerInfer-2 hybrid), layer-scan helpers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sparse_ffn import init_ffn, ffn_spec, ffn_apply
from repro.models.attention import (
    apply_rotary, decode_attention, flash_attention, maybe_qk_norm)
from repro.models.modules import dense_init
from repro.sharding import constrain, BATCH


# ------------------------------------------------------------ attention ----

def init_attn(key, cfg: ModelConfig, dtype, kv_heads=None, q_dim=None):
    h, dh = cfg.num_heads, cfg.d_head
    kv = kv_heads if kv_heads is not None else cfg.num_kv_heads
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, kv * dh), dtype),
        "wv": dense_init(ks[2], (d, kv * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qk_norm:
        p["qk"] = {"q_norm": jnp.zeros((dh,), dtype),
                   "k_norm": jnp.zeros((dh,), dtype)}
    return p


def attn_spec(cfg: ModelConfig):
    s = {"wq": P(None, "model"), "wk": P(None, "model"),
         "wv": P(None, "model"), "wo": P("model", None)}
    if cfg.qk_norm:
        s["qk"] = {"q_norm": P(None), "k_norm": P(None)}
    return s


def _qkv(p, x, cfg: ModelConfig, angles, k_angles=None):
    """Project + rope. x (B,S,D) -> q (B,S,H,dh), k/v (B,S,KV,dh)."""
    B, S, _ = x.shape
    h, dh = cfg.num_heads, cfg.d_head
    kv = p["wk"].shape[1] // dh
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, kv, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, kv, dh)
    q, k = maybe_qk_norm(q, k, p.get("qk"), cfg.norm_eps)
    if angles is not None:
        q = apply_rotary(q, angles)
        k = apply_rotary(k, k_angles if k_angles is not None else angles)
    q = constrain(q, P(BATCH, None, "model", None))
    k = constrain(k, P(BATCH, None, None, None))
    return q, k, v


def attn_full(p, x, cfg: ModelConfig, angles, *, causal=True, window=0):
    """Full-sequence self attention. Returns (out, (k, v)) for caching."""
    q, k, v = _qkv(p, x, cfg, angles)
    o = flash_attention(q, k, v, causal=causal, window=window)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return constrain(out, P(BATCH, None, None)), (k, v)


def attn_decode(p, x, cfg: ModelConfig, angles, k_cache, v_cache, kv_pos,
                pos, *, window=0):
    """One-token self attention vs cache. x (B,1,D); pos (B,) absolute.

    Writes the new token's k/v (RoPE pre-applied) into its slot, then
    attends over the updated cache. `kv_pos` must already include the
    current position (updated once per step by the model, not per layer).
    Returns (out, k_cache', v_cache').
    """
    from repro.models.kv_cache import write_kv
    q, k_new, v_new = _qkv(p, x, cfg, angles)
    k_cache, v_cache = write_kv(k_cache, v_cache, k_new, v_new, pos)
    o = decode_attention(q, k_cache, v_cache, kv_pos, pos, window=window)
    out = jnp.einsum("bse,ed->bsd", o.reshape(*x.shape[:2], -1), p["wo"])
    return constrain(out, P(BATCH, None, None)), k_cache, v_cache


def cross_attn(p, x, mem_k, mem_v, cfg: ModelConfig):
    """Cross attention to precomputed encoder memory (B,Sm,KV,dh)."""
    B, S, _ = x.shape
    h, dh = cfg.num_heads, cfg.d_head
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, h, dh)
    o = flash_attention(q, mem_k, mem_v, causal=False)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return constrain(out, P(BATCH, None, None))


# ------------------------------------------------------------------ FFN ----

def init_ffn_block(key, cfg: ModelConfig, dtype):
    rank = cfg.sparse_ffn.predictor_rank if cfg.sparse_ffn.enabled else 0
    return init_ffn(key, cfg.d_model, cfg.d_ff, cfg.activation, dtype,
                    predictor_rank=rank)


def ffn_block_spec(cfg: ModelConfig):
    return ffn_spec(cfg.sparse_ffn.enabled)


def apply_ffn_block(params, x, cfg: ModelConfig, plan, return_indices=False,
                    active_mask=None):
    return ffn_apply(params, x, cfg.activation, cfg.sparse_ffn, plan,
                     return_indices=return_indices, active_mask=active_mask)


# ------------------------------------------------------------- scanning ----

# When True, layer scans unroll into Python loops. Used ONLY by the
# roofline cost probe (launch/dryrun --probe): XLA's cost analysis
# counts a while-loop body once regardless of trip count, so the probe
# lowers unrolled reduced-depth variants and extrapolates linearly.
UNROLL = False


def scan_over(body, carry, xs, length=None):
    """lax.scan, or an unrolled Python loop when UNROLL is set."""
    if not UNROLL:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        ys = None
    return carry, ys


def scan_layers(body, carry, layer_params, *per_layer_xs, remat=False,
                length=None):
    """Scan over stacked layer params (leaves have leading L dim)."""
    fn = jax.checkpoint(body) if remat else body
    xs = (layer_params,) + per_layer_xs if per_layer_xs else layer_params
    return scan_over(fn, carry, xs, length=length)
