"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427).

Block pattern ('rec','rec','attn') repeating: two RG-LRU recurrent
blocks per local-attention (MQA, window 2048) block; every temporal
block is followed by a GeGLU MLP that carries the PowerInfer-2 hybrid
FFN technique. 38 layers = 12 scanned groups + 2 remainder rec layers.

RG-LRU: r_t = σ(x_t·w_r + b_r), i_t = σ(x_t·w_i + b_i)
        a_t = exp(-c · softplus(Λ) · r_t)
        h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
Full-sequence via associative scan; decode is the O(1) update.
Gates are per-channel (diagonal) — a TPU-friendly simplification of
Griffin's block-diagonal gate matrices (DESIGN.md §2 records this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks, dense
from repro.models.attention import rope_angles
from repro.models.kv_cache import write_pos
from repro.models.modules import (
    dtype_of, dense_init, embed_init, rms_norm, stack_layer_params)
from repro.models.ssm import causal_conv
from repro.sharding import constrain, BATCH


# ------------------------------------------------------------- RG-LRU ----

def rglru_full(p, x, cfg, init_h=None):
    """x (B,S,dr) -> (y, h_final). Associative scan over the sequence."""
    c = cfg.rglru_c
    r = jax.nn.sigmoid(x * p["w_r"] + p["b_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(x * p["w_i"] + p["b_i"])
    log_a = -c * jax.nn.softplus(p["lam"]) * r               # (B,S,dr) fp32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * (i * x).astype(jnp.float32)

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, b2 + a2 * b1

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    if init_h is not None:
        Bc = Bc + A * init_h[:, None].astype(jnp.float32)
    return Bc.astype(x.dtype), Bc[:, -1].astype(x.dtype)


def rglru_step(p, x, cfg, h):
    """x (B,dr), h (B,dr) -> (y, h')."""
    c = cfg.rglru_c
    r = jax.nn.sigmoid(x * p["w_r"] + p["b_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(x * p["w_i"] + p["b_i"])
    log_a = -c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * (i * x).astype(jnp.float32)
    h = a * h.astype(jnp.float32) + b
    return h.astype(x.dtype), h.astype(x.dtype)


# ------------------------------------------------------------- blocks ----

def init_rec_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    dr = d                                                   # lru width
    W = cfg.rglru_conv_width
    ks = jax.random.split(key, 6)
    k2 = jax.random.split(ks[5], 2)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_in": dense_init(ks[0], (d, dr), dtype),
        "w_gate": dense_init(ks[1], (d, dr), dtype),
        "conv_w": dense_init(ks[2], (W, dr), dtype, scale=0.5),
        "conv_b": jnp.zeros((dr,), dtype),
        "lru": {"w_r": dense_init(k2[0], (dr,), dtype, scale=1.0),
                "b_r": jnp.zeros((dr,), dtype),
                "w_i": dense_init(k2[1], (dr,), dtype, scale=1.0),
                "b_i": jnp.zeros((dr,), dtype),
                "lam": jnp.full((dr,), 0.7, jnp.float32)},
        "w_out": dense_init(ks[3], (dr, d), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "ffn": blocks.init_ffn_block(ks[4], cfg, dtype),
    }


def rec_block_spec(cfg):
    return {
        "ln": P(None),
        "w_in": P(None, "model"), "w_gate": P(None, "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "lru": {"w_r": P("model"), "b_r": P("model"),
                "w_i": P("model"), "b_i": P("model"), "lam": P("model")},
        "w_out": P("model", None),
        "ln2": P(None),
        "ffn": blocks.ffn_block_spec(cfg),
    }


def init_attn_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": blocks.init_ffn_block(k2, cfg, dtype),
    }


def attn_block_spec(cfg):
    return {"ln": P(None), "attn": blocks.attn_spec(cfg),
            "ln2": P(None), "ffn": blocks.ffn_block_spec(cfg)}


def _apply_mlp(lp, x, cfg, plan):
    f = blocks.apply_ffn_block(lp["ffn"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                               cfg, plan)
    return x + f


def rec_full(lp, x, cfg, plan=None, init_h=None, conv_tail=None):
    """Full-seq recurrent block + MLP. Returns (x, (h_final, conv_tail))."""
    xi = rms_norm(x, lp["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xi, lp["w_gate"]))
    u = jnp.einsum("bsd,de->bse", xi, lp["w_in"])
    u, tail = causal_conv(u, lp["conv_w"], lp["conv_b"], conv_tail)
    y, h = rglru_full(lp["lru"], u, cfg, init_h)
    out = jnp.einsum("bse,ed->bsd", y * gate, lp["w_out"])
    x = x + constrain(out, P(BATCH, None, None))
    return _apply_mlp(lp, x, cfg, plan), (h, tail)


def rec_step(lp, x, cfg, h, tail, plan=None):
    """One-token recurrent block + MLP. x (B,1,D)."""
    xi = rms_norm(x, lp["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xi, lp["w_gate"]))
    u = jnp.einsum("bsd,de->bse", xi, lp["w_in"])
    u, tail = causal_conv(u, lp["conv_w"], lp["conv_b"], tail)
    y, h = rglru_step(lp["lru"], u[:, 0], cfg, h)
    out = jnp.einsum("bse,ed->bsd", y[:, None] * gate, lp["w_out"])
    x = x + out
    return _apply_mlp(lp, x, cfg, plan), (h, tail)


def attn_full_block(lp, x, cfg, angles, plan=None):
    a, kv = blocks.attn_full(lp["attn"], rms_norm(x, lp["ln"], cfg.norm_eps),
                             cfg, angles, causal=True, window=cfg.local_window)
    x = x + a
    return _apply_mlp(lp, x, cfg, plan), kv


def attn_step_block(lp, x, cfg, angles, kc, vc, kv_pos, pos, plan=None):
    a, kc, vc = blocks.attn_decode(lp["attn"],
                                   rms_norm(x, lp["ln"], cfg.norm_eps),
                                   cfg, angles, kc, vc, kv_pos, pos,
                                   window=cfg.local_window)
    x = x + a
    return _apply_mlp(lp, x, cfg, plan), (kc, vc)


# ------------------------------------------------------------- model ----

def _layout(cfg: ModelConfig):
    """(n_groups, remainder_kinds) for the repeating block pattern."""
    period = len(cfg.block_pattern)
    n_groups = cfg.num_layers // period
    rem = cfg.block_pattern[: cfg.num_layers - n_groups * period]
    return n_groups, rem


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    n_groups, rem = _layout(cfg)
    ke, kg, kr = jax.random.split(key, 3)

    def init_group(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}": (init_rec_block(ks[i], cfg, dtype) if kind == "rec"
                          else init_attn_block(ks[i], cfg, dtype))
                for i, kind in enumerate(cfg.block_pattern)}

    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
        "groups": stack_layer_params(kg, n_groups, init_group),
    }
    krs = jax.random.split(kr, max(len(rem), 1))
    for i, kind in enumerate(rem):
        params[f"rem{i}"] = (init_rec_block(krs[i], cfg, dtype)
                             if kind == "rec"
                             else init_attn_block(krs[i], cfg, dtype))
    return params


def params_spec(cfg: ModelConfig):
    _, rem = _layout(cfg)
    gspec = {f"b{i}": (rec_block_spec(cfg) if kind == "rec"
                       else attn_block_spec(cfg))
             for i, kind in enumerate(cfg.block_pattern)}
    gspec = jax.tree.map(lambda s: P(None, *s), gspec,
                         is_leaf=lambda s: isinstance(s, P))
    spec = {"embed": P("model", None), "out_norm": P(None), "groups": gspec}
    for i, kind in enumerate(rem):
        spec[f"rem{i}"] = (rec_block_spec(cfg) if kind == "rec"
                           else attn_block_spec(cfg))
    return spec


def make_model(cfg: ModelConfig) -> dense.Model:
    dh_half = cfg.d_head // 2
    pattern = cfg.block_pattern
    n_groups, rem = _layout(cfg)
    n_rec_g = sum(1 for k in pattern if k == "rec")
    n_attn_g = sum(1 for k in pattern if k == "attn")
    dr, Wc = cfg.d_model, cfg.rglru_conv_width
    Wloc = cfg.local_window
    kv, dh = cfg.num_kv_heads, cfg.d_head

    def init_cache(batch, seq_len=0, dtype=None):
        dtype = dtype or dtype_of(cfg.param_dtype)
        n_rec = n_groups * n_rec_g + sum(1 for k in rem if k == "rec")
        n_attn = n_groups * n_attn_g + sum(1 for k in rem if k == "attn")
        return {
            "rec_h": jnp.zeros((n_rec, batch, dr), dtype),
            "rec_conv": jnp.zeros((n_rec, batch, Wc - 1, dr), dtype),
            "attn_k": jnp.zeros((n_attn, batch, Wloc, kv, dh), dtype),
            "attn_v": jnp.zeros((n_attn, batch, Wloc, kv, dh), dtype),
            "kv_pos": jnp.full((batch, Wloc), -1, jnp.int32),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def cache_spec(batch=None, seq_len=None):
        return {"rec_h": P(None, BATCH, "model"),
                "rec_conv": P(None, BATCH, None, "model"),
                "attn_k": P(None, BATCH, None, "model", None),
                "attn_v": P(None, BATCH, None, "model", None),
                "kv_pos": P(BATCH, None), "length": P(BATCH)}

    def _group_full(gp, x, angles, plan, collect):
        """Apply one (rec, rec, attn) group. Returns (x, states)."""
        states = {}
        ri = ai = 0
        for i, kind in enumerate(pattern):
            lp = gp[f"b{i}"]
            if kind == "rec":
                x, st = rec_full(lp, x, cfg, plan)
                states[f"rec{ri}"] = st
                ri += 1
            else:
                x, kvp = attn_full_block(lp, x, cfg, angles, plan)
                states[f"attn{ai}"] = kvp
                ai += 1
        return x, (states if collect else None)

    def forward(params, batch, plan=None):
        x = dense.embed_tokens(params, cfg, batch["tokens"])
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)

        def body(h, gp):
            h, _ = _group_full(gp, h, angles, plan, False)
            return h, None

        x, _ = blocks.scan_layers(body, x, params["groups"], remat=cfg.remat)
        for i, kind in enumerate(rem):
            lp = params[f"rem{i}"]
            x = (rec_full(lp, x, cfg, plan)[0] if kind == "rec"
                 else attn_full_block(lp, x, cfg, angles, plan)[0])
        return dense.lm_logits(params, cfg, x)

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = dense.embed_tokens(params, cfg, tokens)
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)

        def body(h, gp):
            h, st = _group_full(gp, h, angles, None, True)
            return h, st

        x, gstates = blocks.scan_layers(body, x, params["groups"],
                                        remat=cfg.remat)
        rec_h = [gstates[f"rec{i}"][0] for i in range(n_rec_g)]
        rec_conv = [gstates[f"rec{i}"][1] for i in range(n_rec_g)]
        attn_k = [gstates[f"attn{i}"][0] for i in range(n_attn_g)]
        attn_v = [gstates[f"attn{i}"][1] for i in range(n_attn_g)]
        # interleave group-major: scanned states are (n_groups, B, ...)
        rec_h = (jnp.stack(rec_h, 1).reshape(-1, B, dr)
                 if rec_h else jnp.zeros((0, B, dr), x.dtype))
        rec_conv = (jnp.stack(rec_conv, 1).reshape(-1, B, Wc - 1, dr)
                    if rec_conv else jnp.zeros((0, B, Wc - 1, dr), x.dtype))

        def ring(k):
            # k (G, B, S, kv, dh) -> last Wloc tokens
            assert S % Wloc == 0 or S < Wloc, (S, Wloc)
            if S >= Wloc:
                return k[:, :, S - Wloc:]
            pad = jnp.zeros(k.shape[:2] + (Wloc - S,) + k.shape[3:], k.dtype)
            return jnp.concatenate([k, pad], axis=2)

        attn_k = [ring(jnp.stack(attn_k, 1).reshape(-1, B, S, kv, dh))] \
            if attn_k else []
        attn_v = [ring(jnp.stack(attn_v, 1).reshape(-1, B, S, kv, dh))] \
            if attn_v else []

        # remainder layers
        rem_states = []
        for i, kind in enumerate(rem):
            lp = params[f"rem{i}"]
            if kind == "rec":
                x, st = rec_full(lp, x, cfg, None)
                rem_states.append(st)
            else:
                x, kvp = attn_full_block(lp, x, cfg, angles, None)
                attn_k.append(ring(kvp[0][None]))
                attn_v.append(ring(kvp[1][None]))
        if rem_states:
            rec_h = jnp.concatenate(
                [rec_h] + [st[0][None] for st in rem_states], 0)
            rec_conv = jnp.concatenate(
                [rec_conv] + [st[1][None] for st in rem_states], 0)

        if S >= Wloc:
            kv_pos = jnp.broadcast_to(jnp.arange(S - Wloc, S), (B, Wloc))
        else:
            kv_pos = jnp.broadcast_to(
                jnp.where(jnp.arange(Wloc) < S, jnp.arange(Wloc), -1),
                (B, Wloc))
        cache = {
            "rec_h": rec_h, "rec_conv": rec_conv,
            "attn_k": (jnp.concatenate(attn_k, 0) if attn_k
                       else jnp.zeros((0, B, Wloc, kv, dh), x.dtype)),
            "attn_v": (jnp.concatenate(attn_v, 0) if attn_v
                       else jnp.zeros((0, B, Wloc, kv, dh), x.dtype)),
            "kv_pos": kv_pos.astype(jnp.int32),
            "length": jnp.full((B,), S, jnp.int32),
        }
        return dense.lm_logits(params, cfg, x[:, -1:]), cache

    def decode_step(params, tokens, cache, plan=None):
        pos = cache["length"]
        x = dense.embed_tokens(params, cfg, tokens)
        angles = rope_angles(pos[:, None], dh_half, cfg.rope_theta)
        kv_pos = write_pos(cache["kv_pos"], pos)

        def body(carry, xs):
            h = carry
            gp, rh, rc, ak, av = xs
            new_rh, new_rc, new_ak, new_av = [], [], [], []
            ri = ai = 0
            for i, kind in enumerate(pattern):
                lp = gp[f"b{i}"]
                if kind == "rec":
                    h, (hh, tl) = rec_step(lp, h, cfg, rh[ri], rc[ri], plan)
                    new_rh.append(hh)
                    new_rc.append(tl)
                    ri += 1
                else:
                    h, (kc, vc) = attn_step_block(lp, h, cfg, angles,
                                                  ak[ai], av[ai], kv_pos,
                                                  pos, plan)
                    new_ak.append(kc)
                    new_av.append(vc)
                    ai += 1
            return h, (jnp.stack(new_rh), jnp.stack(new_rc),
                       jnp.stack(new_ak), jnp.stack(new_av))

        ng = n_groups
        rh = cache["rec_h"][: ng * n_rec_g].reshape(ng, n_rec_g, *cache["rec_h"].shape[1:])
        rc = cache["rec_conv"][: ng * n_rec_g].reshape(ng, n_rec_g, *cache["rec_conv"].shape[1:])
        ak = cache["attn_k"][: ng * n_attn_g].reshape(ng, n_attn_g, *cache["attn_k"].shape[1:])
        av = cache["attn_v"][: ng * n_attn_g].reshape(ng, n_attn_g, *cache["attn_v"].shape[1:])
        x, (rh, rc, ak, av) = blocks.scan_over(
            body, x, (params["groups"], rh, rc, ak, av))
        rec_h = rh.reshape(-1, *cache["rec_h"].shape[1:])
        rec_conv = rc.reshape(-1, *cache["rec_conv"].shape[1:])
        attn_k = ak.reshape(-1, *cache["attn_k"].shape[1:])
        attn_v = av.reshape(-1, *cache["attn_v"].shape[1:])

        ri, ai = n_groups * n_rec_g, n_groups * n_attn_g
        rem_h, rem_c, rem_k, rem_v = [], [], [], []
        for i, kind in enumerate(rem):
            lp = params[f"rem{i}"]
            if kind == "rec":
                x, (hh, tl) = rec_step(lp, x, cfg, cache["rec_h"][ri],
                                       cache["rec_conv"][ri], plan)
                rem_h.append(hh)
                rem_c.append(tl)
                ri += 1
            else:
                x, (kc, vc) = attn_step_block(lp, x, cfg, angles,
                                              cache["attn_k"][ai],
                                              cache["attn_v"][ai],
                                              kv_pos, pos, plan)
                rem_k.append(kc)
                rem_v.append(vc)
                ai += 1
        if rem_h:
            rec_h = jnp.concatenate([rec_h, jnp.stack(rem_h)], 0)
            rec_conv = jnp.concatenate([rec_conv, jnp.stack(rem_c)], 0)
        if rem_k:
            attn_k = jnp.concatenate([attn_k, jnp.stack(rem_k)], 0)
            attn_v = jnp.concatenate([attn_v, jnp.stack(rem_v)], 0)

        new_cache = dict(cache, rec_h=rec_h, rec_conv=rec_conv,
                         attn_k=attn_k, attn_v=attn_v, kv_pos=kv_pos,
                         length=pos + 1)
        return dense.lm_logits(params, cfg, x), new_cache

    return dense.Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        param_spec=lambda: params_spec(cfg),
        forward=forward,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_spec=cache_spec,
    )
