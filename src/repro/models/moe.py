"""Mixture-of-Experts FFN + MoE decoder model (grok-1 / deepseek-moe /
turbosparse-mixtral).

The paper's neuron-cluster abstraction maps onto MoE at two levels
(DESIGN.md §Arch-applicability):
  * expert level — shared experts (deepseek) are *hot clusters*
    (always-dense), routed experts are *cold clusters* gated by the
    router (which plays the predictor's role);
  * neuron level — inside each expert the hybrid hot/cold FFN applies
    (the paper's TurboSparse-Mixtral-47B case).

Dispatch is sort-based (fully jittable, capacity-dropped):
tokens -> top-k experts -> rank within expert via stable argsort ->
(E, C, D) dispatch buffer -> batched expert GEMMs -> weighted combine.

Sharding: 'ep' shards the expert dim over the mesh 'model' axis
(deepseek: 64/16 = 4 per shard); 'tp' shards d_ff inside every expert
(grok: 8 experts < 16 shards). Both selectable per config; roofline
hillclimb compares. For grouped training dispatch the pjit/constrain
formulation below lets XLA insert the all-to-alls; the serving decode
shape (one replica-local group) takes `_moe_ep_shard_map` instead —
replicated routing, strictly shard-local dispatch/combine, one psum
per layer — which is what makes ep=N decode token-identical to ep=1
(DESIGN.md §8).

Serving (DESIGN.md §8): `make_decode_step(cfg, collect_indices=True)`
is the family registry's traced decode — it accepts the engine's
`active_mask` (freed KV-arena lanes never consume expert capacity)
and returns the per-layer kept-dispatch counts (L, E), the expert
activation trace the storage plane prices as cold-cluster residency.
With `cfg.moe_intra_expert` (DESIGN.md §9, the TurboSparse-Mixtral
case) the trace refines to (L, E, 1+ncc): real per-cold-cluster
activation counts *inside* each expert, thresholded off the unchanged
dense expert GEMMs — decode stays token-identical while the storage
plane prices hot/cold clusters within each routed expert.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.clusters import HybridPlan
from repro.models import blocks, dense
from repro.models.attention import rope_angles
from repro.models.kv_cache import write_pos
from repro.models.modules import (
    dtype_of, dense_init, rms_norm, stack_layer_params)
from repro.core.sparse_ffn import init_ffn, ffn_spec, ffn_dense
from repro.sharding import constrain, BATCH


# ------------------------------------------------------------- MoE FFN ----

def init_moe_ffn(key, cfg: ModelConfig, dtype):
    from repro.core.sparse_ffn import ffn_rows
    E, f, d = cfg.num_experts, cfg.d_ff, cfg.d_model
    R = ffn_rows(cfg.activation)
    kr, ke, ks = jax.random.split(key, 3)
    p = {
        "router": dense_init(kr, (d, E), dtype),
        "experts": dense_init(ke, (E, f, R, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_ffn(ks, d, f * cfg.num_shared_experts,
                               cfg.activation, dtype)
    return p


def moe_ffn_spec(cfg: ModelConfig):
    ep = cfg.moe_shard_mode == "ep"
    s = {"router": P(None, None),
         "experts": P("model", None, None, None) if ep
         else P(None, "model", None, None)}
    if cfg.num_shared_experts:
        s["shared"] = ffn_spec(False)
    return s


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(T * k / E * factor)
    return max(8, ((c + 7) // 8) * 8)


def moe_dispatch(gates, k: int, capacity: int, active=None):
    """gates (T, E) router probs -> dispatch metadata.

    Returns (expert_idx (T,k), combine_w (T,k), slot (T,k), keep (T,k))
    where slot indexes a flat (E*C) buffer.

    active (T,) bool, optional: rows excluded from dispatch entirely —
    they never occupy a capacity slot, so a dead row (a freed KV-arena
    lane decoding garbage) can neither evict a live token past capacity
    nor shift any live token's slot. Inactive entries route to a
    sentinel expert bucket E that sorts after every real expert, which
    keeps capacity ranking for the live tokens *identical* to a
    dispatch over the live tokens alone.
    """
    T, E = gates.shape
    topv, tope = jax.lax.top_k(gates, k)                    # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    flat_e = tope.reshape(-1)                               # (T*k,)
    if active is not None:
        flat_e = jnp.where(jnp.repeat(active, k), flat_e, E)
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.arange(T * k, dtype=jnp.int32))
    counts = jnp.zeros((E + 1,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.cumsum(counts) - counts                   # exclusive
    pos_in_e = ranks - offsets[flat_e]                      # (T*k,)
    keep = (pos_in_e < capacity) & (flat_e < E)
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, 0)
    return (tope, topv, slot.reshape(T, k), keep.reshape(T, k))


def _dispatch_group(xt, router, cfg, C, active=None):
    """One dispatch group: xt (T, D) -> (buf (E,C,D), combine metadata,
    aux loss, per-expert kept counts). Vmapped over data-local groups
    by apply_moe_ffn."""
    T, D = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xt.astype(jnp.float32),
                   router.astype(jnp.float32)), axis=-1)
    tope, topv, slot, keep = moe_dispatch(gates, k, C, active)
    xk = jnp.broadcast_to(xt[:, None], (T, k, D)).reshape(T * k, D)
    wgt = jnp.where(keep.reshape(-1), 1.0, 0.0).astype(xt.dtype)
    buf = jnp.zeros((E * C, D), xt.dtype)
    buf = buf.at[slot.reshape(-1)].add(xk * wgt[:, None])
    # router load-balance aux loss (Switch-style)
    me = gates.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[tope.reshape(-1)].add(1.0 / (T * k))
    aux = E * jnp.sum(me * ce)
    counts = _expert_counts(tope, keep, E)
    return buf.reshape(E, C, D), (slot, keep, topv), aux, counts


def _expert_counts(tope, keep, E: int):
    """Kept dispatch entries per expert, (E,) int32 — the MoE
    activation trace the storage plane consumes (experts == clusters:
    an expert with count > 0 was activated this step)."""
    flat = jnp.where(keep.reshape(-1), tope.reshape(-1), E)
    return jnp.zeros((E + 1,), jnp.int32).at[flat].add(1)[:E]


def _two_level_trace(cfg: ModelConfig, plan) -> bool:
    """True when the decode trace is the two-level (E, 1+ncc) form:
    intra-expert sparsity enabled and the stepped plan carries a
    per-expert hot prefix (DESIGN.md §9)."""
    return (cfg.moe_intra_expert and plan is not None
            and getattr(plan, "n_expert_hot", 0) > 0)


def _cold_cluster_counts(h, cfg: ModelConfig, n_hot_e: int, cs: int):
    """h (..., e_slice, C, f) real expert activations -> (e_slice, ncc)
    int32 active-(slot, neuron) counts per intra-expert *cold* cluster
    (rows are hot-first permuted, so the cold suffix starts at
    n_hot_e and groups into (f - n_hot_e)/cs clusters).

    The expert GEMMs are computed densely (numerics untouched), so the
    trace is the TRUE activation set: empty capacity slots and dropped
    dispatch entries contribute exact zeros (relu/silu of 0 is 0) and
    never mark a cluster active. With relu-family activations skipping
    an inactive cold cluster is lossless — exactly why the paper's
    TurboSparse models ReLUfy — which is what lets the storage plane
    price only the traced clusters while decode stays token-identical
    to dense-expert decode."""
    from repro.core.planner import _act_threshold
    tau = _act_threshold(cfg.sparse_ffn.mode)
    f = h.shape[-1]
    active = (jnp.abs(h) > tau).astype(jnp.int32)
    na = active.reshape((-1,) + h.shape[-3:]).sum(axis=(0, 2))  # (e, f)
    ncc = (f - n_hot_e) // cs
    return na[:, n_hot_e:].reshape(-1, ncc, cs).sum(axis=-1)


def _combine_group(yb, slot, keep, topv):
    """yb (E*C, D) expert outputs -> (T, D) weighted combine."""
    T, k = slot.shape
    yk = jnp.take(yb, slot.reshape(-1), axis=0).reshape(T, k, yb.shape[-1])
    yk = yk * (topv * keep).astype(yk.dtype)[..., None]
    return yk.sum(axis=1)


def _use_ep_shard_map(cfg: ModelConfig, G: int) -> bool:
    """Shard-local expert parallelism applies when the mesh 'model'
    axis evenly splits the experts, sharding mode is 'ep', and the
    token block is a single replica-local group (the serving decode
    shape — grouped training dispatch keeps the pjit formulation)."""
    from repro.sharding import current_mesh
    m = current_mesh()
    if m is None or "model" not in m.axis_names or G != 1:
        return False
    if cfg.moe_shard_mode != "ep":
        return False
    n = dict(m.shape).get("model", 1)
    return n > 1 and cfg.num_experts % n == 0


def _moe_ep_shard_map(params, xt, cfg: ModelConfig, C: int, active_mask,
                      plan=None, collect_trace: bool = False):
    """Shard-local expert-parallel dispatch (DESIGN.md §8), mirroring
    the cold-group scheme of core/sparse_ffn._cold_path_shard_map: the
    mesh 'model' axis (size n) owns E/n whole experts per shard.

    Routing is computed *replicated* (the router weights replicate, so
    gates/top-k/capacity ranking are exactly the single-device math on
    every shard); dispatch and combine are strictly shard-local — each
    shard scatters only the (token, expert) entries whose expert it
    owns into its (E/n, C, D) buffer, runs its expert GEMMs, and
    combines a partial (T, D) output. One fp32 psum per layer crosses
    shards, so expert selection — and decoded tokens — are identical
    at every mesh size. Returns ((T, D) output, trace, aux).

    The trace is the (E,) kept counts, or — when the stepped plan
    enables two-level sparsity (DESIGN.md §9) — the (E, 1+ncc) form:
    each shard thresholds its own experts' real activations (the
    per-expert cold gathers stay strictly shard-local) and the local
    (E/n, 1+ncc) blocks are all_gather'd in expert order, the same
    id-only collective the dense cold path uses for its cluster ids.
    """
    from jax.sharding import PartitionSpec as PS
    from repro.compat import shard_map
    from repro.sharding import current_mesh

    mesh = current_mesh()
    n = dict(mesh.shape)["model"]
    E, k = cfg.num_experts, cfg.experts_per_token
    e_loc = E // n
    w = params["experts"]                                   # (E, f, R, D)
    R = w.shape[2]
    from repro.models.modules import activation_fn
    act = activation_fn(cfg.activation)
    two_level = collect_trace and _two_level_trace(cfg, plan)
    n_hot_e = plan.n_expert_hot if two_level else 0
    cs = plan.cluster_size if two_level else 0

    def local(xl, wl, rl, ml):
        # xl (T, D) replicated; wl (e_loc, f, R, D) this shard's
        # experts; rl (D, E) replicated router; ml (T,) live-row mask.
        T, D = xl.shape
        gates = jax.nn.softmax(
            jnp.einsum("td,de->te", xl.astype(jnp.float32),
                       rl.astype(jnp.float32)), axis=-1)
        tope, topv, slot, keep = moe_dispatch(gates, k, C, ml)
        e0 = jax.lax.axis_index("model") * e_loc
        flat_e = tope.reshape(-1)
        sel = keep.reshape(-1) & (flat_e >= e0) & (flat_e < e0 + e_loc)
        lslot = jnp.where(sel, slot.reshape(-1) - e0 * C, 0)
        xk = jnp.broadcast_to(xl[:, None], (T, k, D)).reshape(T * k, D)
        wgt = jnp.where(sel, 1.0, 0.0).astype(xl.dtype)
        buf = jnp.zeros((e_loc * C, D), xl.dtype)
        buf = buf.at[lslot].add(xk * wgt[:, None]).reshape(e_loc, C, D)
        g = jnp.einsum("ecd,efd->ecf", buf, wl[:, :, 0])
        if R == 3:
            u = jnp.einsum("ecd,efd->ecf", buf, wl[:, :, 1])
            h = act(g) * u
        else:
            h = act(g)
        yb = jnp.einsum("ecf,efd->ecd", h, wl[:, :, -1])
        yk = jnp.take(yb.reshape(e_loc * C, D), lslot, axis=0)
        yk = yk.reshape(T, k, D) \
            * (topv * sel.reshape(T, k)).astype(yk.dtype)[..., None]
        # psum in f32 (same rationale as _cold_path_shard_map); the
        # kept counts and aux loss are replicated global math — no
        # collective beyond the one output reduction (plus, for the
        # two-level trace, the id-only all_gather below).
        y = jax.lax.psum(yk.sum(axis=1).astype(jnp.float32), "model")
        me = gates.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[tope.reshape(-1)].add(
            1.0 / (T * k))
        aux = E * jnp.sum(me * ce)
        counts = _expert_counts(tope, keep, E)
        if two_level:
            # this shard's experts' real activations -> local
            # (e_loc, 1+ncc) block, gathered in expert-block order
            cold = _cold_cluster_counts(h, cfg, n_hot_e, cs)
            loc = jax.lax.dynamic_slice_in_dim(counts, e0, e_loc)
            blk = jnp.concatenate([loc[:, None], cold], axis=1)
            trace = jax.lax.all_gather(blk, "model").reshape(
                E, blk.shape[1]).astype(jnp.int32)
        else:
            trace = counts
        return y, trace, aux

    if active_mask is None:
        active_mask = jnp.ones((xt.shape[0],), bool)
    tr_spec = PS(None, None) if two_level else PS(None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(PS(None, None), PS("model", None, None, None),
                  PS(None, None), PS(None)),
        out_specs=(PS(None, None), tr_spec, PS()),
        axis_names={"model"}, check_vma=False)
    y, counts, aux = fn(xt, w, params["router"], active_mask)
    return y.astype(xt.dtype), counts, aux


def apply_moe_ffn(params, x, cfg: ModelConfig,
                  plan: Optional[HybridPlan] = None,
                  active_mask=None, collect_trace: bool = False):
    """x (..., D) -> ((..., D), aux[, trace]). Train (T=B*S) and
    decode (T=B).

    active_mask (T,) bool: rows excluded from dispatch (the serving
    engine's freed KV-arena lanes) — they must neither consume expert
    capacity nor appear in the activation trace. collect_trace=True
    additionally returns the activation trace the serving storage
    plane consumes: the per-expert kept-entry counts (E,) int32, or —
    when `cfg.moe_intra_expert` and the stepped plan carries a
    per-expert hot prefix — the two-level (E, 1+ncc) form whose first
    column is the kept counts and whose remaining columns count real
    activations per intra-expert cold cluster (DESIGN.md §9). The
    expert compute itself never changes: the trace thresholds the
    dense GEMMs' activations, so two-level decode is token-identical
    to whole-expert decode by construction.

    Hierarchical dispatch (§Perf iteration, EXPERIMENTS.md): tokens are
    routed within `moe_dispatch_groups` data-local groups (group dim
    sharded over batch axes, experts over 'model'), so the dispatch
    buffer is (G, E, C_local, D) — per-device E_local*C_local*D —
    instead of a replicated global (E, C_global, D). Per-token top-k is
    unchanged; only capacity dropping becomes group-local, which is
    *more* faithful to EP systems (capacity is per-device there too).
    """
    shape = x.shape
    D = shape[-1]
    xt = x.reshape(-1, D)                                   # (T, D)
    T = xt.shape[0]
    E, k = cfg.num_experts, cfg.experts_per_token
    G = cfg.moe_dispatch_groups \
        if cfg.moe_dispatch_groups > 0 and T % cfg.moe_dispatch_groups == 0 \
        else 1
    Tg = T // G
    C = _capacity(Tg, k, E, cfg.moe_capacity_factor)
    w = params["experts"]                                   # (E, f, R, D)

    if _use_ep_shard_map(cfg, G):
        y, trace, aux = _moe_ep_shard_map(params, xt, cfg, C, active_mask,
                                          plan=plan,
                                          collect_trace=collect_trace)
        if "shared" in params:                              # hot clusters
            y = y + ffn_dense(params["shared"], xt, cfg.activation)
        y = y.reshape(shape)
        return (y, aux, trace) if collect_trace else (y, aux)

    xg = constrain(xt.reshape(G, Tg, D), P(BATCH, None, None))
    mask = jnp.ones((T,), bool) if active_mask is None \
        else active_mask.reshape(-1)
    buf, meta, auxg, cnts = jax.vmap(
        lambda xx, mm: _dispatch_group(xx, params["router"], cfg, C, mm)
    )(xg, mask.reshape(G, Tg))

    # explicit all-to-all: the dispatch buffer reshards from
    # batch-sharded groups to expert-sharded slots — tokens move to the
    # experts' shards instead of XLA all-gathering every expert's
    # weights onto every data shard (§Perf iteration 3).
    ep = cfg.moe_shard_mode == "ep"
    espec = P(BATCH, "model", None, None) if ep \
        else P(BATCH, None, None, None)
    buf = constrain(buf, espec)

    from repro.models.modules import activation_fn
    act = activation_fn(cfg.activation)
    R = w.shape[2]
    g = jnp.einsum("gecd,efd->gecf", buf, w[:, :, 0])
    g = constrain(g, P(BATCH, "model", None, None) if ep
                  else P(BATCH, None, None, "model"))
    if R == 3:
        u = jnp.einsum("gecd,efd->gecf", buf, w[:, :, 1])
        h = act(g) * u
    else:
        h = act(g)
    yb = jnp.einsum("gecf,efd->gecd", h, w[:, :, -1])
    # all-to-all back: expert-sharded outputs return to their groups
    yb = constrain(yb, P(BATCH, None, None, None))
    slot, keep, topv = meta
    yg = jax.vmap(_combine_group)(
        yb.reshape(G, E * C, D), slot, keep, topv)
    yg = constrain(yg, P(BATCH, None, None))
    y = yg.reshape(T, D)
    aux = auxg.mean()

    if "shared" in params:                                  # hot clusters
        y = y + ffn_dense(params["shared"], xt, cfg.activation)
    y = y.reshape(shape)
    if collect_trace:
        counts = cnts.sum(axis=0)                           # (E,) counts
        if _two_level_trace(cfg, plan):
            cold = _cold_cluster_counts(h, cfg, plan.n_expert_hot,
                                        plan.cluster_size)
            return y, aux, jnp.concatenate(
                [counts[:, None], cold], axis=1).astype(jnp.int32)
        return y, aux, counts
    return y, aux


# ------------------------------------------------------------- model ----

def init_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": init_moe_ffn(k2, cfg, dtype),
    }


def layer_spec(cfg: ModelConfig):
    return {"ln1": P(None), "attn": blocks.attn_spec(cfg),
            "ln2": P(None), "moe": moe_ffn_spec(cfg)}


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    from repro.models.modules import embed_init
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": stack_layer_params(kl, cfg.num_layers,
                                     lambda k: init_layer(k, cfg, dtype)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_padded), dtype)
    return params


def params_spec(cfg: ModelConfig):
    ls = jax.tree.map(lambda s: P(None, *s), layer_spec(cfg),
                      is_leaf=lambda s: isinstance(s, P))
    spec = {"embed": P("model", None), "out_norm": P(None), "layers": ls}
    if not cfg.tie_embeddings:
        spec["lm_head"] = P(None, "model")
    return spec


def make_model(cfg: ModelConfig) -> dense.Model:
    dh_half = cfg.d_head // 2
    init_cache, cache_spec = dense.make_cache_fns(cfg)
    W = cfg.sliding_window

    def forward(params, batch, plan=None):
        tokens = batch["tokens"]
        x = dense.embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)

        def body(h, lp):
            a, _ = blocks.attn_full(lp["attn"],
                                    rms_norm(h, lp["ln1"], cfg.norm_eps),
                                    cfg, angles, causal=True, window=W)
            h = h + a
            f, aux = apply_moe_ffn(lp["moe"],
                                   rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            return h + f, aux

        x, auxs = blocks.scan_layers(body, x, params["layers"],
                                     remat=cfg.remat)
        logits = dense.lm_logits(params, cfg, x)
        return logits

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = dense.embed_tokens(params, cfg, tokens)
        S = x.shape[1]
        angles = rope_angles(jnp.arange(S), dh_half, cfg.rope_theta)

        def body(h, lp):
            a, kv = blocks.attn_full(lp["attn"],
                                     rms_norm(h, lp["ln1"], cfg.norm_eps),
                                     cfg, angles, causal=True, window=W)
            h = h + a
            f, _ = apply_moe_ffn(lp["moe"],
                                 rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            return h + f, kv

        x, (k, v) = blocks.scan_layers(body, x, params["layers"],
                                       remat=cfg.remat)
        T = max_len or S
        pad = T - S
        if pad:
            zeros = jnp.zeros(k.shape[:2] + (pad,) + k.shape[3:], k.dtype)
            k = jnp.concatenate([k, zeros], axis=2)
            v = jnp.concatenate([v, zeros], axis=2)
        kv_pos = jnp.where(jnp.arange(T) < S, jnp.arange(T), -1)
        kv_pos = jnp.broadcast_to(kv_pos, (B, T)).astype(jnp.int32)
        cache = {"k": k, "v": v, "kv_pos": kv_pos,
                 "length": jnp.full((B,), S, jnp.int32)}
        return dense.lm_logits(params, cfg, x[:, -1:]), cache

    return dense.Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        param_spec=lambda: params_spec(cfg),
        forward=forward,
        prefill=prefill,
        decode_step=make_decode_step(cfg),
        init_cache=init_cache,
        cache_spec=cache_spec,
    )


def make_decode_step(cfg: ModelConfig, collect_indices: bool = False):
    """Serving decode step with the uniform family signature
    (params, tokens, cache, plan, active_mask) -> (logits, cache[,
    trace]). The router plays the predictor's role (DESIGN.md §8);
    the hybrid plan never alters the expert compute, it only shapes
    the trace: collect_indices=True returns the per-layer
    kept-dispatch counts (L, E), or the two-level (L, E, 1+ncc) trace
    when the plan carries a per-expert hot prefix
    (cfg.moe_intra_expert, DESIGN.md §9) — the activation trace the
    storage plane prices exactly like dense cold-cluster selections."""
    dh_half = cfg.d_head // 2
    W = cfg.sliding_window

    def decode_step(params, tokens, cache, plan=None, active_mask=None):
        pos = cache["length"]
        x = dense.embed_tokens(params, cfg, tokens)
        angles = rope_angles(pos[:, None], dh_half, cfg.rope_theta)
        kv_pos = write_pos(cache["kv_pos"], pos)

        def body(h, xs):
            lp, kc, vc = xs
            a, kc, vc = blocks.attn_decode(
                lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                angles, kc, vc, kv_pos, pos, window=W)
            h = h + a
            out = apply_moe_ffn(lp["moe"],
                                rms_norm(h, lp["ln2"], cfg.norm_eps), cfg,
                                plan=plan, active_mask=active_mask,
                                collect_trace=collect_indices)
            if collect_indices:
                f, _, tr = out
                h = h + f
                return h, (kc, vc, tr)
            f, _ = out
            return h + f, (kc, vc)

        x, ys = blocks.scan_over(body, x, (params["layers"],
                                           cache["k"], cache["v"]))
        if collect_indices:
            k, v, trace = ys
        else:
            k, v = ys
        new_cache = dict(cache, k=k, v=v, kv_pos=kv_pos, length=pos + 1)
        logits = dense.lm_logits(params, cfg, x)
        if collect_indices:
            return logits, new_cache, trace
        return logits, new_cache

    return decode_step
