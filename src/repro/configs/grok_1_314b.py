"""Grok-1 314B [hf:xai-org/grok-1] — MoE, 8 experts top-2, GQA kv=8.

8 experts < model-axis size (16) -> tensor-parallel *inside* experts
(moe_shard_mode='tp'); see DESIGN.md §5. Technique applies within
experts (the paper's TurboSparse-Mixtral case).
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    activation="gelu",
    num_experts=8,
    experts_per_token=2,
    moe_shard_mode="tp",
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.4, cold_active_ratio=0.2),
)
