"""SeamlessM4T-large v2 [arXiv:2308.11596] — encoder-decoder audio backbone.

Transformer backbone only (per brief): the mel-spectrogram + conformer
feature frontend is a stub; input_specs() supplies precomputed frame
embeddings (B, num_frames, d_model). 24 encoder + 24 decoder layers,
MHA (kv=16=heads), d_ff 8192, vocab 256206. GELU FFNs carry the
technique in 'cats' mode.
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    num_frames=4096,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.4, cold_active_ratio=0.2),
)
