"""Config registry: get_config('<arch-id>') for every assigned architecture
(plus the paper's own models) and the four assigned input shapes."""
from repro.configs.base import ModelConfig, SparseFFNConfig, InputShape, INPUT_SHAPES

from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.qwen3_14b import CONFIG as _qwen3
from repro.configs.deepseek_moe_16b import CONFIG as _dsmoe
from repro.configs.paper_models import (
    BAMBOO_7B, MISTRAL_7B, TURBOSPARSE_MIXTRAL_47B)

ASSIGNED_ARCHS = (
    "nemotron-4-15b", "llama3-405b", "recurrentgemma-9b",
    "seamless-m4t-large-v2", "grok-1-314b", "smollm-135m",
    "mamba2-130m", "qwen2-vl-2b", "qwen3-14b", "deepseek-moe-16b",
)

_REGISTRY = {c.name: c for c in (
    _nemotron, _llama3, _rgemma, _seamless, _grok, _smollm,
    _mamba2, _qwen2vl, _qwen3, _dsmoe,
    BAMBOO_7B, MISTRAL_7B, TURBOSPARSE_MIXTRAL_47B,
)}


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)


__all__ = ["ModelConfig", "SparseFFNConfig", "InputShape", "INPUT_SHAPES",
           "ASSIGNED_ARCHS", "get_config", "list_archs"]
