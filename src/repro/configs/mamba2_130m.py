"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality), attention-free.

d_ff=0: no FFN blocks at all -> the PowerInfer-2 hot/cold FFN technique is
INAPPLICABLE (DESIGN.md §Arch-applicability); implemented without it.
Natively sub-quadratic: long_500k decode runs on the recurrent state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_head=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)
