"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small, GQA kv=3.

The primary CPU-runnable demo model for examples/ and serving benchmarks.
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    activation="silu",
    tie_embeddings=True,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.25, cold_active_ratio=0.15,
                               cluster_size=64),
)
