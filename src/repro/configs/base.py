"""Model configuration system.

A single `ModelConfig` dataclass covers all six architecture families
(dense / moe / ssm / hybrid / encdec / vlm). Every assigned architecture
gets one file in this package instantiating the exact published config,
with the source paper / model card cited in the docstring.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class SparseFFNConfig:
    """PowerInfer-2 hybrid hot/cold FFN settings (the paper's technique).

    Neurons (FFN rows) are permuted offline by the planner so that the
    `hot_ratio` most frequently activated neurons form a contiguous *hot*
    prefix computed densely (the NPU/MXU path); the remaining *cold*
    neurons are computed through the predictor-gated gathered-cluster
    path (the CPU/sparse path).
    """
    enabled: bool = False
    # Fraction of FFN neurons in the dense hot prefix (batch-size bucket 1).
    hot_ratio: float = 0.25
    # Fraction of *cold* neurons actually computed per step (top-k budget).
    cold_active_ratio: float = 0.10
    # Low-rank activation predictor rank.
    predictor_rank: int = 64
    # Neuron-cluster granularity (rows per cluster). MXU-aligned.
    cluster_size: int = 128
    # Activation mode: 'relu' family has native zeros; 'cats' thresholds
    # SiLU activations (paper §7.2.5 — CATS / CHESS style ~50% sparsity).
    mode: str = "relu"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // num_heads
    activation: str = "silu"         # silu | relu2 | gelu | geglu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- attention variant ---
    sliding_window: int = 0          # 0 = full attention
    # auto-substituted window for long_500k on full-attention archs:
    long_context_window: int = 4096

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_shard_mode: str = "ep"       # 'ep' (experts over model axis) | 'tp'
    # Hierarchical dispatch (§Perf iteration): tokens dispatch to experts
    # within data-local groups (capacity per group), so the dispatch
    # buffer shards over the batch axes instead of materializing a
    # global (E, C_global, D) buffer. Launcher sets = data*pod shards.
    moe_dispatch_groups: int = 1
    # Intra-expert hot/cold sparsity (the paper's TurboSparse-Mixtral
    # path, DESIGN.md §9): each routed expert's d_ff rows get the
    # dense-family hybrid treatment — a per-expert hot-first
    # permutation with a pinned per-expert hot prefix, cold rows priced
    # as sparse_ffn.cluster_size clusters from the real activation
    # trace. False = whole experts are the cluster unit (DESIGN.md §8).
    moe_intra_expert: bool = False

    # --- SSM (Mamba-2 / SSD, arXiv:2405.21060) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (RecurrentGemma / Griffin, arXiv:2402.19427) ---
    block_pattern: tuple = ()        # e.g. ('rec','rec','attn'); () = all attn
    local_window: int = 0            # local-attention window for 'attn' blocks
    rglru_conv_width: int = 4
    rglru_c: float = 8.0

    # --- encoder-decoder (audio) ---
    num_encoder_layers: int = 0
    # stub modality frontend: input_specs() provides (B, n_frames, d_model)
    num_frames: int = 4096

    # --- VLM ---
    num_image_tokens: int = 0        # patch embeddings prepended to text
    mrope_sections: tuple = ()       # M-RoPE section split of d_head//2

    # --- the paper's technique ---
    sparse_ffn: SparseFFNConfig = field(default_factory=SparseFFNConfig)

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True               # activation checkpointing over layer scan

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.num_heads, 1))

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding shards over
        any mesh axis (production practice; invalid logits are masked)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch natively supports 500k-token decode."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def moe_flat_neurons(self) -> int:
        """Flat serving neuron space of a MoE layer: shared experts
        first (the pinned hot prefix — always-dense clusters), then the
        routed experts (cold clusters of d_ff neurons each). This is
        the experts-as-clusters mapping the storage plane prices
        (DESIGN.md §8)."""
        return (self.num_shared_experts + self.num_experts) * self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        kv = self.num_kv_heads
        h = self.num_heads
        dh = self.d_head
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        if self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state
            blk = d * (2 * di + 2 * ns + self.ssm_heads) + di * d + di * self.ssm_conv_width
            return emb + self.num_layers * blk
        ffn = 3 * d * f
        if self.num_experts:
            ffn = ffn * self.num_experts + 3 * d * f * self.num_shared_experts \
                + d * self.num_experts
        blk = attn + ffn
        n_layers = self.num_layers + self.num_encoder_layers
        return emb + n_layers * blk

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        ffn_all = 3 * d * f * self.num_experts
        ffn_act = 3 * d * f * self.experts_per_token
        return full - self.num_layers * (ffn_all - ffn_act)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests.

        2 layers, d_model<=512, <=4 experts, small vocab — per the brief.
        """
        kw = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_head=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            num_frames=64,
        )
        if self.num_experts:
            kw["num_experts"] = min(self.num_experts, 4)
            kw["num_shared_experts"] = min(self.num_shared_experts, 1)
            kw["experts_per_token"] = min(self.experts_per_token, 2)
        if self.num_encoder_layers:
            kw["num_encoder_layers"] = 2
        if self.block_pattern:
            kw["num_layers"] = len(self.block_pattern) + 2  # full group + remainder
        if self.num_image_tokens:
            kw["num_image_tokens"] = 16
        if self.mrope_sections:
            kw["mrope_sections"] = (8, 12, 12)  # sums to 32 = d_head//2
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 64)
            kw["ssm_head_dim"] = 32
            kw["ssm_chunk"] = 32
        if self.sparse_ffn.enabled:
            kw["sparse_ffn"] = dataclasses.replace(
                self.sparse_ffn, predictor_rank=16, cluster_size=32)
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
