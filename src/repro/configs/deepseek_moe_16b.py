"""DeepSeekMoE-16B [arXiv:2401.06066] — 2 shared + 64 routed experts, top-6.

Fine-grained experts (d_ff=1408) map 1:1 onto the paper's neuron-cluster
abstraction: shared experts = hot clusters (always-dense), routed
experts = cold clusters (predictor=router). EP sharding (64/16 = 4
experts per model shard).
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    activation="silu",
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_shard_mode="ep",
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.5, cold_active_ratio=0.25),
)
