"""Llama-3.1 405B [arXiv:2407.21783] — dense, GQA kv=8, 128k vocab, SiLU.

SiLU model: technique applies in CATS-style thresholded-sparsity mode
(paper §7.2.5, Table 6).
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    activation="silu",
    rope_theta=500000.0,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.5, cold_active_ratio=0.25),
)
