"""RecurrentGemma-9B [arXiv:2402.19427 Griffin] — RG-LRU + local attention.

Pattern 1 local-attention : 2 recurrent blocks ('rec','rec','attn').
38 layers = 12 full groups + 2 remainder recurrent blocks.
GeGLU MLP blocks carry the sparse-FFN technique; the RG-LRU recurrence
itself is dense (see DESIGN.md §Arch-applicability). MQA (kv=1).
Natively sub-quadratic: local attention window 2048.
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    tie_embeddings=True,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.4, cold_active_ratio=0.2),
)
