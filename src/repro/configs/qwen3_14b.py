"""Qwen3-14B [hf:Qwen/Qwen3-8B family] — dense, qk-norm, GQA kv=8."""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    activation="silu",
    qk_norm=True,
    rope_theta=1000000.0,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.3, cold_active_ratio=0.2),
)
