"""Qwen2-VL-2B [arXiv:2409.12191] — VLM backbone, M-RoPE, GQA kv=2.

LM backbone only (per brief): the ViT vision encoder + projector is a
stub; input_specs() supplies patch embeddings (B, num_image_tokens,
d_model) which the model interleaves ahead of text tokens with
multimodal 3D rotary positions (M-RoPE, sections over d_head//2).
d_head = 1536/12 = 128 -> half 64 -> sections (16, 24, 24).
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    activation="silu",
    rope_theta=1000000.0,
    num_image_tokens=1024,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.3, cold_active_ratio=0.2),
)
