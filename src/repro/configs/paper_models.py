"""The paper's own evaluation models (PowerInfer-2 §7.1).

Bamboo-7B [arXiv:2406.05955 TurboSparse] — ReLU-family, high sparsity.
TurboSparse-Mixtral-47B — 8-expert MoE, ~3B active params/token.
Mistral-7B (SiLU) — the §7.2.5 SiLU case.
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

BAMBOO_7B = ModelConfig(
    name="bamboo-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    activation="relu2",
    sparse_ffn=SparseFFNConfig(enabled=True, mode="relu",
                               hot_ratio=0.2, cold_active_ratio=0.08),
)

MISTRAL_7B = BAMBOO_7B.replace(
    name="mistral-7b-silu",
    activation="silu",
    sparse_ffn=SparseFFNConfig(enabled=True, mode="cats",
                               hot_ratio=0.4, cold_active_ratio=0.25),
)

TURBOSPARSE_MIXTRAL_47B = ModelConfig(
    name="turbosparse-mixtral-47b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    activation="relu2",
    num_experts=8,
    # TurboSparse's ReLUfication adds an always-on shared expert next
    # to the routed ones — the pinned hot prefix of the serving plane.
    num_shared_experts=1,
    experts_per_token=2,
    # expert-parallel over 'model' (8 experts / n shards), so the
    # serving EP goldens cover the two-level path shard-locally
    moe_shard_mode="ep",
    # the paper's headline case: the hybrid hot/cold FFN applies
    # *inside* each routed expert (DESIGN.md §9)
    moe_intra_expert=True,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="relu",
                               hot_ratio=0.2, cold_active_ratio=0.08),
)
