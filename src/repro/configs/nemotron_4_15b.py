"""Nemotron-4 15B [arXiv:2402.16819] — dense, GQA, squared-ReLU FFN.

Squared-ReLU is a ReLU-family activation (paper §2.1): natively sparse,
the PowerInfer-2 technique's home turf -> sparse_ffn mode 'relu'.
"""
from repro.configs.base import ModelConfig, SparseFFNConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="relu2",
    rope_theta=10000.0,
    sparse_ffn=SparseFFNConfig(enabled=True, mode="relu",
                               hot_ratio=0.25, cold_active_ratio=0.10),
)
