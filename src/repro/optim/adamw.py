"""Pure-JAX AdamW with configurable moment dtype.

For the 314B/405B train_4k dry-runs the moments are kept in bf16
(`moment_dtype='bfloat16'`) so the optimizer state fits the production
mesh (DESIGN.md §5); small-model training uses fp32 moments.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    moment_dtype: str = "float32"
    grad_clip: float = 1.0

    def init(self, params):
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        # global-norm clip
        if self.grad_clip:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        dt = jnp.dtype(self.moment_dtype)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
            mh = m32 / c1
            vh = v32 / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - self.lr * delta).astype(p.dtype),
                    m32.astype(dt), v32.astype(dt))

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}
