"""Sharding-constraint helpers usable from model code without a mesh.

All model code calls `constrain(x, spec)`; outside a mesh context (CPU
smoke tests) it is a no-op, inside `repro.compat.set_mesh(...)` (the
`jax.set_mesh` shim) it becomes a `with_sharding_constraint`. Axis
names: 'pod' (outer replica/data), 'data' (batch), 'model'
(tensor/expert/neuron/seq shards).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import current_mesh

__all__ = ["current_mesh", "batch_axes", "constrain", "constrain_batch",
           "BATCH"]


def batch_axes(mesh=None):
    """The axis names that shard the global batch in the current mesh."""
    m = mesh or current_mesh()
    if m is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in m.axis_names)


def _filter_spec(spec: P, mesh, shape=None) -> P:
    """Drop axis names that don't exist in the mesh, and (when `shape`
    is given) axes whose size doesn't evenly divide the dimension —
    e.g. batch=1 long-context decode replicates over 'data'."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(mesh.shape)

    def axsize(e):
        if isinstance(e, (tuple, list)):
            n = 1
            for a in e:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(e, 1)

    def keep(e, dim):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            e = kept if kept else None
        else:
            e = e if e in names else None
        if e is not None and dim is not None and dim % axsize(e) != 0:
            return None
        return e

    dims = list(shape) + [None] * (len(spec) - len(shape)) \
        if shape is not None else [None] * len(spec)
    return P(*[keep(e, d) for e, d in zip(spec, dims)])


def constrain(x, spec: P):
    m = current_mesh()
    if m is None:
        return x
    spec = _filter_spec(spec, m, shape=getattr(x, "shape", None))
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))


def constrain_batch(x):
    """Shard the leading (batch) dim over pod+data."""
    m = current_mesh()
    if m is None:
        return x
    spec = P(batch_axes(m), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))


BATCH = ("pod", "data")
