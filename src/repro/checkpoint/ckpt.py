"""Sharded checkpointing without orbax: one .npy per leaf + manifest.

Leaves are addressed by their pytree path; restore rebuilds the exact
tree. Device arrays are pulled to host; on restore, arrays are placed
with the provided sharding fn (or left on the default device).
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    name = "__".join(parts)
    return re.sub(r"[^A-Za-z0-9_.-]", "_", name)


def save_checkpoint(path: str, tree, step: int = 0):
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for p, leaf in leaves:
        name = _leaf_name(p)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(path, name + ".npy"), arr)
        manifest["leaves"].append({"name": name,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore_checkpoint(path: str, like_tree, device_put_fn=None):
    """Restore into the structure of `like_tree` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    out = []
    for p, like in paths:
        name = _leaf_name(p)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(path, name + ".npy"))
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {like.shape}")
        out.append(device_put_fn(arr) if device_put_fn else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
