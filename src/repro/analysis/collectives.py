"""Collective discipline inside shard_map bodies (DESIGN.md §3/§8/§10).

The mesh design holds every decode path to *shard-local* math plus
exactly one fp32 psum per layer (the output reduction), with id-only
all_gathers allowed on the side. Three rules make that checkable:

* collective-axis   — every collective (psum / all_gather /
                      psum_scatter / axis_index / ...) inside a
                      shard_map body names an axis bound by that
                      shard_map's `axis_names`; collectives *outside*
                      any shard_map body have no bound axis at all.
* collective-budget — no execution path through a shard_map body
                      issues more than `psum_budget` psums (default 1
                      — the one-fp32-psum-per-layer invariant; a psum
                      inside a loop counts double so a looped
                      reduction always trips).
* collective-fp32   — psum operands are explicitly reduced in fp32
                      (`.astype(jnp.float32)` somewhere in the
                      operand): XLA:CPU's AllReducePromotion crashes
                      on bf16 all-reduce inside partial-manual
                      shard_map, and fp32 reduction is the numerics
                      the goldens were recorded with.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (AnalysisConfig, Checker, Finding,
                                      SourceFile, register_checker)

# collectives whose axis argument we resolve; value = positional index
# of the axis name when not passed as axis_name=
_COLLECTIVES = {"psum": 1, "psum_scatter": 1, "all_gather": 1,
                "pmean": 1, "pmax": 1, "pmin": 1, "all_to_all": 1,
                "ppermute": 1, "axis_index": 0}
_PSUMS = ("psum", "psum_scatter")


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _iter_skip_defs(node):
    """Walk a subtree without descending into nested function/class
    definitions (their bodies only run when called)."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                continue
            stack.append(child)


def _collective_calls(node, include_defs=True):
    it = ast.walk(node) if include_defs else _iter_skip_defs(node)
    for n in it:
        if isinstance(n, ast.Call) and _call_name(n) in _COLLECTIVES:
            yield n


def _axis_consts(call: ast.Call):
    """The statically-resolvable axis names a collective call uses."""
    name = _call_name(call)
    pos = _COLLECTIVES[name]
    cands = []
    if len(call.args) > pos:
        cands.append(call.args[pos])
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis"):
            cands.append(kw.value)
    axes = []
    for c in cands:
        if isinstance(c, ast.Constant) and isinstance(c.value, str):
            axes.append(c.value)
        elif isinstance(c, (ast.Tuple, ast.List, ast.Set)):
            axes.extend(e.value for e in c.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str))
    return axes


def _count_psums(node) -> int:
    return sum(1 for c in _collective_calls(node, include_defs=False)
               if _call_name(c) in _PSUMS)


def _max_path_psums(stmts) -> tuple:
    """(max psums along any execution path, every path terminates).
    Branch-aware so exclusive if/else arms (e.g. the pallas vs jnp
    backend split, each ending in its own return) don't double-count."""
    cur = 0
    for i, s in enumerate(stmts):
        if isinstance(s, ast.If):
            t = _count_psums(s.test)
            b, bret = _max_path_psums(s.body)
            o, oret = _max_path_psums(s.orelse)
            rest, rret = _max_path_psums(stmts[i + 1:])
            outs, term = [], True
            for cnt, ret in ((b, bret), (o, oret)):
                if ret:
                    outs.append(cur + t + cnt)
                else:
                    outs.append(cur + t + cnt + rest)
                    term = term and rret
            return max(outs), term
        if isinstance(s, (ast.Return, ast.Raise)):
            return cur + _count_psums(s), True
        if isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
            body, _ = _max_path_psums(s.body)
            orelse, _ = _max_path_psums(s.orelse)
            head = s.test if isinstance(s, ast.While) else s.iter
            # a psum in a loop body may run every iteration: double it
            # so any looped reduction exceeds a budget of 1
            cur += 2 * body + orelse + _count_psums(head)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            b, bret = _max_path_psums(s.body)
            cur += b
            if bret:
                return cur, True
        elif isinstance(s, ast.Try):
            b, _ = _max_path_psums(s.body)
            h = max((_max_path_psums(x.body)[0] for x in s.handlers),
                    default=0)
            f, _ = _max_path_psums(s.finalbody)
            cur += b + h + f
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            continue
        else:
            cur += _count_psums(s)
    return cur, False


def _shard_map_sites(tree):
    """Yield (call, body_node, bound_axes) per shard_map call. The
    body is the first positional arg: a lambda inline, or a FunctionDef
    resolved by name anywhere in the module (shard_map bodies are
    defined right next to their call in this codebase)."""
    defs = {}
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[n.name] = n
    for n in ast.walk(tree):
        if not (isinstance(n, ast.Call)
                and _call_name(n).endswith("shard_map")):
            continue
        if not n.args:
            continue
        target = n.args[0]
        body = None
        if isinstance(target, ast.Lambda):
            body = target
        elif isinstance(target, ast.Name):
            body = defs.get(target.id)
        axes = set()
        spec_consts = set()
        for kw in n.keywords:
            if kw.arg == "axis_names":
                for e in ast.walk(kw.value):
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        axes.add(e.value)
            elif kw.arg in ("in_specs", "out_specs"):
                for e in ast.walk(kw.value):
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        spec_consts.add(e.value)
        if not axes:
            axes = spec_consts      # pre-axis_names shard_map fallback
        yield n, body, axes


@register_checker
class CollectiveChecker(Checker):
    name = "collectives"
    rules = ("collective-axis", "collective-budget", "collective-fp32")
    scope = ("src/repro/",)

    def check(self, src: SourceFile, config: AnalysisConfig) -> list:
        findings = []
        bodies = []          # (body_node, axes)
        in_body = set()      # ids of collective calls inside some body
        for _, body, axes in _shard_map_sites(src.tree):
            if body is None:
                continue
            bodies.append((body, axes))
            for c in _collective_calls(body):
                in_body.add(id(c))

        for body, axes in bodies:
            for c in _collective_calls(body):
                for ax in _axis_consts(c):
                    if ax not in axes:
                        findings.append(Finding(
                            "collective-axis", src.path, c.lineno,
                            f"{_call_name(c)} over axis {ax!r} which the "
                            f"enclosing shard_map does not bind "
                            f"(bound: {sorted(axes) or 'none'})"))
            stmts = body.body if not isinstance(body, ast.Lambda) else []
            n_psum, _ = _max_path_psums(stmts) if stmts else (
                _count_psums(body.body), True)
            if n_psum > config.psum_budget:
                findings.append(Finding(
                    "collective-budget", src.path, body.lineno,
                    f"shard_map body issues up to {n_psum} psums on one "
                    f"path (budget: {config.psum_budget} — DESIGN.md "
                    f"one-fp32-psum-per-layer)"))
            for c in _collective_calls(body):
                if _call_name(c) not in _PSUMS or not c.args:
                    continue
                operand = c.args[0]
                fp32 = any(isinstance(x, ast.Attribute)
                           and x.attr == "float32"
                           for x in ast.walk(operand))
                if not fp32:
                    findings.append(Finding(
                        "collective-fp32", src.path, c.lineno,
                        f"{_call_name(c)} operand is not explicitly "
                        f"reduced in fp32 (.astype(jnp.float32)) — "
                        f"bf16 all-reduce miscompiles on XLA:CPU and "
                        f"drifts from the recorded goldens"))

        for c in _collective_calls(src.tree):
            if id(c) not in in_body:
                findings.append(Finding(
                    "collective-axis", src.path, c.lineno,
                    f"{_call_name(c)} outside any shard_map body: no "
                    f"axis is bound here (collectives live in the "
                    f"shard-local bodies, DESIGN.md §3)"))
        return findings
