"""Seeded-violation self-test: proves every rule actually fires.

Each `bad_*.py` / `*_bad.py` fixture in this directory seeds specific
violations; the clean fixtures must produce zero findings (including
one whose violation carries an inline `# repro: ignore[...]`, proving
suppression end to end). `run_self_test()` analyzes the fixture set
with every checker's scope pointed here and asserts the rule<->fixture
map below — a checker whose rule stops firing (a refactor broke its
AST match) fails the self-test, not silently the repo gate.

This directory is in `framework.EXCLUDED_SEGMENTS`: fixtures are never
scanned repo-wide, never imported, never executed.
"""
from __future__ import annotations

import os

from repro.analysis.framework import (AnalysisConfig, all_rules,
                                      analyze_files)

_DIR = os.path.dirname(os.path.abspath(__file__))

# rule -> fixture file its seeded violation lives in
EXPECTED = {
    "collective-axis": "bad_collectives.py",
    "collective-budget": "bad_collectives.py",
    "collective-fp32": "bad_collectives.py",
    "dma-pairing": "bad_kernels.py",
    "semaphore-scope": "bad_kernels.py",
    "vmem-budget": "bad_kernels.py",
    "wall-clock": "bad_trace.py",
    "py-random": "bad_trace.py",
    "tracer-branch": "bad_trace.py",
    "jit-static-args": "bad_trace.py",
    "protocol-method": "bad_handle.py",
    "family-fields": "families_bad.py",
    "registry-drift": "families_bad.py",
    "bench-gate-drift": "bench_emit_bad.py",
    "trace-registry-drift": "ops_bad.py",
}

CLEAN = ("good_all.py", "suppressed.py", "conformance.py",
         "bench_gate.py", "trace_reg.py")

# unparseable source must surface as a finding, not an exception
_BROKEN = "def broken(:\n"


def fixture_config() -> AnalysisConfig:
    scopes = {name: ("selftest/",)
              for name in ("collectives", "kernel-hygiene",
                           "trace-hazards")}
    return AnalysisConfig(
        scopes=scopes,
        families_path="selftest/families_bad.py",
        conformance_path="selftest/conformance.py",
        bench_gate_path="selftest/bench_gate.py",
        bench_emitter_prefix="selftest/bench_emit",
        kernels_ops_path="selftest/ops_bad.py",
        trace_registry_path="selftest/trace_reg.py",
    )


def load_fixtures() -> dict:
    files = {}
    for fname in sorted(os.listdir(_DIR)):
        if fname.endswith(".py") and fname != "__init__.py":
            with open(os.path.join(_DIR, fname), encoding="utf-8") as fh:
                files[f"selftest/{fname}"] = fh.read()
    files["selftest/broken_syntax.py"] = _BROKEN
    return files


def run_self_test():
    """Returns (ok, report_lines)."""
    findings = analyze_files(load_fixtures(), fixture_config())
    by_file: dict = {}
    for f in findings:
        by_file.setdefault(f.path, []).append(f)

    ok, lines = True, []
    for rule in sorted(set(EXPECTED) | set(all_rules())):
        want = EXPECTED.get(rule)
        if want is None:
            ok = False
            lines.append(f"FAIL {rule}: no fixture seeds this rule")
            continue
        hits = [f for f in by_file.get(f"selftest/{want}", [])
                if f.rule == rule]
        if hits:
            lines.append(f"ok   {rule}: fires in {want} "
                         f"(line {hits[0].line})")
        else:
            ok = False
            lines.append(f"FAIL {rule}: seeded violation in {want} "
                         f"did not fire")
    for fname in CLEAN:
        extra = by_file.get(f"selftest/{fname}", [])
        if extra:
            ok = False
            lines.append(f"FAIL clean fixture {fname} produced: "
                         + "; ".join(str(f) for f in extra))
        else:
            lines.append(f"ok   clean fixture {fname}: no findings")
    if any(f.rule == "syntax-error"
           for f in by_file.get("selftest/broken_syntax.py", [])):
        lines.append("ok   syntax-error: unparseable source reported "
                     "as a finding")
    else:
        ok = False
        lines.append("FAIL syntax-error: unparseable source not "
                     "reported")
    return ok, lines
