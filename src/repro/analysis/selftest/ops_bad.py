"""Seeded trace-registry-drift: exports a kernel entry point the trace
registry (trace_reg.py fixture) never names."""

__all__ = ["dense_ffn", "unregistered_kernel"]


def dense_ffn():
    pass


def unregistered_kernel():          # exported, no semantic coverage
    pass
