"""Seeded violations: family-fields (missing field, wrong call
shapes), registry-drift (family absent from the conformance fixture).
Fixture only — never imported or executed."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ServingFamily:
    family: str
    make_model: object
    make_decode_step: object
    build_plan: object
    prepare_params: object
    default_arch: str = ""


def register_family(fam):
    return fam


def _make_model(cfg):
    return cfg


def _plan_two(cfg, extra):
    return (cfg, extra)


register_family(ServingFamily(
    family="ghost",             # never named in the conformance fixture
    make_model=_make_model,
    build_plan=_plan_two,       # cannot accept (cfg, freqs=, hw=, backend=)
    prepare_params=_make_model,     # needs to accept (params, plan)
))                              # make_decode_step missing entirely
