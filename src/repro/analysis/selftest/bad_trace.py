"""Seeded violations: wall-clock, py-random, tracer-branch,
jit-static-args. Fixture only — never imported or executed."""
import functools
import random
import time

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode", "missing"))
def decode_step(x, mode="greedy"):
    y = jnp.tanh(x)
    if y:                       # Python truthiness on a traced value
        y = y + 1.0
    return y if mode == "greedy" else -y


def sample_delay():
    t0 = time.perf_counter()    # wall clock in clock-driven code
    jitter = random.random()    # global-state RNG
    return t0 + jitter
