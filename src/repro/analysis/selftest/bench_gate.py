"""Clean fixture: the trend gate's extractor table (gates 'serving'
only, so bench_emit_bad's 'rogue' kind drifts)."""


def _serving(doc):
    return doc


EXTRACTORS = {"serving": _serving}
