"""Seeded violations: collective-axis, collective-budget,
collective-fp32. Fixture only — never imported or executed."""
import jax
import jax.numpy as jnp

from repro.compat import shard_map


def local(x):
    a = jax.lax.psum(x.astype(jnp.float32), "model")
    b = jax.lax.psum(a, "data")     # wrong axis, bf16, 2nd psum on path
    return b


def build(mesh):
    return shard_map(local, mesh=mesh, in_specs=("model",),
                     out_specs=("model",), axis_names={"model"})


def stray(x):
    return jax.lax.all_gather(x, "model")   # outside any shard_map body
