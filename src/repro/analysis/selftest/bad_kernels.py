"""Seeded violations: dma-pairing, semaphore-scope, vmem-budget.
Fixture only — never imported or executed."""
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu


def leaky_kernel(x_ref, o_ref, w_hbm):
    sem = pltpu.SemaphoreType.DMA((2,))     # ad hoc, outside run_scoped
    cp = pltpu.make_async_copy(w_hbm, o_ref, sem)
    cp.start()                              # started but never waited
    o_ref[...] = x_ref[...]


def huge_scratch(body):
    return pl.run_scoped(
        body,
        buf=pltpu.VMEM((4, 4096, 4096), jnp.float32),   # ~256MiB scratch
    )
