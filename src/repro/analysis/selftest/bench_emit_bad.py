"""Seeded violation: bench-gate-drift (emits a kind the gate fixture
has no extractor for). Fixture only — never imported or executed."""


def emit():
    return {"bench": "rogue", "metrics": {"tok_s": 0.0}}
