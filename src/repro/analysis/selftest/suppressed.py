"""Clean fixture: a real violation carrying an inline ignore — proves
`# repro: ignore[rule]` suppression works end to end."""
import time


def observe():
    # observability stat, not the modeled clock
    return time.perf_counter()  # repro: ignore[wall-clock]
