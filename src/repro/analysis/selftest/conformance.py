"""Clean fixture: the conformance battery's family parametrization
(covers 'dense' only, so families_bad's 'ghost' drifts)."""

FAMILY_ARCHS = {"dense": "tiny"}
