"""Seeded violations: protocol-method (missing override, arity drift,
dropped @property). Fixture only — never imported or executed."""


class WorkerHandle:
    def submit(self, prompt, max_new, arrival_time):
        raise NotImplementedError

    def step(self):
        raise NotImplementedError

    @property
    def load(self):
        raise NotImplementedError

    def close(self):
        return None


class DriftedBackend(WorkerHandle):
    def submit(self, prompt):       # protocol declares 3 positional args
        return 0

    def load(self):                 # protocol declares this a @property
        return 0.0
