"""Clean fixture: the trace-registry side of the trace-registry-drift
pair — names dense_ffn but not ops_bad.py's unregistered_kernel."""

KERNEL_ENTRY_POINTS = ("dense_ffn",)
