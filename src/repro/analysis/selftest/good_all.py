"""Clean fixture: disciplined collectives, paired DMA, scoped
semaphores, small scratch — must produce zero findings."""
import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu


def local(x):
    y = jax.lax.psum(x.astype(jnp.float32), "model")
    idx = jax.lax.all_gather(y, "model")
    return y, idx


def build(mesh, shard_map):
    return shard_map(local, mesh=mesh, in_specs=("model",),
                     out_specs=("model",), axis_names={"model"})


def pipelined(x_ref, o_ref, w_hbm):
    def body(buf, sem):
        cp = pltpu.make_async_copy(w_hbm, buf.at[0], sem.at[0])
        cp.start()
        cp.wait()
        o_ref[...] = x_ref[...] + buf[0]

    return pl.run_scoped(
        body,
        buf=pltpu.VMEM((2, 256, 128), jnp.float32),
        sem=pltpu.SemaphoreType.DMA((2,)),
    )
