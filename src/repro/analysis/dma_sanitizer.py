"""DMA race sanitizer: shadow-state machine for the pallas cold kernels.

Interpret mode executes `pltpu.make_async_copy` synchronously, so a
missing/wrong `wait()` or a premature slot reuse is *invisible* to
every CPU test — the data is always there. On a real TPU the same bug
is a race: compute reads a VMEM slot whose copy hasn't landed. This
module re-executes the kernel body eagerly with the pallas surface
swapped for shadow objects that track every VMEM buffer slot through
idle -> in-flight -> ready and flag the §4.3 pipeline's race classes:

* dma-start-without-wait — start() on a slot whose previous copy was
    never waited on (premature slot reuse; the in-flight copy is lost).
* dma-double-wait — wait() with no copy in flight (double wait, or a
    wait paired with a different semaphore than the start signaled).
* dma-slot-overwrite — direct compute write to a slot while a copy
    into it is in flight.
* dma-read-not-ready — compute read of a slot that is not ready (the
    dropped-wait race: garbage on real hardware).
* dma-inflight-at-exit — a copy still in flight when its run_scoped
    scope ends (its semaphore leaks past the kernel).
* dma-shadow-fidelity — the shadow execution's outputs diverged from
    the real interpret-mode kernel: the harness itself rotted and its
    race verdicts can no longer be trusted.

The harness patches the *target module's* `pl` / `pltpu` / `jax`
globals (restored on exit), so the real `_fused_kernel` body runs
unmodified — what is sanitized is exactly the shipped kernel, swept
over every storage dtype including the int4 sidecar's paired
descriptors (sweep_fused_cold_ffn). Seeded mutant kernels in
semantic_selftest.py prove each race class still fires.
"""
from __future__ import annotations

import contextlib
import sys

import numpy as np

from repro.analysis.framework import Finding

__all__ = ["DMA_RULES", "Sanitizer", "PlainRef", "HBMRef",
           "shadow_env", "run_fused_shadow", "run_mini_shadow",
           "fidelity_findings", "sweep_fused_cold_ffn"]

DMA_RULES = ("dma-start-without-wait", "dma-double-wait",
             "dma-slot-overwrite", "dma-read-not-ready",
             "dma-inflight-at-exit", "dma-shadow-fidelity")

IDLE, INFLIGHT, READY = "idle", "in-flight", "ready"


class Sanitizer:
    """Finding collector + per-grid-step state shared by the shadows."""

    def __init__(self, case: str):
        self.case = case
        self.findings: list = []
        self.program_id = 0

    def report(self, rule: str, message: str):
        self.findings.append(
            Finding(rule, f"semantic/{self.case}", 1,
                    f"[grid step {self.program_id}] {message}"))


# ------------------------------------------------------- shadow refs ----

class PlainRef:
    """Untracked mutable block ref (x/a/b/mask/y/idx blocks) backed by
    a numpy array — kernels read/write it like a pallas Ref."""

    def __init__(self, arr):
        self._a = np.array(arr)

    shape = property(lambda self: self._a.shape)
    dtype = property(lambda self: self._a.dtype)
    value = property(lambda self: self._a)

    def __getitem__(self, ix):
        return self._a[ix]

    def __setitem__(self, ix, val):
        self._a[ix] = np.asarray(val)

    def __jax_array__(self):          # jnp.zeros_like(y_ref) etc.
        import jax.numpy as jnp
        return jnp.asarray(self._a)


class _DS:
    """Shadow pl.ds: a (start, size) row window."""

    def __init__(self, start, size):
        self.start, self.size = int(start), int(size)


class _SrcSlice:
    def __init__(self, arr, ds):
        self._arr, self._ds = arr, ds

    def read(self):
        if self._ds is None:
            return self._arr.copy()
        return self._arr[self._ds.start:self._ds.start + self._ds.size].copy()


class HBMRef:
    """HBM-resident operand: only `.at[pl.ds(...)]` source windows."""

    def __init__(self, arr):
        self._a = np.asarray(arr)

    shape = property(lambda self: self._a.shape)
    dtype = property(lambda self: self._a.dtype)

    @property
    def at(self):
        return _HBMAt(self._a)


class _HBMAt:
    def __init__(self, arr):
        self._arr = arr

    def __getitem__(self, ix):
        return _SrcSlice(self._arr, ix if isinstance(ix, _DS) else None)


def _slot_of(ix):
    """Leading-axis slot index of a ref access, or None for whole-
    buffer access."""
    if isinstance(ix, tuple):
        ix = ix[0] if ix else None
    if ix is None or ix is Ellipsis or isinstance(ix, slice):
        return None
    try:
        return int(ix)
    except (TypeError, ValueError):
        return None


class TrackedVMEM:
    """Double-buffer scratch: slot states on the leading axis."""

    def __init__(self, san: Sanitizer, name: str, shape, dtype):
        self.san, self.name = san, name
        self._a = np.zeros(shape, dtype)
        self.state = [IDLE] * shape[0]
        self.pending = [None] * shape[0]      # sem key of active copy

    shape = property(lambda self: self._a.shape)
    dtype = property(lambda self: self._a.dtype)

    @property
    def at(self):
        return _VmemAt(self)

    def _slots(self, ix):
        s = _slot_of(ix)
        return range(len(self.state)) if s is None else (s,)

    def __getitem__(self, ix):
        for s in self._slots(ix):
            if self.state[s] != READY:
                self.san.report(
                    "dma-read-not-ready",
                    f"compute reads {self.name}[{s}] while it is "
                    f"{self.state[s]} — garbage on real hardware")
        return self._a[ix]

    def __setitem__(self, ix, val):
        for s in self._slots(ix):
            if self.state[s] == INFLIGHT:
                self.san.report(
                    "dma-slot-overwrite",
                    f"compute writes {self.name}[{s}] while a copy "
                    f"into it is in flight")
        self._a[ix] = np.asarray(val)


class _VmemAt:
    def __init__(self, buf):
        self._buf = buf

    def __getitem__(self, slot):
        return _DstSlot(self._buf, int(slot))


class _DstSlot:
    def __init__(self, buf, slot):
        self.buf, self.slot = buf, slot


class ShadowSem:
    def __init__(self, name: str):
        self.name = name

    @property
    def at(self):
        return _SemAt(self)


class _SemAt:
    def __init__(self, sem):
        self._sem = sem

    def __getitem__(self, slot):
        return (self._sem, int(slot))


class ShadowCopy:
    """One make_async_copy descriptor driving the state machine."""

    def __init__(self, san, src, dst, sem):
        self.san, self.src, self.dst, self.sem = san, src, dst, sem

    def start(self):
        buf, slot = self.dst.buf, self.dst.slot
        if buf.state[slot] == INFLIGHT:
            self.san.report(
                "dma-start-without-wait",
                f"start() reuses {buf.name}[{slot}] while its previous "
                f"copy is still in flight")
        buf.state[slot] = INFLIGHT
        buf.pending[slot] = self.sem
        # data lands now — the *state* decides whether reads were safe
        buf._a[slot] = self.src.read()

    def wait(self):
        buf, slot = self.dst.buf, self.dst.slot
        if buf.state[slot] != INFLIGHT:
            self.san.report(
                "dma-double-wait",
                f"wait() on {buf.name}[{slot}] with no copy in flight "
                f"(state {buf.state[slot]})")
            return
        if buf.pending[slot] is not None \
                and buf.pending[slot][0] is not self.sem[0]:
            self.san.report(
                "dma-double-wait",
                f"wait() on {buf.name}[{slot}] pairs semaphore "
                f"{self.sem[0].name} with a copy started on "
                f"{buf.pending[slot][0].name}")
        buf.state[slot] = READY
        buf.pending[slot] = None


# -------------------------------------------------- shadow namespaces ----

class _VMEMSpec:
    def __init__(self, shape, dtype):
        self.shape, self.dtype = tuple(shape), np.dtype(dtype)


class _SemSpec:
    def __init__(self, shape):
        self.shape = shape


class _SemTypeNS:
    @staticmethod
    def DMA(shape):
        return _SemSpec(shape)


class _ShadowPl:
    def __init__(self, san: Sanitizer):
        self._san = san

    def program_id(self, axis):
        return self._san.program_id

    @staticmethod
    def when(cond):
        def deco(f):
            if bool(cond):
                f()
            return f
        return deco

    @staticmethod
    def ds(start, size):
        return _DS(start, size)

    def run_scoped(self, body, **kwargs):
        allocs = {}
        for name, spec in kwargs.items():
            if isinstance(spec, _VMEMSpec):
                allocs[name] = TrackedVMEM(self._san, name,
                                           spec.shape, spec.dtype)
            elif isinstance(spec, _SemSpec):
                allocs[name] = ShadowSem(name)
            else:
                raise TypeError(f"unshadowed scoped alloc {name}: "
                                f"{spec!r}")
        body(**allocs)
        for name, alloc in allocs.items():
            if not isinstance(alloc, TrackedVMEM):
                continue
            for s, st in enumerate(alloc.state):
                if st == INFLIGHT:
                    self._san.report(
                        "dma-inflight-at-exit",
                        f"{name}[{s}] copy still in flight at scope "
                        f"exit — its semaphore leaks past the kernel")


class _ShadowPltpu:
    def __init__(self, san: Sanitizer):
        self._san = san
        self.SemaphoreType = _SemTypeNS()

    @staticmethod
    def VMEM(shape, dtype):
        return _VMEMSpec(shape, dtype)

    def make_async_copy(self, src, dst, sem):
        return ShadowCopy(self._san, src, dst, sem)


class _LaxShim:
    """jax.lax with fori_loop unrolled to a Python loop so ref
    mutations execute eagerly instead of being traced away."""

    def __getattr__(self, name):
        import jax
        return getattr(jax.lax, name)

    @staticmethod
    def fori_loop(lo, hi, body, init, **_kw):
        val = init
        for i in range(int(lo), int(hi)):
            val = body(i, val)
        return val


class _JaxShim:
    lax = _LaxShim()

    def __getattr__(self, name):
        import jax
        return getattr(jax, name)


@contextlib.contextmanager
def shadow_env(module, san: Sanitizer):
    """Swap `module`'s pl/pltpu/jax globals for the shadow surface."""
    saved = {k: getattr(module, k) for k in ("pl", "pltpu", "jax")}
    module.pl = _ShadowPl(san)
    module.pltpu = _ShadowPltpu(san)
    module.jax = _JaxShim()
    try:
        yield
    finally:
        for k, v in saved.items():
            setattr(module, k, v)


# ------------------------------------------------------------ drivers ----

def run_fused_shadow(x, wc, A, Bp, *, activation: str, kc: int,
                     cats: bool = False, active_mask=None,
                     wq=None, wsc=None, wout=None, case: str = "fused"):
    """Shadow-execute the real kernels/cluster_gather_ffn._fused_kernel
    over its full grid, hand-slicing each BlockSpec window exactly as
    fused_cold_ffn's specs do. Returns (findings, y, idx)."""
    from repro.kernels import cluster_gather_ffn as cg

    x = np.asarray(x, np.float32)
    wc = np.asarray(wc)
    G, nc_g, cs, R, D = wc.shape
    B = x.shape[0]
    blk = nc_g * cs
    quant, mixed = wq is not None, wout is not None
    stored = np.asarray(wq if quant else wc)
    w_flat = stored.reshape(G * blk, R, D)
    wsc_flat = None if wsc is None else np.asarray(wsc).reshape(G * blk, R)
    wout_flat = None if wout is None else np.asarray(wout).reshape(
        G * blk, R, D)
    mask = (np.ones((B, 1), np.float32) if active_mask is None
            else np.asarray(active_mask, np.float32).reshape(B, 1))
    Bp = np.asarray(Bp)

    san = Sanitizer(case)
    y_ref = PlainRef(np.zeros((B, D), np.float32))
    idx_ref = PlainRef(np.zeros((G, kc), np.int32))
    w_hbm = HBMRef(w_flat)
    wout_hbm = None if wout_flat is None else HBMRef(wout_flat)
    with shadow_env(cg, san):
        for g in range(G):
            san.program_id = g
            refs = [PlainRef(x), w_hbm, PlainRef(np.asarray(A)),
                    PlainRef(Bp[:, g * blk:(g + 1) * blk]),
                    PlainRef(mask)]
            if quant:
                refs.append(PlainRef(wsc_flat[g * blk:(g + 1) * blk]))
                if mixed:
                    refs.append(wout_hbm)
            refs += [y_ref, idx_ref]
            cg._fused_kernel(*refs, activation=activation, gated=R == 3,
                             cats=cats, kc=kc, nc_g=nc_g, cs=cs,
                             quant=quant, mixed=mixed)
    return san.findings, y_ref.value, idx_ref.value


def run_mini_shadow(kernel, *, case: str, kc: int = 4, cs: int = 8,
                    d: int = 16, b: int = 2):
    """Drive a mini kernel (signature (x_ref, w_hbm, y_ref, *, kc, cs))
    through the shadow surface — the mutant-kernel harness. Returns
    (findings, y, x, w)."""
    module = sys.modules[kernel.__module__]
    rng = np.random.default_rng(0)
    x = rng.standard_normal((b, cs)).astype(np.float32)
    w = rng.standard_normal((kc * cs, d)).astype(np.float32)
    san = Sanitizer(case)
    y_ref = PlainRef(np.zeros((b, d), np.float32))
    with shadow_env(module, san):
        san.program_id = 0
        kernel(PlainRef(x), HBMRef(w), y_ref, kc=kc, cs=cs)
    return san.findings, y_ref.value, x, w


def fidelity_findings(case: str, got, want, idx_got=None, idx_want=None,
                      atol: float = 1e-4) -> list:
    """Compare shadow outputs against the real interpret-mode kernel's;
    divergence means the harness no longer executes the shipped math
    and its race verdicts are void."""
    findings = []
    if not np.allclose(np.asarray(got), np.asarray(want),
                       rtol=1e-4, atol=atol):
        err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
        findings.append(Finding(
            "dma-shadow-fidelity", f"semantic/{case}", 1,
            f"shadow y diverges from interpret-mode kernel "
            f"(max abs err {err:.3g})"))
    if idx_got is not None and not np.array_equal(
            np.asarray(idx_got), np.asarray(idx_want)):
        findings.append(Finding(
            "dma-shadow-fidelity", f"semantic/{case}", 1,
            "shadow cluster selection diverges from interpret-mode "
            "kernel"))
    return findings


def sweep_fused_cold_ffn() -> list:
    """Sanitize the shipped fused kernel over every storage dtype
    (incl. the int4 sidecar's paired descriptors) and both gating
    modes, with a fidelity check against interpret mode per cell."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import fused_cold_ffn

    G, nc_g, cs, R, D, r, B, kc = 2, 3, 8, 3, 16, 4, 2, 2
    ks = jax.random.split(jax.random.key(7), 6)
    x = jax.random.normal(ks[0], (B, D), jnp.float32)
    wc = jax.random.normal(ks[1], (G, nc_g, cs, R, D), jnp.float32)
    A = jax.random.normal(ks[2], (D, r), jnp.float32)
    Bp = jax.random.normal(ks[3], (r, G * nc_g * cs), jnp.float32)
    wq = jax.random.randint(ks[4], wc.shape, -127, 128).astype(jnp.int8)
    wsc = jax.random.uniform(ks[5], wc.shape[:-1], jnp.float32,
                             0.01, 0.1)
    wout = (wq.astype(jnp.float16) * 0.01).astype(jnp.float16)

    cells = [("fp16", False, {}), ("fp16-cats", True, {}),
             ("int8", False, {"wq": wq, "wsc": wsc}),
             ("int4-mixed", False, {"wq": wq, "wsc": wsc,
                                    "wout": wout})]
    findings = []
    for name, cats, quant in cells:
        case = f"dma/fused_cold_ffn/{name}"
        got, y, idx = run_fused_shadow(
            x, wc, A, Bp, activation="silu", kc=kc, cats=cats,
            case=case, **quant)
        findings.extend(got)
        y_ref, idx_ref = fused_cold_ffn(
            x, wc, A, Bp, activation="silu",
            mode="cats" if cats else "relu", kc=kc, interpret=True,
            **quant)
        findings.extend(fidelity_findings(
            case, y, y_ref, idx_got=idx, idx_want=idx_ref))
    return findings
