"""Checker framework: findings, suppression, registry, allowlist.

A checker is a small AST pass owning one or more *rules*. File
checkers run per source file (scoped by path patterns); repo checkers
run once over the whole parsed file set (cross-file invariants like
protocol conformance and registry drift).

Suppression is two-tier, mirroring the repo's other gates:

* inline — a `# repro: ignore[rule]` comment on the finding's line
  (or the line above it) suppresses that rule there; `ignore[*]`
  suppresses every rule. Inline ignores are for *intentional*
  violations and should carry a one-line justification.
* allowlist — a committed JSON file mapping "path:rule" keys to a
  reason, for bulk-ratcheting legacy findings. Like
  tests/known_failures.json, the allowlist only ratchets forward:
  an entry that no longer matches any finding is *stale* and fails
  the gate until pruned (scripts/repro_analyze.py --update).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

__all__ = ["Finding", "SourceFile", "AnalysisConfig", "Checker",
           "RepoChecker", "register_checker", "checkers", "all_rules",
           "analyze_files", "analyze_paths", "analyze_source",
           "apply_allowlist", "iter_python_files"]

# paths containing any of these segments are never scanned repo-wide
EXCLUDED_SEGMENTS = ("__pycache__", "analysis/selftest")

_IGNORE_RE = re.compile(r"#\s*repro:\s*ignore\[([^\]]+)\]")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str        # repo-relative posix path
    line: int        # 1-based
    message: str

    @property
    def key(self) -> str:
        """Allowlist key: line-insensitive so line churn above a
        ratcheted finding does not invalidate the entry."""
        return f"{self.path}:{self.rule}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class SourceFile:
    """One parsed source file plus its inline suppressions."""

    def __init__(self, path: str, text: str):
        self.path = path.replace(os.sep, "/")
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.ignores: dict = {}          # line (1-based) -> set of rules
        for i, raw in enumerate(text.splitlines(), start=1):
            m = _IGNORE_RE.search(raw)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")}
                self.ignores[i] = rules

    def suppressed(self, finding: Finding) -> bool:
        for line in (finding.line, finding.line - 1):
            rules = self.ignores.get(line)
            if rules and (finding.rule in rules or "*" in rules):
                return True
        return False


def _default_dims() -> dict:
    # worst-case symbolic dims for the static VMEM estimate: serving
    # bucket batches stay small, cluster tiles are 128-row MXU-aligned,
    # d_model caps at the largest config the kernels serve. Unresolvable
    # dims (attribute/subscript shapes) fall back to default_dim each.
    return {"B": 16, "D": 2048, "d": 2048, "d_model": 2048, "cs": 256,
            "cluster_size": 256, "R": 3, "r": 64, "rank": 64,
            "nc_g": 64, "kc": 8, "G": 8, "groups": 8}


@dataclass
class AnalysisConfig:
    """Tunables shared by the checkers. `scopes` overrides a checker's
    default path patterns (the self-test points every checker at its
    fixture files through this)."""
    psum_budget: int = 1                 # max psums per shard_map body path
    vmem_cap_bytes: int = 16 * 1024 * 1024   # one TPU core's VMEM
    dim_assumptions: dict = field(default_factory=_default_dims)
    default_dim: int = 128               # unresolvable symbolic dim
    dtype_bytes: int = 4                 # estimate dtype (fp32 worst case)
    scopes: dict = field(default_factory=dict)   # checker name -> patterns
    # repo-checker inputs (repo-relative); drift/protocol read these
    families_path: str = "src/repro/serving/families.py"
    conformance_path: str = "tests/test_family_conformance.py"
    bench_gate_path: str = "scripts/check_bench_trend.py"
    bench_emitter_prefix: str = "benchmarks/"
    kernels_ops_path: str = "src/repro/kernels/ops.py"
    trace_registry_path: str = "src/repro/analysis/trace_registry.py"


class Checker:
    """Per-file AST pass. Subclasses set `name`, `rules`, default
    `scope` (path substrings; empty = every file) and implement
    `check`."""
    name: str = ""
    rules: tuple = ()
    scope: tuple = ()

    def applies(self, path: str, config: AnalysisConfig) -> bool:
        patterns = config.scopes.get(self.name, self.scope)
        if not patterns:
            return True
        return any(p in path for p in patterns)

    def check(self, src: SourceFile, config: AnalysisConfig) -> list:
        raise NotImplementedError


class RepoChecker:
    """Whole-tree pass over every parsed file (cross-file rules)."""
    name: str = ""
    rules: tuple = ()

    def check_repo(self, files: dict, config: AnalysisConfig) -> list:
        raise NotImplementedError


_CHECKERS: list = []


def register_checker(cls):
    """Class decorator: instantiate and register a checker."""
    _CHECKERS.append(cls())
    return cls


def checkers() -> list:
    _ensure_loaded()
    return list(_CHECKERS)


def all_rules() -> tuple:
    return tuple(sorted({r for c in checkers() for r in c.rules}))


def _ensure_loaded():
    # import the checker modules for their registration side effects
    from repro.analysis import (collectives, drift, kernel_hygiene,  # noqa: F401
                                protocol, trace_hazards)


# ------------------------------------------------------------ running ----

def analyze_files(files: dict, config: AnalysisConfig = None) -> list:
    """Run every applicable checker over {path: source_text}. Returns
    findings not suppressed inline, sorted by (path, line, rule)."""
    config = config or AnalysisConfig()
    parsed: dict = {}
    findings: list = []
    for path, text in files.items():
        try:
            parsed[path] = SourceFile(path, text)
        except SyntaxError as e:
            findings.append(Finding("syntax-error", path,
                                    e.lineno or 1, str(e.msg)))
    for checker in checkers():
        if isinstance(checker, RepoChecker):
            findings.extend(checker.check_repo(parsed, config))
        else:
            for path, src in parsed.items():
                if checker.applies(path, config):
                    findings.extend(checker.check(src, config))
    kept = [f for f in findings
            if f.path not in parsed or not parsed[f.path].suppressed(f)]
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))


def analyze_source(text: str, path: str,
                   config: AnalysisConfig = None) -> list:
    """Single-snippet entry for unit tests: file checkers only (repo
    checkers need the cross-file context analyze_files provides)."""
    config = config or AnalysisConfig()
    src = SourceFile(path, text)
    findings = []
    for checker in checkers():
        if isinstance(checker, RepoChecker):
            continue
        if checker.applies(src.path, config):
            findings.extend(checker.check(src, config))
    return sorted((f for f in findings if not src.suppressed(f)),
                  key=lambda f: (f.line, f.rule))


def iter_python_files(root: str, paths: list = None):
    """Yield (repo-relative path, absolute path) for every scannable
    .py file under `paths` (repo-relative; default: the whole tree)."""
    roots = paths or ["."]
    seen = set()
    for rel_root in roots:
        top = os.path.join(root, rel_root)
        if os.path.isfile(top):
            cands = [top]
        else:
            cands = []
            for dirpath, dirnames, filenames in os.walk(top):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if not d.startswith(".") and d != "__pycache__")
                cands.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames)
                             if f.endswith(".py"))
        for abspath in cands:
            rel = os.path.relpath(abspath, root).replace(os.sep, "/")
            if rel in seen:
                continue
            if any(seg in rel for seg in EXCLUDED_SEGMENTS):
                continue
            seen.add(rel)
            yield rel, abspath


def analyze_paths(root: str, paths: list = None,
                  config: AnalysisConfig = None) -> list:
    files = {}
    for rel, abspath in iter_python_files(root, paths):
        with open(abspath, encoding="utf-8") as f:
            files[rel] = f.read()
    return analyze_files(files, config)


# ---------------------------------------------------------- allowlist ----

def apply_allowlist(findings: list, allow: dict) -> tuple:
    """Split findings against an allowlist of {key: reason}. Returns
    (kept, allowed, stale_keys): `kept` must be fixed, `allowed` are
    ratcheted, `stale_keys` no longer match anything and fail the gate
    until pruned (ratchet semantics, scripts/_ratchet.py)."""
    kept, allowed, used = [], [], set()
    for f in findings:
        if f.key in allow:
            allowed.append(f)
            used.add(f.key)
        else:
            kept.append(f)
    stale = sorted(set(allow) - used)
    return kept, allowed, stale
