"""Registry of traceable entry points for the semantic analysis tier.

Every clock-driven entry point the serving stack stages — the kernel
wrappers in kernels/ops.py, the jnp and pallas cold paths
(core/sparse_ffn.ffn_hybrid, whose shard_map body carries the one
per-layer psum), and every ServingFamily's decode step — is registered
here as a TraceEntry: a lazy builder returning (fn, args) plus the
entry's *declared* collective budget. jaxpr_rules traces each entry to
a ClosedJaxpr under its declared mesh and asserts the declaration.

Coverage is the grid the golden tests sample: representative plan
buckets (core/adaptation.DEFAULT_BUCKETS) x mesh shapes tp/ep in
{1, 2} x cold-path backends (each family's ServingFamily.backends)
x storage dtypes for the fused kernel. Entries needing more devices
than the process has are skipped by `entries()` — the CI semantic job
forces 8 host devices so the full grid runs there.

The KERNEL_ENTRY_POINTS tuple below is the drift anchor: the AST rule
trace-registry-drift (drift.py) fails the gate when kernels/ops.py
exports an entry point not named here — a new kernel cannot ship
without semantic coverage, mirroring the family/bench drift rules.

Declared budgets (verified ground truth, not aspiration):
tp1/ep1 traces contain zero collectives (no mesh, no shard_map);
tp2/ep2 dense and vlm traces contain exactly one f32 psum (the cold
path's output reduction, inside the layer scan body = once per layer)
plus one integer all_gather (the selected-cluster ids); moe ep2
contains the one f32 psum only (expert combine; ids stay local).
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["TraceEntry", "KERNEL_ENTRY_POINTS", "entries",
           "entry_names"]

# one name per kernels/ops.py __all__ export — the trace-registry-drift
# AST rule matches these literals against that __all__
KERNEL_ENTRY_POINTS = ("cluster_gather_ffn", "cluster_gather_ffn_grouped",
                       "fused_cold_ffn", "dense_ffn")


@dataclass(frozen=True)
class TraceEntry:
    """One traceable entry point plus its declared post-trace facts."""
    name: str                      # e.g. "decode/dense/jnp/tp2/b4"
    build: Callable                # () -> (fn, example_args)
    n_devices: int = 1             # mesh 'model' axis size (1 = no mesh)
    psums: int = 0                 # exact psum count the trace must show
    all_gathers: int = 0           # exact all_gather count
    clock_driven: bool = True      # jaxpr-callback rule applies
    const_cap_bytes: int = 1 << 20
    trace_ctx: Callable = None     # extra context-manager factory

    def trace(self):
        """Stage to a ClosedJaxpr under the declared mesh."""
        from repro.compat import set_mesh
        from repro.launch.mesh import make_serving_mesh
        fn, args = self.build()
        mesh = (make_serving_mesh(self.n_devices)
                if self.n_devices > 1 else None)
        mesh_ctx = (set_mesh(mesh) if mesh is not None
                    else contextlib.nullcontext())
        extra = self.trace_ctx() if self.trace_ctx else \
            contextlib.nullcontext()
        with mesh_ctx, extra:
            return jax.make_jaxpr(fn)(*args)


# ------------------------------------------------- kernel entries ----
# tiny MXU-shaped operands: B=2 tokens, D=32, R=3 bundles, cs=8,
# G=2 groups x nc_g=3 clusters, predictor rank 4

def _kernel_operands():
    k = jax.random.key(0)
    G, nc_g, cs, R, D, r = 2, 3, 8, 3, 32, 4
    x = jnp.zeros((2, D), jnp.float32)
    wc = jax.random.normal(k, (G, nc_g, cs, R, D), jnp.float32)
    A = jnp.zeros((D, r), jnp.float32)
    Bp = jnp.zeros((r, G * nc_g * cs), jnp.float32)
    return x, wc, A, Bp


def _build_dense_ffn():
    from repro.kernels.ops import dense_ffn
    x = jnp.zeros((2, 32), jnp.float32)
    w = jnp.zeros((16, 3, 32), jnp.float32)
    return (lambda xx, ww: dense_ffn(xx, ww, activation="silu",
                                     interpret=True)), (x, w)


def _build_cluster_gather():
    from repro.kernels.ops import cluster_gather_ffn
    x = jnp.zeros((2, 32), jnp.float32)
    w = jnp.zeros((48, 3, 32), jnp.float32)
    idx = jnp.zeros((2,), jnp.int32)
    return (lambda xx, ww, ii: cluster_gather_ffn(
        xx, ww, ii, activation="silu", cluster_size=8,
        interpret=True)), (x, w, idx)


def _build_cluster_gather_grouped():
    from repro.kernels.ops import cluster_gather_ffn_grouped
    x, wc, _, _ = _kernel_operands()
    cidx = jnp.zeros((2, 2), jnp.int32)
    return (lambda xx, ww, ii: cluster_gather_ffn_grouped(
        xx, ww, ii, activation="silu", interpret=True)), (x, wc, cidx)


def _build_fused(storage_dtype: str, mode: str = "relu"):
    def build():
        from repro.kernels.ops import fused_cold_ffn
        x, wc, A, Bp = _kernel_operands()
        quant = {}
        if storage_dtype != "fp16":
            quant["wq"] = jnp.zeros(wc.shape, jnp.int8)
            quant["wsc"] = jnp.ones(wc.shape[:-1], jnp.float32)
        if storage_dtype == "int4-mixed":
            quant["wout"] = jnp.zeros(wc.shape, jnp.float16)
        fn = lambda xx, ww, aa, bb: fused_cold_ffn(  # noqa: E731
            xx, ww, aa, bb, activation="silu", mode=mode, kc=2,
            interpret=True, **quant)
        return fn, (x, wc, A, Bp)
    return build


def _kernel_entries():
    yield TraceEntry("kernel/dense_ffn", _build_dense_ffn)
    yield TraceEntry("kernel/cluster_gather_ffn", _build_cluster_gather)
    yield TraceEntry("kernel/cluster_gather_ffn_grouped",
                     _build_cluster_gather_grouped)
    for sd in ("fp16", "int8", "int4-mixed"):
        yield TraceEntry(f"kernel/fused_cold_ffn/{sd}", _build_fused(sd))
    yield TraceEntry("kernel/fused_cold_ffn/fp16-cats",
                     _build_fused("fp16", mode="cats"))


# ---------------------------------------------- cold-path entries ----

def _build_cold(backend: str, mode: str = "relu"):
    def build():
        from repro.core.clusters import make_plan
        from repro.core.sparse_ffn import ffn_hybrid, init_ffn
        D, d_ff = 32, 256
        params = init_ffn(jax.random.key(0), D, d_ff, "silu",
                          jnp.float32, predictor_rank=4)
        plan = make_plan(d_ff, 0.25, 0.25, 16, groups=4,
                         backend=backend)
        x = jnp.zeros((2, D), jnp.float32)
        fn = lambda p, xx: ffn_hybrid(  # noqa: E731
            p, xx, "silu", mode, plan, return_indices=True)
        return fn, (params, x)
    return build


def _cold_entries():
    for backend in ("jnp", "pallas"):
        for tp in (1, 2):
            n_coll = 1 if tp > 1 else 0
            yield TraceEntry(f"cold/{backend}/tp{tp}",
                             _build_cold(backend), n_devices=tp,
                             psums=n_coll, all_gathers=n_coll)
    yield TraceEntry("cold/jnp/tp2/cats", _build_cold("jnp", "cats"),
                     n_devices=2, psums=1, all_gathers=1)


# ------------------------------------------- decode-step entries ----

@functools.lru_cache(maxsize=None)
def _family_setup(family: str):
    """One tiny reduced-config model per family, shared across every
    mesh shape / bucket / backend variant of its decode entries."""
    from repro.configs import get_config
    from repro.serving.families import default_archs, serving_family
    cfg = get_config(default_archs()[family]).reduced()
    fam = serving_family(cfg)
    model = fam.make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, fam, model, params


def _build_decode(family: str, backend: str, bucket: int):
    def build():
        from repro.core.clusters import make_plan
        cfg, fam, model, params = _family_setup(family)
        plan = fam.build_plan(cfg)
        if cfg.family != "moe":
            # group-aligned bucket plans so tp in {1, 2} both divide
            # the neuron groups (the test_distributed tp pattern)
            base = make_plan(cfg.d_ff, 0.25, 0.25,
                             cfg.sparse_ffn.cluster_size, groups=4,
                             backend=backend)
            plan.plans = {b: base for b in plan.plans}
        step = fam.make_decode_step(cfg)
        pb = plan.plan_for_batch(bucket)
        tokens = jnp.zeros((bucket, 1), jnp.int32)
        cache = model.init_cache(bucket, 32)
        mask = jnp.ones((bucket,), bool)
        fn = lambda p, t, c, m: step(p, t, c, pb, m)  # noqa: E731
        return fn, (params, tokens, cache, mask)
    return build


def _decode_entries():
    from repro.core.adaptation import DEFAULT_BUCKETS
    buckets = (DEFAULT_BUCKETS[0], DEFAULT_BUCKETS[2])     # 1 and 4
    axis = {"dense": "tp", "vlm": "tp", "moe": "ep"}
    grid = [
        # (family, backend, tp, buckets) — moe psums=1/ag=0 at ep2,
        # dense/vlm psums=1/ag=1 at tp2 (id gather), all-zero at 1
        ("dense", "jnp", 1, buckets[:1]),
        ("dense", "jnp", 2, buckets),
        ("dense", "pallas", 1, buckets[:1]),
        ("dense", "pallas", 2, buckets[:1]),
        ("vlm", "jnp", 1, buckets[:1]),
        ("vlm", "jnp", 2, buckets[:1]),
        ("moe", "jnp", 1, buckets[:1]),
        ("moe", "jnp", 2, buckets[:1]),
    ]
    for family, backend, tp, bks in grid:
        for b in bks:
            psums = 1 if tp > 1 else 0
            ags = 1 if tp > 1 and family != "moe" else 0
            yield TraceEntry(
                f"decode/{family}/{backend}/{axis[family]}{tp}/b{b}",
                _build_decode(family, backend, b), n_devices=tp,
                psums=psums, all_gathers=ags)


# -------------------------------------------------------- registry ----

def entries(max_devices: int = None) -> tuple:
    """Every registered entry runnable with `max_devices` host devices
    (default: what the process actually has). Backend variants a family
    does not declare (ServingFamily.backends) are filtered out."""
    from repro.serving.families import serving_family
    limit = max_devices if max_devices is not None else \
        jax.device_count()
    out = list(_kernel_entries()) + list(_cold_entries())
    for e in _decode_entries():
        _, family, backend = e.name.split("/")[:3]
        cfg, _, _, _ = _family_setup(family)
        if backend not in serving_family(cfg).backends:
            continue
        out.append(e)
    return tuple(e for e in out if e.n_devices <= limit)


def entry_names(max_devices: int = None) -> tuple:
    return tuple(e.name for e in entries(max_devices))
