"""Config/registry drift across gate boundaries.

Two registries in this repo have a *shadow copy* that must track them
by hand: the family registry (serving/families.py) is exercised by the
conformance battery's own FAMILY_ARCHS map, and every benchmark's
`"bench": <kind>` artifact kind must be named in check_bench_trend.py's
EXTRACTORS table or its regressions sail through the trend gate
unexamined. Both drifts are invisible to the test suite (the stale
copy just silently covers less), so they are checked statically:

* registry-drift   — every family name passed to register_family(...)
                     (resolving one level of helper indirection: the
                     `family=` kwarg of the ServingFamily construction
                     inside the helper, mapped back through the
                     helper's parameters to the call-site constant)
                     appears as a string literal in the conformance
                     battery.
* bench-gate-drift — every `"bench": <kind>` emitted under
                     benchmarks/ is a key of EXTRACTORS in
                     scripts/check_bench_trend.py.

A third shadow copy arrived with the semantic tier: the trace registry
(analysis/trace_registry.py) must cover every kernel entry point
kernels/ops.py exports, or a new kernel ships without jaxpr-level
verification:

* trace-registry-drift — every name in kernels/ops.py `__all__`
                     appears as a string literal in the trace
                     registry (the KERNEL_ENTRY_POINTS anchor).
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (AnalysisConfig, Finding,
                                      RepoChecker, register_checker)

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _const_str(node):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _registered_families(tree):
    """Yield (family_name, lineno) per register_family(...) call,
    resolving one level of helper indirection."""
    defs = {n.name: n for n in ast.walk(tree) if isinstance(n, _FUNCS)}
    for call in ast.walk(tree):
        if not (isinstance(call, ast.Call)
                and _call_name(call) == "register_family" and call.args):
            continue
        arg = call.args[0]
        # direct: register_family(ServingFamily(family="dense", ...))
        if isinstance(arg, ast.Call) \
                and _call_name(arg) == "ServingFamily":
            for kw in arg.keywords:
                if kw.arg == "family":
                    name = _const_str(kw.value)
                    if name:
                        yield name, call.lineno
            continue
        # indirect: register_family(_dense_family("dense", ...))
        if not isinstance(arg, ast.Call):
            continue
        helper = defs.get(_call_name(arg))
        if helper is None:
            continue
        params = [a.arg for a in helper.args.posonlyargs
                  + helper.args.args]
        for ctor in ast.walk(helper):
            if not (isinstance(ctor, ast.Call)
                    and _call_name(ctor) == "ServingFamily"):
                continue
            for kw in ctor.keywords:
                if kw.arg != "family":
                    continue
                name = _const_str(kw.value)
                if name:                      # family="moe" in helper
                    yield name, call.lineno
                elif isinstance(kw.value, ast.Name) \
                        and kw.value.id in params:
                    # family=<param>: read the call-site argument
                    i = params.index(kw.value.id)
                    site = None
                    if i < len(arg.args):
                        site = _const_str(arg.args[i])
                    for akw in arg.keywords:
                        if akw.arg == kw.value.id:
                            site = _const_str(akw.value)
                    if site:
                        yield site, call.lineno


@register_checker
class DriftChecker(RepoChecker):
    name = "drift"
    rules = ("registry-drift", "bench-gate-drift",
             "trace-registry-drift")

    def check_repo(self, files: dict, config: AnalysisConfig) -> list:
        findings = []
        findings.extend(self._check_registry(files, config))
        findings.extend(self._check_bench_gate(files, config))
        findings.extend(self._check_trace_registry(files, config))
        return findings

    # ------------------------------------------- family registry ----
    def _check_registry(self, files: dict,
                        config: AnalysisConfig) -> list:
        fam_src = files.get(config.families_path)
        conf_src = files.get(config.conformance_path)
        if fam_src is None or conf_src is None:
            return []
        covered = {n.value for n in ast.walk(conf_src.tree)
                   if isinstance(n, ast.Constant)
                   and isinstance(n.value, str)}
        return [Finding(
            "registry-drift", config.families_path, line,
            f"family {name!r} is registered but never named in "
            f"{config.conformance_path}: the conformance battery "
            f"silently skips it")
            for name, line in _registered_families(fam_src.tree)
            if name not in covered]

    # -------------------------------------------- trace registry ----
    def _check_trace_registry(self, files: dict,
                              config: AnalysisConfig) -> list:
        ops_src = files.get(config.kernels_ops_path)
        reg_src = files.get(config.trace_registry_path)
        if ops_src is None or reg_src is None:
            return []
        exported, line = [], 1
        for n in ast.walk(ops_src.tree):
            if isinstance(n, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "__all__"
                            for t in n.targets) \
                    and isinstance(n.value, (ast.List, ast.Tuple)):
                line = n.lineno
                exported = [s for s in map(_const_str, n.value.elts) if s]
        registered = {n.value for n in ast.walk(reg_src.tree)
                      if isinstance(n, ast.Constant)
                      and isinstance(n.value, str)}
        return [Finding(
            "trace-registry-drift", config.kernels_ops_path, line,
            f"kernel entry point {name!r} is exported but not "
            f"registered in {config.trace_registry_path}: it ships "
            f"without jaxpr-level semantic coverage")
            for name in exported if name not in registered]

    # ------------------------------------------------ bench gate ----
    def _check_bench_gate(self, files: dict,
                          config: AnalysisConfig) -> list:
        gate_src = files.get(config.bench_gate_path)
        if gate_src is None:
            return []
        gated = set()
        for n in ast.walk(gate_src.tree):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Dict):
                if any(isinstance(t, ast.Name) and t.id == "EXTRACTORS"
                       for t in n.targets):
                    gated = {k.value for k in n.value.keys
                             if isinstance(k, ast.Constant)}
        findings = []
        for path, src in sorted(files.items()):
            if not path.startswith(config.bench_emitter_prefix):
                continue
            for n in ast.walk(src.tree):
                if not isinstance(n, ast.Dict):
                    continue
                for k, v in zip(n.keys, n.values):
                    if _const_str(k) == "bench":
                        kind = _const_str(v)
                        if kind and kind not in gated:
                            findings.append(Finding(
                                "bench-gate-drift", path, v.lineno,
                                f"bench kind {kind!r} has no extractor "
                                f"in {config.bench_gate_path}: its "
                                f"artifacts bypass the trend gate"))
        return findings
