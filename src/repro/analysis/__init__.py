"""repro-analyze: jax/pallas-aware static analysis (DESIGN.md §12).

The repo's three invariant families — shard-local collective
discipline (§3/§8/§10), paired DMA start/wait + VMEM-bounded double
buffering in the fused cluster kernel (§10, the executable form of the
paper's §4.3 I/O pipeline), and the deterministic event clock every
golden token-identity test leans on (§7/§11) — are *sampled* by tests
but can rot silently between the sampled points. This package proves
whole classes of those regressions absent at lint time.

Layout:
  framework.py       Finding/SourceFile/checker registry, inline
                     `# repro: ignore[rule]` suppression, allowlist
                     ratchet (scripts/_ratchet.py semantics).
  collectives.py     collective-axis / collective-budget /
                     collective-fp32 inside shard_map bodies.
  kernel_hygiene.py  dma-pairing / semaphore-scope / vmem-budget for
                     kernels/*.py.
  trace_hazards.py   wall-clock / py-random / tracer-branch /
                     jit-static-args in clock-driven + traced code.
  protocol.py        protocol-method (BackendHandle impls) /
                     family-fields (ServingFamily registrations).
  drift.py           registry-drift (families vs conformance battery) /
                     bench-gate-drift (BENCH kinds vs trend gate).
  selftest/          seeded-violation fixtures proving every rule
                     fires (scripts/repro_analyze.py --self-test);
                     excluded from repo-wide scans.

Entry point: scripts/repro_analyze.py (CI `static-analysis` job).
"""
from repro.analysis.framework import (
    AnalysisConfig, Finding, SourceFile, all_rules, analyze_files,
    analyze_paths, analyze_source, apply_allowlist, checkers,
)

__all__ = ["AnalysisConfig", "Finding", "SourceFile", "all_rules",
           "analyze_files", "analyze_paths", "analyze_source",
           "apply_allowlist", "checkers"]
