"""Jaxpr-level invariant rules — the semantic half of repro-analyze.

The AST tier (collectives.py etc.) pattern-matches source; this tier
checks the *traced program*: each registered entry point
(trace_registry.py) is staged to a ClosedJaxpr under its declared mesh
and the rules below assert post-trace facts XLA will actually compile.
Where the AST psum counter is branch-heuristic, these counts are ground
truth — a psum inside a layer `scan` body appears exactly once in the
trace, i.e. once per layer.

Rules (each fires as a Finding with path "semantic/<entry name>"):

* jaxpr-collective-count — exact psum / all_gather equation counts
    match the entry's declaration (tp1 paths declare zero, tp2/ep2
    paths declare the single per-layer output reduction + the id
    gather the jnp cold path emits). Any extra collective is a §3 mesh
    -discipline regression; any missing one means the path silently
    stopped reducing across shards.
* jaxpr-collective-fp32 — every psum operand is float32 (XLA:CPU's
    bf16 all-reduce promotion crash, and reduction precision); every
    all_gather operand is integer (the cold path only gathers cluster
    *ids* — gathering activations would reintroduce the traffic the
    shard-local design removed).
* jaxpr-f64 — no float64/complex128 aval anywhere in the trace and no
    f64 captured const: a weak-type promotion to f64 doubles every
    buffer on the serving path.
* jaxpr-callback — no pure_callback / io_callback / debug_callback
    equation in clock-driven entries: a host callback inside a decode
    step stalls the device stream the deterministic event clock prices.
* jaxpr-const-capture — total bytes of consts closed over by the trace
    stay under the entry's cap: a weight array baked into the jaxpr is
    silently duplicated into every executable the bucket table holds.
* jaxpr-trace-error — the entry failed to trace at all (build or
    make_jaxpr raised); surfaced as a finding so the gate reports the
    broken registration instead of crashing.
"""
from __future__ import annotations

from repro.analysis.framework import Finding

__all__ = ["JAXPR_RULES", "iter_eqns", "collect_consts", "check_trace",
           "run_entries"]

JAXPR_RULES = ("jaxpr-collective-count", "jaxpr-collective-fp32",
               "jaxpr-f64", "jaxpr-callback", "jaxpr-const-capture",
               "jaxpr-trace-error")

# collective primitive names across jax releases (newer jax splits
# psum into variant primitives; match the closed set, not a prefix,
# so psum_scatter never counts as the output reduction)
_PSUM = {"psum", "psum2", "psum_invariant"}
_ALL_GATHER = {"all_gather", "all_gather_invariant"}
_CALLBACK = {"pure_callback", "io_callback", "debug_callback"}


def _subjaxprs(val):
    """Yield any jaxpr nested in one eqn param value (pjit/scan/cond
    carry ClosedJaxprs, shard_map a bare Jaxpr, cond a tuple)."""
    vals = val if isinstance(val, (tuple, list)) else (val,)
    for v in vals:
        inner = getattr(v, "jaxpr", v)       # ClosedJaxpr -> Jaxpr
        if hasattr(inner, "eqns"):
            yield v, inner


def iter_eqns(jaxpr):
    """Depth-first over every equation, descending into subjaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for _, inner in _subjaxprs(val):
                yield from iter_eqns(inner)


def collect_consts(closed):
    """Every const captured by the trace, top-level and nested
    (deduped by identity: nested ClosedJaxprs often alias the same
    buffers the outer trace closes over)."""
    seen, out = set(), []

    def visit(node):
        for c in getattr(node, "consts", ()):
            if id(c) not in seen:
                seen.add(id(c))
                out.append(c)
        inner = getattr(node, "jaxpr", node)
        for eqn in getattr(inner, "eqns", ()):
            for val in eqn.params.values():
                for closed_sub, _ in _subjaxprs(val):
                    visit(closed_sub)

    visit(closed)
    return out


def _is_f64(aval) -> bool:
    dt = str(getattr(aval, "dtype", ""))
    return dt in ("float64", "complex128")


def check_trace(entry, closed) -> list:
    """Run every jaxpr rule over one traced entry. `entry` is a
    trace_registry.TraceEntry; `closed` its ClosedJaxpr."""
    path = f"semantic/{entry.name}"
    findings = []
    n_psum = n_ag = 0
    bad_dtypes, f64_hit, callbacks = [], None, []
    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in _PSUM:
            n_psum += 1
            for v in eqn.invars:
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None and "float" in str(dt) \
                        and str(dt) != "float32":
                    bad_dtypes.append(f"psum over {dt}")
        elif name in _ALL_GATHER:
            n_ag += 1
            for v in eqn.invars:
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None and not ("int" in str(dt)
                                           or str(dt) == "bool"):
                    bad_dtypes.append(f"all_gather over {dt}")
        elif name in _CALLBACK or "callback" in name:
            callbacks.append(name)
        if f64_hit is None:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and _is_f64(aval):
                    f64_hit = f"{name} touches {aval.dtype}"
                    break

    if (n_psum, n_ag) != (entry.psums, entry.all_gathers):
        findings.append(Finding(
            "jaxpr-collective-count", path, 1,
            f"traced {n_psum} psum / {n_ag} all_gather, declared "
            f"{entry.psums} / {entry.all_gathers}: the per-layer "
            f"collective budget drifted (§3 mesh discipline)"))
    for msg in bad_dtypes:
        findings.append(Finding(
            "jaxpr-collective-fp32", path, 1,
            f"{msg}: psums must reduce in f32, all_gathers must move "
            f"integer ids only"))
    if f64_hit is None:
        for c in collect_consts(closed):
            if _is_f64(c):
                f64_hit = f"captured const of dtype {c.dtype}"
                break
    if f64_hit:
        findings.append(Finding(
            "jaxpr-f64", path, 1,
            f"{f64_hit}: f64 promotion doubles every serving buffer"))
    if entry.clock_driven:
        for name in sorted(set(callbacks)):
            findings.append(Finding(
                "jaxpr-callback", path, 1,
                f"{name} traced into clock-driven code: host callbacks "
                f"stall the decode stream"))
    const_bytes = sum(getattr(c, "nbytes", 0)
                      for c in collect_consts(closed))
    if const_bytes > entry.const_cap_bytes:
        findings.append(Finding(
            "jaxpr-const-capture", path, 1,
            f"trace closes over {const_bytes} const bytes "
            f"(cap {entry.const_cap_bytes}): closure-baked arrays are "
            f"duplicated into every bucket executable"))
    return findings


def run_entries(entries) -> list:
    """Trace and check each entry; a trace failure becomes a
    jaxpr-trace-error finding rather than an exception."""
    findings = []
    for entry in entries:
        try:
            closed = entry.trace()
        except Exception as e:           # noqa: BLE001 - surfaced as finding
            findings.append(Finding(
                "jaxpr-trace-error", f"semantic/{entry.name}", 1,
                f"entry failed to trace: {type(e).__name__}: {e}"))
            continue
        findings.extend(check_trace(entry, closed))
    return findings
