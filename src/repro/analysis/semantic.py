"""Semantic analysis tier front door (--tier semantic).

Two sub-analyses, both operating on *staged computation* rather than
source text (DESIGN.md §14):

* jaxpr invariant verification — trace every registered entry point
  (trace_registry) to a ClosedJaxpr and assert its declared collective
  budget, fp32 reduce dtypes, no f64 promotion, no host callbacks in
  clock-driven code, no large captured constants (jaxpr_rules);
* the pallas DMA race sanitizer — shadow-execute the fused cold-FFN
  kernel sweep and flag async-copy state-machine violations
  (dma_sanitizer).

Findings flow through the same allowlist/ratchet machinery as the AST
tier; keys look like `semantic/<entry>:<rule>` and
`semantic/dma/<case>:<rule>`.

Import cost: this module (and everything it pulls in) imports jax and
traces real models — the CLI only imports it when a semantic tier is
requested, keeping `--tier ast` install-free. Callers that want the
full mesh grid must set XLA_FLAGS=--xla_force_host_platform_device_count=8
before the first jax import (scripts/repro_analyze.py does).
"""
from __future__ import annotations


def semantic_rules() -> tuple:
    from repro.analysis import dma_sanitizer, jaxpr_rules
    return tuple(jaxpr_rules.JAXPR_RULES) + tuple(dma_sanitizer.DMA_RULES)


def semantic_findings() -> list:
    """Run both semantic analyses over the live registry; sorted
    Finding list (same contract as framework.analyze_files)."""
    from repro.analysis import dma_sanitizer, jaxpr_rules, trace_registry
    findings = list(jaxpr_rules.run_entries(trace_registry.entries()))
    findings.extend(dma_sanitizer.sweep_fused_cold_ffn())
    return sorted(findings, key=lambda f: (f.path, f.rule, f.line))


def run_self_test():
    """(ok, lines): every semantic rule fires on its seeded fixture."""
    from repro.analysis.semantic_selftest import run_semantic_self_test
    return run_semantic_self_test()
