"""Pallas kernel hygiene (DESIGN.md §10, the paper's §4.3 pipeline).

The fused cluster kernel hand-drives its I/O: explicit
`pltpu.make_async_copy` DMAs double-buffered through a VMEM scratch,
synchronized by DMA semaphores. Nothing at trace time catches a DMA
that is started and never waited (a race on the destination buffer) or
a scratch that outgrows VMEM (a compile failure only on real TPUs —
CI runs interpret mode, which happily "allocates" anything). Three
rules:

* dma-pairing     — every DMA descriptor (a direct make_async_copy or
                    a local helper returning one) has both `.start()`
                    and `.wait()` call sites in its defining top-level
                    function; a start-only descriptor races its
                    consumer, a wait-only one deadlocks, an unused one
                    is dead I/O code.
* semaphore-scope — DMA semaphores are allocated only through
                    `pl.run_scoped(...)` (or pallas_call
                    scratch_shapes), never ad hoc: scoped allocation
                    is what guarantees the semaphore outlives every
                    in-flight copy that signals it.
* vmem-budget     — a static estimate of each top-level function's
                    VMEM footprint (run_scoped VMEM allocations +
                    BlockSpec tile shapes; dims resolved from literals
                    and the configured symbol assumptions, x dtype
                    bytes — buffer slots are just the leading shape
                    dim) stays under `vmem_cap_bytes`.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (AnalysisConfig, Checker, Finding,
                                      SourceFile, register_checker)

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _attr_name(func) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _is_make_async_copy(node) -> bool:
    return isinstance(node, ast.Call) \
        and _attr_name(node.func) == "make_async_copy"


def _iter_skip_defs(node):
    """Walk without descending into nested function/class defs."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, _FUNCS + (ast.Lambda, ast.ClassDef)):
                continue
            stack.append(child)


def _returns_dma(fn) -> bool:
    """Does this function's *own* body (nested defs excluded) return a
    make_async_copy descriptor?"""
    return any(isinstance(n, ast.Return) and _is_make_async_copy(n.value)
               for n in _iter_skip_defs(fn))


def _resolve_dims(node, config: AnalysisConfig) -> list:
    """Flatten a shape expression into a list of estimated dims."""
    if isinstance(node, (ast.Tuple, ast.List)):
        dims = []
        for e in node.elts:
            dims.extend(_resolve_dims(e, config))
        return dims
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        # tuple concatenation, e.g. (2, cs) + w.shape[1:]
        return (_resolve_dims(node.left, config)
                + _resolve_dims(node.right, config))
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, ast.Name):
        return [config.dim_assumptions.get(node.id, config.default_dim)]
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        left = _resolve_dims(node.left, config)
        right = _resolve_dims(node.right, config)
        if len(left) == 1 and len(right) == 1:
            return [left[0] * right[0]]
    # attribute / subscript / call: not statically resolvable
    return [config.default_dim]


def _shape_bytes(node, config: AnalysisConfig) -> int:
    total = config.dtype_bytes
    for d in _resolve_dims(node, config):
        total *= max(int(d), 1)
    return total


@register_checker
class KernelHygieneChecker(Checker):
    name = "kernel-hygiene"
    rules = ("dma-pairing", "semaphore-scope", "vmem-budget")
    scope = ("src/repro/kernels/",)

    def check(self, src: SourceFile, config: AnalysisConfig) -> list:
        findings = []
        tops = [n for n in src.tree.body if isinstance(n, _FUNCS)]
        for cls in src.tree.body:
            if isinstance(cls, ast.ClassDef):
                tops.extend(n for n in cls.body if isinstance(n, _FUNCS))
        for fn in tops:
            findings.extend(self._check_dma(fn, src))
            findings.extend(self._check_vmem(fn, src, config))
        findings.extend(self._check_semaphores(src))
        return findings

    # ------------------------------------------------- dma pairing ----
    def _check_dma(self, fn, src: SourceFile) -> list:
        """Pair every DMA descriptor constructed anywhere under `fn`
        (helpers may be nested arbitrarily deep — the fused kernel
        defines its constructor inside a run_scoped body) with its
        .start()/.wait() call sites in the same top-level function."""
        helpers = {d.name: d for d in ast.walk(fn)
                   if isinstance(d, _FUNCS) and d is not fn
                   and _returns_dma(d)}
        helper_nodes = set()
        for d in helpers.values():
            helper_nodes.update(id(n) for n in _iter_skip_defs(d))

        def ctor_identity(call):
            if not isinstance(call, ast.Call):
                return None
            if _is_make_async_copy(call):
                return "<make_async_copy>"
            name = _attr_name(call.func)
            return name if name in helpers else None

        started, waited, seen = {}, {}, {}
        assigned = {}          # var name -> identity
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
                ident = ctor_identity(n.value)
                if ident is not None:
                    for t in n.targets:
                        if isinstance(t, ast.Name):
                            assigned[t.id] = ident
                            seen.setdefault(ident, n.lineno)
            if not isinstance(n, ast.Call):
                continue
            op = _attr_name(n.func)
            ident = None
            if isinstance(n.func, ast.Attribute):
                base = n.func.value
                if isinstance(base, ast.Call):
                    ident = ctor_identity(base)
                elif isinstance(base, ast.Name):
                    ident = assigned.get(base.id)
            if ident is not None:
                seen.setdefault(ident, n.lineno)
                if op == "start":
                    started[ident] = n.lineno
                elif op == "wait":
                    waited[ident] = n.lineno
            cident = ctor_identity(n)
            # a make_async_copy inside a helper's own body is that
            # helper's descriptor, not an anonymous one
            if cident == "<make_async_copy>" and id(n) in helper_nodes:
                cident = None
            if cident is not None:
                seen.setdefault(cident, n.lineno)
        for h, hdef in helpers.items():
            seen.setdefault(h, hdef.lineno)

        findings = []
        for ident, line in sorted(seen.items(), key=lambda kv: kv[1]):
            has_start, has_wait = ident in started, ident in waited
            if has_start and has_wait:
                continue
            label = (f"DMA helper {ident!r}" if ident in helpers
                     else "make_async_copy descriptor")
            if has_start:
                msg = (f"{label} in {fn.name} is .start()ed but never "
                       f".wait()ed: the copy races its consumer")
            elif has_wait:
                msg = (f"{label} in {fn.name} is .wait()ed but never "
                       f".start()ed: the wait deadlocks")
            else:
                msg = (f"{label} in {fn.name} is constructed but "
                       f"neither .start()ed nor .wait()ed (dead DMA)")
            findings.append(Finding("dma-pairing", src.path, line, msg))
        return findings

    # ------------------------------------------------- vmem budget ----
    def _check_vmem(self, fn, src: SourceFile,
                    config: AnalysisConfig) -> list:
        total, parts = 0, []
        for n in ast.walk(fn):
            if not isinstance(n, ast.Call):
                continue
            name = _attr_name(n.func)
            if name == "VMEM" and n.args:
                b = _shape_bytes(n.args[0], config)
                total += b
                parts.append(f"VMEM scratch ~{b // 1024}KiB "
                             f"(line {n.lineno})")
            elif name == "BlockSpec" and n.args \
                    and isinstance(n.args[0], (ast.Tuple, ast.List)):
                b = _shape_bytes(n.args[0], config)
                total += b
                parts.append(f"block ~{b // 1024}KiB (line {n.lineno})")
        if total > config.vmem_cap_bytes:
            return [Finding(
                "vmem-budget", src.path, fn.lineno,
                f"{fn.name}: estimated VMEM footprint "
                f"{total / 2**20:.1f}MiB exceeds the "
                f"{config.vmem_cap_bytes / 2**20:.0f}MiB cap "
                f"({'; '.join(parts)})")]
        return []

    # -------------------------------------------------- semaphores ----
    def _check_semaphores(self, src: SourceFile) -> list:
        scoped = set()
        for n in ast.walk(src.tree):
            if isinstance(n, ast.Call) \
                    and _attr_name(n.func) in ("run_scoped",
                                               "pallas_call"):
                regions = list(n.args) if _attr_name(
                    n.func) == "run_scoped" else []
                regions += [kw.value for kw in n.keywords
                            if _attr_name(n.func) == "run_scoped"
                            or kw.arg == "scratch_shapes"]
                for region in regions:
                    scoped.update(id(sub) for sub in ast.walk(region))
        findings = []
        for n in ast.walk(src.tree):
            if isinstance(n, ast.Attribute) \
                    and n.attr == "SemaphoreType" and id(n) not in scoped:
                findings.append(Finding(
                    "semaphore-scope", src.path, n.lineno,
                    "DMA semaphore allocated outside pl.run_scoped / "
                    "pallas_call scratch_shapes: scoped allocation is "
                    "what keeps the semaphore alive for every "
                    "in-flight copy that signals it"))
        return findings
