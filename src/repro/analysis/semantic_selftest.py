"""Seeded fixtures proving every semantic rule fires (--tier semantic
--self-test).

Mirrors analysis/selftest/ for the jaxpr tier and the DMA sanitizer:

* fixture TraceEntries seed one jaxpr-rule violation each — a
  double-psum shard_map body (the collective-count regression the
  acceptance gate names), a bf16 psum, an f64 trace, a debug.print in
  clock-driven code, an oversized captured const, and a build that
  raises. The two bad collective bodies call `jax.lax.psum` through a
  local alias on purpose: the AST tier counts *names*, so an aliased
  reduce is exactly the regression only the traced jaxpr can see.
* mutant mini-kernels seed one DMA race class each — written against
  the real pl/pltpu surface (they would compile as pallas kernels)
  but only ever executed through dma_sanitizer's shadow harness. The
  clean mini-kernel must produce zero findings and match the eager
  reference, proving the harness neither under- nor over-reports.

Unlike analysis/selftest/ these fixtures ARE imported and executed —
they live here (not in the excluded selftest/ dir) so the repo-wide
AST scan also proves they carry no *syntactic* violations: what they
seed is invisible to that tier by construction.

The shard_map fixtures need >= 2 host devices; the CLI forces 8 via
XLA_FLAGS before importing jax, and the self-test fails loudly (rather
than skipping rules) when run in a 1-device process.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import dma_sanitizer, jaxpr_rules
from repro.analysis.trace_registry import TraceEntry

__all__ = ["fixture_entries", "clean_entries", "MUTANTS", "CLEAN_MINI",
           "EXPECTED_SEMANTIC", "run_semantic_self_test"]


# ------------------------------------------------ jaxpr fixtures ----

def _shard_mapped(local):
    """Wrap a shard-local body over the ambient 'model' mesh axis."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.sharding import current_mesh

    def fn(x):
        return shard_map(local, mesh=current_mesh(),
                         in_specs=(P("model"),), out_specs=P(None),
                         axis_names={"model"}, check_vma=False)(x)
    return fn, (jnp.zeros((8,), jnp.float32),)


def _build_double_psum():
    # aliased reduce: invisible to the AST counter, plain as day in
    # the jaxpr — the seeded §3 budget regression
    from jax.lax import psum as allreduce

    def local(xl):
        y = allreduce(xl.astype(jnp.float32), "model")
        return allreduce(y, "model")
    return _shard_mapped(local)


def _build_bf16_psum():
    from jax.lax import psum as allreduce

    def local(xl):
        return allreduce(xl.astype(jnp.bfloat16), "model")
    return _shard_mapped(local)


def _build_clean_shard_map():
    def local(xl):
        return jax.lax.psum(xl.astype(jnp.float32), "model")
    return _shard_mapped(local)


def _build_f64():
    return (lambda x: x.astype(jnp.float64) * 2.0), \
        (jnp.zeros((4,), jnp.float32),)


def _x64_ctx():
    from jax.experimental import enable_x64
    return enable_x64()


def _build_callback():
    def fn(x):
        jax.debug.print("decode x[0] {v}", v=x[0])
        return x + 1.0
    return fn, (jnp.zeros((4,), jnp.float32),)


def _build_const_capture():
    baked = jnp.zeros((64, 1024), jnp.float32)       # 256 KiB closure

    def fn(x):
        return x @ baked
    return fn, (jnp.zeros((2, 64), jnp.float32),)


def _build_trace_error():
    raise RuntimeError("seeded broken registration")


def _build_clean():
    return (lambda x: jnp.tanh(x) * 2.0), (jnp.zeros((4,), jnp.float32),)


def fixture_entries() -> tuple:
    """Seeded-violation TraceEntries, keyed by the rule they prove."""
    return (
        TraceEntry("fixture/double-psum", _build_double_psum,
                   n_devices=2, psums=1, all_gathers=0),
        TraceEntry("fixture/bf16-psum", _build_bf16_psum,
                   n_devices=2, psums=1, all_gathers=0),
        TraceEntry("fixture/f64", _build_f64, trace_ctx=_x64_ctx),
        TraceEntry("fixture/callback", _build_callback),
        TraceEntry("fixture/const-capture", _build_const_capture,
                   const_cap_bytes=64 * 1024),
        TraceEntry("fixture/trace-error", _build_trace_error),
    )


def clean_entries() -> tuple:
    """Fixtures that must stay finding-free (incl. a correct
    single-psum shard_map body and a non-clock-driven callback)."""
    return (
        TraceEntry("fixture/clean-shardmap", _build_clean_shard_map,
                   n_devices=2, psums=1, all_gathers=0),
        TraceEntry("fixture/clean", _build_clean),
        TraceEntry("fixture/clean-offline-callback", _build_callback,
                   clock_driven=False),
    )


# ---------------------------------------------- mutant mini-kernels ----
# Each would compile as a pallas kernel; each is only ever run through
# dma_sanitizer.run_mini_shadow. Signature: (x_ref, w_hbm, y_ref,
# *, kc, cs) — kc clusters of cs rows, double-buffered HBM->VMEM.

def clean_mini(x_ref, w_hbm, y_ref, *, kc, cs):
    """Correct Fig-6(b) overlap: warm-up start, prefetch k+1, wait k."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[slot], sem.at[slot])
        dma(0, 0).start()

        def step(k, _):
            slot = jax.lax.rem(k, 2)

            @pl.when(k + 1 < kc)
            def _prefetch():
                dma(jax.lax.rem(k + 1, 2), k + 1).start()

            dma(slot, k).wait()
            y_ref[...] += x_ref[...] @ buf[slot]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((2,)))


def mutant_dropped_wait(x_ref, w_hbm, y_ref, *, kc, cs):
    """Never waits: compute reads slots whose copies are in flight."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[slot], sem.at[slot])
        dma(0, 0).start()

        def step(k, _):
            slot = jax.lax.rem(k, 2)

            @pl.when(k + 1 < kc)
            def _prefetch():
                dma(jax.lax.rem(k + 1, 2), k + 1).start()

            # wait dropped
            y_ref[...] += x_ref[...] @ buf[slot]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((2,)))


def mutant_premature_slot_reuse(x_ref, w_hbm, y_ref, *, kc, cs):
    """Single-slot buffer: the prefetch restarts the slot before the
    previous copy was waited on."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[0], sem.at[0])
        dma(0).start()

        def step(k, _):
            @pl.when(k + 1 < kc)
            def _prefetch():
                dma(k + 1).start()        # reuses slot 0 pre-wait

            dma(k).wait()
            y_ref[...] += x_ref[...] @ buf[0]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((1, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((1,)))


def mutant_swapped_slot_wait(x_ref, w_hbm, y_ref, *, kc, cs):
    """Waits on the prefetch slot instead of the compute slot."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[slot], sem.at[slot])
        dma(0, 0).start()

        def step(k, _):
            slot = jax.lax.rem(k, 2)

            @pl.when(k + 1 < kc)
            def _prefetch():
                dma(jax.lax.rem(k + 1, 2), k + 1).start()

            swapped = jax.lax.rem(k + 1, 2)          # wrong slot
            pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[swapped],
                sem.at[swapped]).wait()
            y_ref[...] += x_ref[...] @ buf[slot]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((2,)))


def mutant_double_wait(x_ref, w_hbm, y_ref, *, kc, cs):
    """Waits twice on the same copy."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[slot], sem.at[slot])

        def step(k, _):
            slot = jax.lax.rem(k, 2)
            dma(slot, k).start()
            dma(slot, k).wait()
            dma(slot, k).wait()                      # second wait
            y_ref[...] += x_ref[...] @ buf[slot]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((2,)))


def mutant_direct_overwrite(x_ref, w_hbm, y_ref, *, kc, cs):
    """Compute writes a slot while a copy into it is in flight."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(buf, sem):
        def dma(slot, k):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(k * cs, cs)], buf.at[slot], sem.at[slot])

        def step(k, _):
            slot = jax.lax.rem(k, 2)
            dma(slot, k).start()
            buf[slot] = jnp.zeros((cs,) + w_hbm.shape[1:],
                                  w_hbm.dtype)       # overwrite in flight
            dma(slot, k).wait()
            y_ref[...] += x_ref[...] @ buf[slot]
            return 0
        jax.lax.fori_loop(0, kc, step, 0)

    pl.run_scoped(body,
                  buf=pltpu.VMEM((2, cs) + w_hbm.shape[1:], w_hbm.dtype),
                  sem=pltpu.SemaphoreType.DMA((2,)))


# mutant name -> (kernel, race classes it must trip)
MUTANTS = {
    "mutant_dropped_wait": (mutant_dropped_wait,
                            {"dma-read-not-ready",
                             "dma-inflight-at-exit"}),
    "mutant_premature_slot_reuse": (mutant_premature_slot_reuse,
                                    {"dma-start-without-wait"}),
    "mutant_swapped_slot_wait": (mutant_swapped_slot_wait,
                                 {"dma-read-not-ready"}),
    "mutant_double_wait": (mutant_double_wait, {"dma-double-wait"}),
    "mutant_direct_overwrite": (mutant_direct_overwrite,
                                {"dma-slot-overwrite"}),
}

CLEAN_MINI = clean_mini

# rule -> the fixture/mutant that proves it fires
EXPECTED_SEMANTIC = {
    "jaxpr-collective-count": "fixture/double-psum",
    "jaxpr-collective-fp32": "fixture/bf16-psum",
    "jaxpr-f64": "fixture/f64",
    "jaxpr-callback": "fixture/callback",
    "jaxpr-const-capture": "fixture/const-capture",
    "jaxpr-trace-error": "fixture/trace-error",
    "dma-read-not-ready": "mutant_dropped_wait",
    "dma-inflight-at-exit": "mutant_dropped_wait",
    "dma-start-without-wait": "mutant_premature_slot_reuse",
    "dma-double-wait": "mutant_double_wait",
    "dma-slot-overwrite": "mutant_direct_overwrite",
    "dma-shadow-fidelity": "fidelity-drift",
}


def _mini_reference(x, w, kc, cs):
    return sum(x @ w[k * cs:(k + 1) * cs] for k in range(kc))


def run_semantic_self_test():
    """Returns (ok, report_lines) — every semantic rule must fire on
    its seeded fixture/mutant, every clean fixture must stay clean."""
    ok, lines = True, []
    if jax.device_count() < 2:
        return False, [
            "FAIL semantic self-test needs >= 2 host devices for the "
            "shard_map fixtures (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "before jax imports)"]

    fired = {}                       # case -> set of rules that fired
    for entry in fixture_entries() + clean_entries():
        fs = jaxpr_rules.run_entries([entry])
        fired[entry.name] = {f.rule for f in fs}
        if entry.name.startswith("fixture/clean") and fs:
            ok = False
            lines.append(f"FAIL clean fixture {entry.name} produced: "
                         + "; ".join(str(f) for f in fs))
    for name, (kernel, _) in MUTANTS.items():
        fs, _, _, _ = dma_sanitizer.run_mini_shadow(kernel, case=name)
        fired[name] = {f.rule for f in fs}

    # the comparator itself: a drifted shadow output must be reported
    drift = dma_sanitizer.fidelity_findings(
        "fidelity-drift", np.ones((2, 2)), np.zeros((2, 2)))
    fired["fidelity-drift"] = {f.rule for f in drift}

    all_rules = jaxpr_rules.JAXPR_RULES + dma_sanitizer.DMA_RULES
    for rule in sorted(set(all_rules) | set(EXPECTED_SEMANTIC)):
        want = EXPECTED_SEMANTIC.get(rule)
        if want is None:
            ok = False
            lines.append(f"FAIL {rule}: no fixture seeds this rule")
        elif rule in fired.get(want, ()):
            lines.append(f"ok   {rule}: fires on {want}")
        else:
            ok = False
            lines.append(f"FAIL {rule}: seeded violation {want} did "
                         f"not fire (got {sorted(fired.get(want, ()))})")

    # every declared race class of every mutant must trip
    for name, (_, expected) in sorted(MUTANTS.items()):
        missing = expected - fired[name]
        if missing:
            ok = False
            lines.append(f"FAIL {name}: missed {sorted(missing)}")

    # the clean mini-kernel: no findings, faithful output
    fs, y, x, w = dma_sanitizer.run_mini_shadow(CLEAN_MINI,
                                                case="clean_mini")
    fs += dma_sanitizer.fidelity_findings(
        "clean_mini", y, _mini_reference(x, w, kc=4, cs=8))
    if fs:
        ok = False
        lines.append("FAIL clean mini-kernel produced: "
                     + "; ".join(str(f) for f in fs))
    else:
        lines.append("ok   clean mini-kernel: no findings, output "
                     "matches the eager reference")
    return ok, lines
