"""Trace/determinism hazards in clock-driven and traced code.

Two determinism contracts hold the golden tests together: the modeled
clocks (engine device clock §7, gateway fleet clock §11) are the ONLY
time source in serving code, and every traced decode path is a pure
function of (params, tokens, rng-key chain). Wall-clock reads, global
RNG state, or Python control flow on tracer values each break one of
them — silently, until a golden flakes. Four rules:

* wall-clock      — no time.time/perf_counter/monotonic or
                    datetime.now in the scanned set: modeled clocks
                    only (intentional observability reads carry an
                    inline ignore).
* py-random       — no stdlib `random.*` and no numpy global-state RNG
                    (`np.random.<fn>`); `np.random.default_rng(seed)`
                    with an explicit seed is fine (deterministic), a
                    seedless `default_rng()` is not. jax.random is
                    threaded-key and always fine.
* tracer-branch   — inside traced functions (jit-decorated, shard_map
                    bodies, pallas kernels, and their nested defs), no
                    Python `if`/`while`/`assert`/`bool()` on a value
                    produced by a jnp/jax call: tracer truthiness
                    either crashes or, worse, burns one trace's branch
                    into every execution.
* jit-static-args — `static_argnames` entries must exist in the jitted
                    function's signature and must not default to a
                    non-hashable (list/dict/set) value;
                    `static_argnums` must be in positional range. A
                    drifted static name silently stops being static
                    (retrace per call) or throws at first call.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (AnalysisConfig, Checker, Finding,
                                      SourceFile, register_checker)

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)

_TIME_ATTRS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
               "monotonic", "monotonic_ns", "process_time", "clock"}
_DATETIME_ATTRS = {"now", "utcnow", "today"}


def _attr_name(func) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _root_name(node) -> str:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _module_aliases(tree) -> dict:
    """Names bound to imported modules: {'np': 'numpy', 'random':
    'random', ...} — so a local variable named `random` never trips
    the RNG rule."""
    aliases = {}
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            for a in n.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(n, ast.ImportFrom) and n.module:
            for a in n.names:
                aliases.setdefault(a.asname or a.name,
                                   f"{n.module}.{a.name}")
    return aliases


def _is_jax_call(call: ast.Call) -> bool:
    return _root_name(call.func) in ("jnp", "jax", "lax")


@register_checker
class TraceHazardChecker(Checker):
    name = "trace-hazards"
    rules = ("wall-clock", "py-random", "tracer-branch",
             "jit-static-args")
    scope = ("src/repro/serving/", "src/repro/core/sparse_ffn.py",
             "src/repro/kernels/")

    def check(self, src: SourceFile, config: AnalysisConfig) -> list:
        findings = []
        aliases = _module_aliases(src.tree)
        findings.extend(self._check_clock_and_rng(src, aliases))
        findings.extend(self._check_tracer_branches(src))
        findings.extend(self._check_jit_static(src))
        return findings

    # --------------------------------------------- clock + rng ----
    def _check_clock_and_rng(self, src: SourceFile,
                             aliases: dict) -> list:
        findings = []
        for n in ast.walk(src.tree):
            if not (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)):
                continue
            attr, base = n.func.attr, n.func.value
            base_root = _root_name(base)
            base_mod = aliases.get(base_root, "")
            if attr in _TIME_ATTRS and base_mod == "time":
                findings.append(Finding(
                    "wall-clock", src.path, n.lineno,
                    f"time.{attr}() in clock-driven code: the modeled "
                    f"event clock (DESIGN.md §7/§11) is the only time "
                    f"source the deterministic goldens allow"))
            elif attr in _DATETIME_ATTRS \
                    and "datetime" in (base_mod,
                                       getattr(base, "attr", "")):
                findings.append(Finding(
                    "wall-clock", src.path, n.lineno,
                    f"datetime {attr}() in clock-driven code: use the "
                    f"modeled event clock"))
            elif base_mod == "random":
                findings.append(Finding(
                    "py-random", src.path, n.lineno,
                    f"stdlib random.{attr}() draws from global mutable "
                    f"state: thread a jax key or a seeded "
                    f"np.random.default_rng through instead"))
            elif isinstance(base, ast.Attribute) \
                    and base.attr == "random" \
                    and aliases.get(_root_name(base), "") == "numpy":
                if attr == "default_rng" and (n.args or n.keywords):
                    continue           # explicitly seeded: deterministic
                how = ("() without a seed" if attr == "default_rng"
                       else " global-state RNG")
                findings.append(Finding(
                    "py-random", src.path, n.lineno,
                    f"np.random.{attr}{how}: serving determinism "
                    f"requires an explicit seed"))
        return findings

    # ------------------------------------------- tracer branches ----
    def _traced_functions(self, tree) -> list:
        """Functions whose bodies run under a jax trace: jit-decorated,
        shard_map bodies, pallas kernels — plus everything nested in
        them."""
        defs = {n.name: n for n in ast.walk(tree)
                if isinstance(n, _FUNCS)}
        traced = []
        for fn in defs.values():
            for dec in fn.decorator_list:
                names = {_attr_name(x) for x in ast.walk(dec)
                         if isinstance(x, (ast.Attribute, ast.Name))}
                if "jit" in names:
                    traced.append(fn)
        for n in ast.walk(tree):
            if not isinstance(n, ast.Call):
                continue
            fname = _attr_name(n.func)
            target = None
            if fname.endswith("shard_map") and n.args:
                target = n.args[0]
            elif fname == "pallas_call" and n.args:
                target = n.args[0]
                # pallas kernels are usually partial(_kernel, ...)
                if isinstance(target, ast.Call) and target.args:
                    target = target.args[0]
            if isinstance(target, ast.Name) and target.id in defs:
                traced.append(defs[target.id])
        out, seen = [], set()
        for fn in traced:
            for sub in ast.walk(fn):
                if isinstance(sub, _FUNCS) and id(sub) not in seen:
                    seen.add(id(sub))
                    out.append(sub)
        return out

    def _check_tracer_branches(self, src: SourceFile) -> list:
        findings = []
        for fn in self._traced_functions(src.tree):
            traced_names = set()
            for n in ast.walk(fn):
                if isinstance(n, (ast.Assign, ast.AugAssign,
                                  ast.AnnAssign)):
                    value = n.value
                    if value is None:
                        continue
                    tainted = any(
                        (isinstance(x, ast.Call) and _is_jax_call(x))
                        or (isinstance(x, ast.Name)
                            and x.id in traced_names)
                        for x in ast.walk(value))
                    if not tainted:
                        continue
                    targets = n.targets if isinstance(n, ast.Assign) \
                        else [n.target]
                    for t in targets:
                        for leaf in ast.walk(t):
                            if isinstance(leaf, ast.Name):
                                traced_names.add(leaf.id)

            def tests(node):
                for x in ast.walk(node):
                    if isinstance(x, (ast.If, ast.While)):
                        yield x.test, type(x).__name__.lower()
                    elif isinstance(x, ast.Assert):
                        yield x.test, "assert"
                    elif isinstance(x, ast.Call) \
                            and _attr_name(x.func) == "bool" and x.args:
                        yield x.args[0], "bool()"

            for test, kind in tests(fn):
                hot = [x.id for x in ast.walk(test)
                       if isinstance(x, ast.Name)
                       and x.id in traced_names]
                if hot:
                    findings.append(Finding(
                        "tracer-branch", src.path, test.lineno,
                        f"Python {kind} on {hot[0]!r}, a value produced "
                        f"by a jnp/jax call inside traced function "
                        f"{fn.name!r}: tracer truthiness burns one "
                        f"trace's branch into every execution (use "
                        f"jnp.where / lax.cond)"))
        return findings

    # ------------------------------------------- jit static args ----
    def _check_jit_static(self, src: SourceFile) -> list:
        findings = []
        for fn in ast.walk(src.tree):
            if not isinstance(fn, _FUNCS):
                continue
            params = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)]
            defaults = {}
            pos = fn.args.posonlyargs + fn.args.args
            for a, d in zip(pos[len(pos) - len(fn.args.defaults):],
                            fn.args.defaults):
                defaults[a.arg] = d
            for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
                if d is not None:
                    defaults[a.arg] = d
            for dec in fn.decorator_list:
                for call in ast.walk(dec):
                    if not isinstance(call, ast.Call):
                        continue
                    in_jit = "jit" in {
                        _attr_name(x) for x in ast.walk(call.func)
                        if isinstance(x, (ast.Attribute, ast.Name))} \
                        or any(_attr_name(a) == "jit"
                               for a in call.args
                               if isinstance(a, (ast.Attribute,
                                                 ast.Name)))
                    if not in_jit:
                        continue
                    for kw in call.keywords:
                        if kw.arg == "static_argnames":
                            findings.extend(self._static_names(
                                kw.value, params, defaults, fn, src))
                        elif kw.arg == "static_argnums":
                            findings.extend(self._static_nums(
                                kw.value, pos, fn, src))
        return findings

    def _static_names(self, value, params, defaults, fn,
                      src: SourceFile) -> list:
        findings = []
        names = []
        if isinstance(value, ast.Constant) \
                and isinstance(value.value, str):
            names = [(value.value, value.lineno)]
        elif isinstance(value, (ast.Tuple, ast.List, ast.Set)):
            names = [(e.value, e.lineno) for e in value.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str)]
        for name, line in names:
            if name not in params:
                findings.append(Finding(
                    "jit-static-args", src.path, line,
                    f"static_argnames names {name!r} which is not a "
                    f"parameter of {fn.name}: the jit silently "
                    f"ignores it (or errors, depending on version)"))
                continue
            d = defaults.get(name)
            if isinstance(d, (ast.List, ast.Dict, ast.Set)) \
                    or (isinstance(d, ast.Call)
                        and _attr_name(d.func) in ("list", "dict",
                                                   "set")):
                findings.append(Finding(
                    "jit-static-args", src.path, line,
                    f"static arg {name!r} of {fn.name} defaults to a "
                    f"non-hashable {type(d).__name__.lower()}: jit "
                    f"static args must be hashable"))
        return findings

    def _static_nums(self, value, pos, fn, src: SourceFile) -> list:
        nums = []
        if isinstance(value, ast.Constant) \
                and isinstance(value.value, int):
            nums = [(value.value, value.lineno)]
        elif isinstance(value, (ast.Tuple, ast.List)):
            nums = [(e.value, e.lineno) for e in value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)]
        return [Finding(
            "jit-static-args", src.path, line,
            f"static_argnums {i} is out of positional range for "
            f"{fn.name} ({len(pos)} positional params)")
            for i, line in nums if i >= len(pos)]
