"""Protocol conformance: BackendHandle impls + ServingFamily entries.

The gateway dispatches over the narrow `BackendHandle` surface and the
engine serves whatever the `ServingFamily` registry provides — both
are duck-typed, so a drifted signature (an added parameter, a method
renamed, a property turned method) only explodes at dispatch time, on
whichever path the conformance battery happens to exercise. Two rules:

* protocol-method — every class subclassing a protocol base (default:
                    BackendHandle) overrides each abstract method
                    (body raises NotImplementedError in the base) with
                    a compatible signature: same required positional
                    arity, extra parameters only with defaults,
                    property-ness preserved.
* family-fields   — every `ServingFamily(...)` construction passes the
                    full required field set (all dataclass fields
                    without defaults), and any field value resolvable
                    to a local def/lambda accepts the registry's
                    documented call shape (families.py field
                    comments): make_model(cfg) / make_decode_step(cfg)
                    / build_plan(cfg, freqs=, hw=, backend=) /
                    prepare_params(params, plan).
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (AnalysisConfig, Finding,
                                      RepoChecker, register_checker)

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)

# field -> (min required positional args, required keyword names)
_FAMILY_CALL_SHAPES = {
    "make_model": (1, ()),
    "make_decode_step": (1, ()),
    "build_plan": (1, ("freqs", "hw", "backend")),
    "prepare_params": (2, ()),
}


def _attr_name(func) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _is_property(fn) -> bool:
    return any(_attr_name(d) == "property" for d in fn.decorator_list)


def _raises_not_implemented(fn) -> bool:
    for n in fn.body:
        if isinstance(n, ast.Raise):
            exc = n.exc
            name = _attr_name(exc.func) if isinstance(exc, ast.Call) \
                else _attr_name(exc) if exc is not None else ""
            if name == "NotImplementedError":
                return True
    return False


def _signature(fn) -> tuple:
    """(required positional names, optional count, has *args,
    has **kwargs) — self excluded."""
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    if pos and pos[0] in ("self", "cls"):
        pos = pos[1:]
    n_opt = len(fn.args.defaults)
    required = pos[:len(pos) - n_opt] if n_opt else pos
    return (tuple(required), n_opt,
            fn.args.vararg is not None, fn.args.kwarg is not None)


def _accepts(fn, n_pos: int, kwnames: tuple) -> bool:
    """Can `fn` be called with n_pos positional args plus the given
    keyword names (each possibly omitted)?"""
    required, n_opt, varargs, varkw = _signature(fn)
    total_pos = len(required) + n_opt
    if len(required) > n_pos and not all(
            r in kwnames for r in required[n_pos:]):
        return False
    if n_pos > total_pos and not varargs:
        return False
    if varkw:
        return True
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    kwonly = [a.arg for a in fn.args.kwonlyargs]
    accept = set(pos + kwonly)
    return all(k in accept for k in kwnames) or not kwnames


@register_checker
class ProtocolChecker(RepoChecker):
    name = "protocol"
    rules = ("protocol-method", "family-fields")

    def check_repo(self, files: dict, config: AnalysisConfig) -> list:
        findings = []
        findings.extend(self._check_protocols(files, config))
        findings.extend(self._check_families(files, config))
        return findings

    # -------------------------------------------- protocol bases ----
    def _check_protocols(self, files: dict,
                         config: AnalysisConfig) -> list:
        # find protocol base classes: any class named *Handle defining
        # at least one NotImplementedError method
        bases = {}          # name -> (path, {method: (fn, is_prop, abstract)})
        for path, src in files.items():
            for cls in ast.walk(src.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                methods = {n.name: (n, _is_property(n),
                                    _raises_not_implemented(n))
                           for n in cls.body if isinstance(n, _FUNCS)}
                if any(abst for _, _, abst in methods.values()) \
                        and cls.name.endswith("Handle"):
                    bases[cls.name] = (path, methods)

        findings = []
        for path, src in files.items():
            for cls in ast.walk(src.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                for base in cls.bases:
                    bname = _attr_name(base)
                    if bname not in bases:
                        continue
                    findings.extend(self._check_impl(
                        cls, bases[bname], bname, path))
        return findings

    def _check_impl(self, cls, base_entry, bname, path) -> list:
        _, base_methods = base_entry
        impl = {n.name: n for n in cls.body if isinstance(n, _FUNCS)}
        findings = []
        for mname, (bfn, bprop, abstract) in sorted(base_methods.items()):
            if not abstract:
                continue            # base provides a default body
            if mname not in impl:
                findings.append(Finding(
                    "protocol-method", path, cls.lineno,
                    f"{cls.name} ({bname} impl) does not override "
                    f"abstract {'property' if bprop else 'method'} "
                    f"{mname!r}: dispatch raises NotImplementedError "
                    f"at runtime"))
                continue
            ifn = impl[mname]
            if _is_property(ifn) != bprop:
                findings.append(Finding(
                    "protocol-method", path, ifn.lineno,
                    f"{cls.name}.{mname} "
                    f"{'drops' if bprop else 'adds'} @property vs "
                    f"{bname}.{mname}: callers access it the other "
                    f"way"))
                continue
            breq, _, _, _ = _signature(bfn)
            ireq, _, ivar, _ = _signature(ifn)
            if not ivar and len(ireq) != len(breq):
                findings.append(Finding(
                    "protocol-method", path, ifn.lineno,
                    f"{cls.name}.{mname} requires {len(ireq)} "
                    f"positional args where {bname}.{mname} declares "
                    f"{len(breq)} ({', '.join(breq) or 'none'}): "
                    f"dispatch sites pass exactly the protocol shape"))
        return findings

    # ------------------------------------------- family registry ----
    def _check_families(self, files: dict,
                        config: AnalysisConfig) -> list:
        src = files.get(config.families_path)
        if src is None:
            return []
        findings = []
        # required fields = dataclass fields without defaults
        required = []
        for cls in ast.walk(src.tree):
            if isinstance(cls, ast.ClassDef) \
                    and cls.name == "ServingFamily":
                for n in cls.body:
                    if isinstance(n, ast.AnnAssign) \
                            and isinstance(n.target, ast.Name) \
                            and n.value is None:
                        required.append(n.target.id)
        if not required:
            return []
        defs = {n.name: n for n in ast.walk(src.tree)
                if isinstance(n, _FUNCS)}
        for call in ast.walk(src.tree):
            if not (isinstance(call, ast.Call)
                    and _attr_name(call.func) == "ServingFamily"):
                continue
            given = {kw.arg for kw in call.keywords if kw.arg}
            n_pos = len(call.args)
            missing = [f for f in required[n_pos:] if f not in given]
            if missing:
                findings.append(Finding(
                    "family-fields", config.families_path, call.lineno,
                    f"ServingFamily(...) misses required field(s) "
                    f"{', '.join(missing)}: the registry entry fails "
                    f"at first use, not at registration"))
            for kw in call.keywords:
                shape = _FAMILY_CALL_SHAPES.get(kw.arg)
                if shape is None:
                    continue
                fn = None
                if isinstance(kw.value, ast.Name):
                    fn = defs.get(kw.value.id)
                elif isinstance(kw.value, ast.Lambda):
                    fn = kw.value
                if fn is None or isinstance(fn, ast.Lambda):
                    # lambdas: check positional arity only
                    if isinstance(fn, ast.Lambda):
                        n_req = len(fn.args.args) - len(fn.args.defaults)
                        if n_req > shape[0]:
                            findings.append(Finding(
                                "family-fields", config.families_path,
                                kw.value.lineno,
                                f"{kw.arg} lambda requires {n_req} "
                                f"positional args; the engine calls it "
                                f"with {shape[0]}"))
                    continue
                if not _accepts(fn, shape[0], shape[1]):
                    findings.append(Finding(
                        "family-fields", config.families_path,
                        kw.value.lineno,
                        f"{kw.arg}={fn.name} does not accept the "
                        f"registry call shape ({shape[0]} positional"
                        f"{' + kw ' + ','.join(shape[1]) if shape[1] else ''})"))
        return findings
