"""Offline planner walkthrough (paper §5): profile real activations,
classify neurons into hot/cold per batch-size bucket, inspect the
I/O-aware sizing, save/reload the execution plan.

  PYTHONPATH=src python examples/plan_and_inspect.py
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core.planner import (ExecutionPlan, HardwareProfile, build_plan,
                                profile_activations)
from repro.models.dense import make_model


def main():
    cfg = get_config("smollm-135m").reduced().replace(activation="relu2")
    cfg = cfg.replace(sparse_ffn=dataclasses.replace(cfg.sparse_ffn,
                                                     mode="relu"))
    model = make_model(cfg)
    params = model.init(jax.random.key(0))

    print("=== profiling activations (paper: 10M tokens; demo: 4k) ===")
    batches = [jax.random.randint(jax.random.key(i), (4, 128), 0,
                                  cfg.vocab_size) for i in range(8)]
    counts, n_tok = profile_activations(params, cfg, batches)
    freqs = (counts / n_tok).astype(np.float32)
    print(f"profiled {n_tok} tokens; "
          f"layer-0 activation freq: min {freqs[0].min():.3f} "
          f"max {freqs[0].max():.3f}")

    print("\n=== classification across batch buckets ===")
    plan = build_plan(cfg, freqs)
    for b, p in sorted(plan.plans.items()):
        print(f"batch<={b:3d}: hot {p.n_hot:5d} neurons "
              f"({p.n_hot / cfg.d_ff:5.1%}) cold budget {p.total_cold:5d}")

    print("\n=== I/O-aware hot sizing (slow vs fast tier) ===")
    slow = build_plan(cfg, freqs, hw=HardwareProfile(seq_bw=5e7))
    fast = build_plan(cfg, freqs, hw=HardwareProfile(seq_bw=50e9))
    print(f"slow-tier hot @b32: {slow.plans[32].n_hot}  "
          f"fast-tier hot @b32: {fast.plans[32].n_hot}")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plan.json")
        plan.save(path)
        plan2 = ExecutionPlan.load(path)
        print(f"\nplan round-trips: {plan2.plans == plan.plans} "
              f"({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
