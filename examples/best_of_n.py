"""Best-of-N sampling with dynamic batch adaptation (paper Fig 1b/13).

Generates N=4 candidate continuations through the continuous-batching
API: the four candidates are submitted with staggered generation
budgets, so they finish at different steps, the effective batch
shrinks, and the engine swaps pre-jitted executables (the paper's
per-batch NPU graphs) + hot/cold plans live — no forced completion
schedule needed. The best candidate is picked by mean token log-prob.

  PYTHONPATH=src python examples/best_of_n.py
"""
import jax
import numpy as np

from repro.launch.serve import build_engine
from repro.serving.sampler import sequence_logprob


def main():
    engine, cfg = build_engine("smollm-135m", reduced=True, offload=0.5,
                               ctx_budget=32, temperature=1.0)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)

    # N=4 candidates of the same prompt, staggered budgets 4/8/12/16
    max_new = 16
    uids = [engine.submit(base, max_new=n) for n in (4, 8, 12, max_new)]
    rep = engine.run_until_drained()
    batches = [s.batch for s in rep.stats]
    print("batch timeline:", batches)
    print("executable swaps:", engine.decoder.switches)
    print(f"modeled {rep.tokens_per_s:.1f} tok/s; "
          f"ttft {rep.ttft().mean() * 1e3:.2f} ms")

    # rank candidates (pad short/finished ones)
    toks = np.zeros((len(uids), max_new), np.int32)
    for i, u in enumerate(uids):
        gen = engine.sched.sequences[u].generated
        toks[i, :len(gen)] = gen
    # score with the model's own logits via a fresh forward
    import jax.numpy as jnp
    from repro.models.dense import make_model
    model = make_model(cfg)
    prompt = np.repeat(base[None], len(uids), axis=0)
    full = jnp.concatenate([jnp.asarray(prompt), jnp.asarray(toks)], 1)
    logits = jax.jit(lambda p, b: model.forward(p, b))(
        engine.params, {"tokens": full})
    scores = sequence_logprob(logits[:, 15:-1], jnp.asarray(toks))
    best = int(np.argmax(np.asarray(scores)))
    print("candidate scores:", [round(float(s), 3) for s in scores])
    print(f"best-of-4 winner: candidate {best}: {toks[best].tolist()}")


if __name__ == "__main__":
    main()
