"""Best-of-N sampling with dynamic batch adaptation (paper Fig 1b/13).

Generates N=4 candidate continuations; candidates finish at staggered
steps, the effective batch shrinks, and the engine swaps pre-jitted
executables (the paper's per-batch NPU graphs) + hot/cold plans live.
The best candidate is picked by mean token log-prob.

  PYTHONPATH=src python examples/best_of_n.py
"""
import jax
import numpy as np

from repro.launch.serve import build_engine
from repro.serving.sampler import sequence_logprob


def main():
    engine, cfg = build_engine("smollm-135m", reduced=True, offload=0.5)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    prompt = np.repeat(base, 4, axis=0)              # N=4 candidates

    res = engine.generate(prompt, max_new=16, temperature=1.0,
                          completion_schedule={4: 1, 8: 1, 12: 1})
    batches = [s.batch for s in res.stats]
    print("batch timeline:", batches)
    print("executable swaps:", engine.decoder.switches)

    # rank candidates (pad finished ones)
    toks = np.where(res.tokens < 0, 0, res.tokens)
    # score with the model's own logits via a fresh forward
    import jax.numpy as jnp
    from repro.models.dense import make_model
    model = make_model(cfg)
    full = jnp.concatenate([jnp.asarray(prompt), jnp.asarray(toks)], 1)
    logits = jax.jit(lambda p, b: model.forward(p, b))(
        engine.params, {"tokens": full})
    scores = sequence_logprob(logits[:, 15:-1], jnp.asarray(toks))
    best = int(np.argmax(np.asarray(scores)))
    print("candidate scores:", [round(float(s), 3) for s in scores])
    print(f"best-of-4 winner: candidate {best}: {toks[best].tolist()}")


if __name__ == "__main__":
    main()
