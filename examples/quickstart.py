"""Quickstart: train a reduced SmolLM on synthetic data, then serve it
with the PowerInfer-2 hybrid engine — the full substrate end to end.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.baselines import POWERINFER2
from repro.core.planner import build_plan, permute_ffn_params
from repro.launch.train import train
from repro.serving.engine import ServeEngine


def main():
    print("=== 1. train (reduced smollm-135m, synthetic tokens) ===")
    params, losses = train("smollm-135m", steps=60, batch_size=4,
                           seq_len=64, reduced=True, lr=2e-3, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("\n=== 2. offline plan (PowerInfer-2 §5) ===")
    cfg = get_config("smollm-135m").reduced()
    plan = build_plan(cfg)
    params = permute_ffn_params(params, plan.neuron_order)
    print("batch->plan:", {b: (p.n_hot, p.total_cold)
                           for b, p in sorted(plan.plans.items())})

    print("\n=== 3. serve with 50% FFN offload (PowerInfer-2 §4) ===")
    engine = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                         offload_ratio=0.5)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32)
    res = engine.generate(prompt, max_new=16, temperature=0.8)
    print(f"generated {int((res.tokens >= 0).sum())} tokens; "
          f"modeled {res.tokens_per_s:.1f} tok/s; "
          f"hit rate {np.mean([s.cache_hit_rate for s in res.stats]):.1%}")
    print("tokens[0]:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
