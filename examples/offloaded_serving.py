"""The paper's headline scenario: a model that does NOT fit in memory,
served with 50% of FFN weights offloaded to the slow tier, compared
across llama.cpp-analogue / LLMFlash-analogue / PowerInfer-2 (Fig 7)
and across storage tiers (UFS 3.1 / UFS 4.0 / TPU host-DMA).

  PYTHONPATH=src python examples/offloaded_serving.py
"""
import numpy as np

from repro.core.baselines import ALL_SYSTEMS
from repro.core.io_model import HOST_DMA, UFS31, UFS40
from repro.launch.serve import build_engine


def main():
    rng = np.random.default_rng(0)
    print(f"{'system':18s} {'storage':9s} {'tok/s':>9s} {'hit':>6s} "
          f"{'io-share':>9s}")
    for storage in (UFS31, UFS40, HOST_DMA):
        for spec in ALL_SYSTEMS:
            engine, cfg = build_engine("smollm-135m", reduced=True,
                                       offload=0.5, spec=spec,
                                       storage=storage)
            prompt = rng.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
            res = engine.generate(prompt, max_new=12, temperature=0.0)
            hit = float(np.mean([s.cache_hit_rate for s in res.stats]))
            io = sum(s.io_s for s in res.stats)
            eff = sum(s.effective_s for s in res.stats)
            print(f"{spec.name:18s} {storage.name:9s} "
                  f"{res.tokens_per_s:9.1f} {hit:6.1%} "
                  f"{min(io / max(eff, 1e-12), 1.0):9.1%}")


if __name__ == "__main__":
    main()
