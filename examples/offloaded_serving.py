"""The paper's headline scenario under a request stream: a model that
does NOT fit in memory, served with 50% of FFN weights offloaded to the
slow tier, compared across llama.cpp-analogue / LLMFlash-analogue /
PowerInfer-2 (Fig 7) and across storage tiers (UFS 3.1 / UFS 4.0 / TPU
host-DMA).

Uses the continuous-batching API: requests arrive on a seeded schedule,
join the running batch at bucket boundaries (submit/step), and the
report aggregates modeled throughput, TTFT and cache behavior.

  PYTHONPATH=src python examples/offloaded_serving.py
"""
import numpy as np

from repro.core.baselines import ALL_SYSTEMS
from repro.core.io_model import HOST_DMA, UFS31, UFS40
from repro.launch.serve import build_engine


def main():
    print(f"{'system':18s} {'storage':9s} {'tok/s':>9s} {'ttft-ms':>8s} "
          f"{'hit':>6s} {'io-share':>9s}")
    for storage in (UFS31, UFS40, HOST_DMA):
        for spec in ALL_SYSTEMS:
            engine, cfg = build_engine("smollm-135m", reduced=True,
                                       offload=0.5, spec=spec,
                                       storage=storage,
                                       buckets=(1, 2, 4, 8),
                                       ctx_budget=40, temperature=0.0)
            rng = np.random.default_rng(0)
            # 6 requests on a staggered modeled-time schedule
            arrivals = np.cumsum(rng.exponential(2e-3, 6))
            for t in arrivals:
                engine.submit(rng.integers(0, cfg.vocab_size, 16),
                              max_new=10, arrival_time=float(t))
            rep = engine.run_until_drained()
            hit = float(np.mean([s.cache_hit_rate for s in rep.stats]))
            io = sum(s.io_s for s in rep.stats)
            eff = sum(s.effective_s for s in rep.stats)
            ttft = float(rep.ttft().mean())
            print(f"{spec.name:18s} {storage.name:9s} "
                  f"{rep.tokens_per_s:9.1f} {ttft * 1e3:8.2f} {hit:6.1%} "
                  f"{min(io / max(eff, 1e-12), 1.0):9.1%}")


if __name__ == "__main__":
    main()
