"""Insert the current roofline table into EXPERIMENTS.md (idempotent)."""
import sys
sys.path.insert(0, "src")
from repro.launch.roofline import load_table, format_table

rows = load_table("artifacts/dryrun", "16x16")
table = format_table(rows)
marker = "<!-- ROOFLINE_TABLE -->"
text = open("EXPERIMENTS.md").read()
head = text.split(marker)[0]
open("EXPERIMENTS.md", "w").write(
    head + marker + "\n\n```\n" + table + "\n```\n")
print(f"inserted {len(rows)} rows")
