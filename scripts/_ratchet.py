"""Shared helpers for the repo's ratchet-style gates.

Three gates share one shape — compare a fresh run against a committed
baseline, fail on anything *new*, and fail on *stale* baseline entries
too so the baseline only ever shrinks (prune via each gate's
--update):

* scripts/check_regressions.py   — test failures vs tests/known_failures.json
* scripts/check_bench_trend.py   — bench metrics vs benchmarks/baselines/
* scripts/repro_analyze.py       — static findings vs tests/analysis_allowlist.json

This module holds the mechanics they share: baseline JSON I/O (one
canonical on-disk format so --update rewrites are diff-stable) and the
new/stale set split.
"""
from __future__ import annotations

import json
import os

_REQUIRED = object()


def load_json(path: str, default=_REQUIRED):
    """Read a JSON baseline. A missing file returns `default` when one
    is given (gates treat absent baselines as empty); without a
    default, missing is an error — fresh artifacts must exist."""
    if not os.path.exists(path):
        if default is _REQUIRED:
            raise FileNotFoundError(path)
        return default
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def dump_json(path: str, obj) -> None:
    """Write a baseline in the gates' canonical format: indent=1,
    sorted keys, trailing newline — so --update rewrites produce
    minimal diffs."""
    with open(path, "w", encoding="utf-8") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")


def diff_ratchet(current, allowed) -> tuple:
    """Split a fresh result set against a baseline set. Returns
    (new, stale), both sorted: `new` entries fail the gate outright;
    `stale` baseline entries no longer occur and fail it too until
    pruned — the ratchet only moves forward."""
    cur, base = set(current), set(allowed)
    return sorted(cur - base), sorted(base - cur)
