"""Regression gate: fail CI only on *new* test failures.

Runs the tier-1 suite (no -x, so the full failure set is visible),
diffs the failed test ids against a recorded known-failure baseline,
and exits nonzero iff a test outside the baseline failed. Baseline
entries that now pass are "stale": the default (CI) mode fails on them
too — the ratchet only moves forward, forcing a baseline prune commit —
while `--update` rewrites the baseline to the current failure set
(pruning fixed tests, recording triaged new ones).

The baseline is keyed by jax major.minor so each CI matrix leg (oldest
pin vs latest) carries its own failure set; a missing key means "no
known failures" for that leg. The special `_min_collected` key maps
each jax series to its collected-test floor (per leg, like the
failure sets — import guards can legitimately collect different
counts per jax): the gate fails when fewer tests are collected than
that leg's floor, so a whole test file silently dropping out of
collection (an import-guard skip, a renamed module) is a gated
regression too — new suites join the ratchet by re-recording the
floor with --update.

  python scripts/check_regressions.py                 # gate (CI)
  python scripts/check_regressions.py --update        # re-record
  python scripts/check_regressions.py --allow-stale   # warn, don't fail
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import xml.etree.ElementTree as ET

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _ratchet import diff_ratchet, dump_json, load_json  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "tests", "known_failures.json")


def jax_series() -> str:
    import jax
    return ".".join(jax.__version__.split(".")[:2])


def run_pytest(extra: list) -> tuple:
    """Run the suite, return (failed_ids, n_collected). Uses junit xml
    so collection errors surface as failures too."""
    with tempfile.TemporaryDirectory() as td:
        xml_path = os.path.join(td, "report.xml")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "pytest", "-q",
               f"--junitxml={xml_path}"] + extra
        r = subprocess.run(cmd, cwd=REPO, env=env)
        if not os.path.exists(xml_path):
            print(f"pytest produced no junit xml (exit {r.returncode})",
                  file=sys.stderr)
            sys.exit(2)
        # 0 = all passed, 1 = some tests failed (the diff handles it).
        # Anything else (interrupted / internal error / usage / no
        # tests) means the junit xml may be partial — never treat a
        # partially-run suite as green.
        if r.returncode not in (0, 1):
            print(f"pytest did not run to completion (exit "
                  f"{r.returncode}); refusing to diff a partial suite",
                  file=sys.stderr)
            sys.exit(2)
        root = ET.parse(xml_path).getroot()
        failed, total = set(), 0
        for case in root.iter("testcase"):
            total += 1
            nodeid = f"{case.get('classname', '')}::{case.get('name', '')}"
            if case.find("failure") is not None \
                    or case.find("error") is not None:
                failed.add(nodeid)
        return failed, total


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite this jax series' baseline to the "
                         "current failure set")
    ap.add_argument("--allow-stale", action="store_true",
                    help="fixed baseline entries warn instead of fail")
    ap.add_argument("pytest_args", nargs="*",
                    help="extra args forwarded to pytest (after --)")
    args = ap.parse_args()

    series = jax_series()
    failed, total = run_pytest(args.pytest_args)
    baseline_all = load_json(args.baseline, default={})
    known = set(baseline_all.get(series, baseline_all.get("default", [])))
    # the collected floor only means anything for a full-suite run:
    # forwarded pytest args select a subset, which must neither trip
    # the shrink gate nor re-record a tiny floor
    full_suite = not args.pytest_args
    floors = baseline_all.get("_min_collected", {})
    floor = int(floors.get(series, min(floors.values(), default=0))) \
        if full_suite else 0

    new, stale = diff_ratchet(failed, known)
    print(f"\n[check_regressions] jax {series}: {total} tests, "
          f"{len(failed)} failed ({len(known)} known, "
          f"collected floor {floor})")

    if args.update:
        baseline_all[series] = sorted(failed)
        if not baseline_all[series]:
            baseline_all.pop(series)
        if full_suite:
            baseline_all.setdefault("_min_collected", {})[series] = total
        dump_json(args.baseline, baseline_all)
        print(f"[check_regressions] baseline[{series}] <- "
              f"{len(failed)} entries, _min_collected <- {total} "
              f"({args.baseline})")
        return 0

    rc = 0
    if total < floor:
        print(f"[check_regressions] suite SHRANK: {total} collected < "
              f"recorded floor {floor} — a test file stopped being "
              f"collected (import error, renamed module?); re-record "
              f"with --update only if intentional")
        rc = 1
    if new:
        print(f"[check_regressions] {len(new)} NEW failure(s):")
        for t in new:
            print(f"  + {t}")
        rc = 1
    if stale:
        print(f"[check_regressions] {len(stale)} baseline entr"
              f"{'y is' if len(stale) == 1 else 'ies are'} now passing "
              f"— prune with --update:")
        for t in stale:
            print(f"  - {t}")
        if not args.allow_stale:
            rc = 1
    if rc == 0:
        print("[check_regressions] OK: no new failures, baseline tight")
    return rc


if __name__ == "__main__":
    sys.exit(main())
