"""repro-analyze: jax/pallas-aware static analysis gate.

Runs the checker battery in src/repro/analysis/ over the tree and
fails on any finding that is neither inline-suppressed
(`# repro: ignore[rule]` with a justification on the offending line or
the line above) nor ratcheted in the committed allowlist
(tests/analysis_allowlist.json, keyed "path:rule" -> reason). Like the
repo's other gates, the allowlist only moves forward: a stale entry —
one that no longer matches any finding — fails the gate until pruned
with --update.

Two tiers (--tier {ast,semantic,all}, default ast):

* ast — install-free source scan. Rules (see DESIGN.md §12):
  collective-axis / collective-budget / collective-fp32, dma-pairing /
  semaphore-scope / vmem-budget, wall-clock / py-random /
  tracer-branch / jit-static-args, protocol-method / family-fields,
  registry-drift / bench-gate-drift / trace-registry-drift.
* semantic — needs jax installed: traces every registered entry point
  (analysis/trace_registry.py) to a jaxpr and verifies collective
  counts/dtypes, f64, callbacks and const capture
  (analysis/jaxpr_rules.py), then shadow-executes the fused cold-FFN
  kernel sweep through the DMA race sanitizer
  (analysis/dma_sanitizer.py). See DESIGN.md §14.

  python scripts/repro_analyze.py                   # ast gate (CI)
  python scripts/repro_analyze.py --tier semantic   # jaxpr + DMA gate
  python scripts/repro_analyze.py src/repro/kernels # ast subset
  python scripts/repro_analyze.py --update          # re-ratchet
  python scripts/repro_analyze.py --self-test       # prove rules fire

--self-test honors --tier: the ast tier analyzes the seeded-violation
fixtures under src/repro/analysis/selftest/; the semantic tier traces
the seeded fixture entries and mutant kernels in
src/repro/analysis/semantic_selftest.py (dropped DMA wait, premature
slot reuse, double-psum shard_map body, ...). Every rule must fire
where seeded and the clean fixtures must stay clean — a rule whose
match rots fails here, not silently in the gate.

Exit codes: 0 clean, 1 findings / stale entries / self-test failure,
2 internal error (unparseable allowlist, bad arguments).
"""
from __future__ import annotations

import argparse
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(_HERE)
sys.path.insert(0, _HERE)
sys.path.insert(0, os.path.join(REPO, "src"))

from _ratchet import dump_json, load_json  # noqa: E402
from repro.analysis import (AnalysisConfig, all_rules,  # noqa: E402
                            analyze_paths, apply_allowlist)

DEFAULT_ALLOWLIST = os.path.join(REPO, "tests", "analysis_allowlist.json")
_TAG = "[repro_analyze]"


def _prepare_semantic_env():
    """The semantic tier's shard_map grid needs >= 2 host devices;
    force 8 before anything imports jax (a no-op once jax is live,
    hence setdefault *here*, not in the library)."""
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_self_test(tier: str) -> int:
    ok, lines, n_rules = True, [], 0
    if tier in ("ast", "all"):
        from repro.analysis.selftest import run_self_test as run_ast
        ast_ok, ast_lines = run_ast()
        ok, n_rules = ok and ast_ok, n_rules + len(all_rules())
        lines += ast_lines
    if tier in ("semantic", "all"):
        _prepare_semantic_env()
        from repro.analysis.semantic import run_self_test as run_sem
        from repro.analysis.semantic import semantic_rules
        sem_ok, sem_lines = run_sem()
        ok, n_rules = ok and sem_ok, n_rules + len(semantic_rules())
        lines += sem_lines
    for line in lines:
        print(f"{_TAG} SELF-TEST {line}")
    print(f"{_TAG} SELF-TEST "
          f"{'OK: every rule fires' if ok else 'FAILED'} "
          f"({n_rules} rules, tier {tier})")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="repo-relative files/dirs to scan "
                         "(default: the whole tree)")
    ap.add_argument("--allowlist", default=DEFAULT_ALLOWLIST)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the allowlist to the current finding "
                         "set (prunes stale entries, ratchets new ones)")
    ap.add_argument("--allow-stale", action="store_true",
                    help="stale allowlist entries warn instead of fail")
    ap.add_argument("--tier", choices=("ast", "semantic", "all"),
                    default="ast",
                    help="ast: install-free source scan (default); "
                         "semantic: jaxpr invariant verification + DMA "
                         "race sanitizer (needs jax); all: both")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the finding set as a JSON report "
                         "(CI artifact)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the seeded-violation fixtures instead of "
                         "scanning the tree")
    ap.add_argument("--psum-budget", type=int, default=1,
                    help="max psums per shard_map body path (default 1)")
    ap.add_argument("--vmem-cap-bytes", type=int,
                    default=16 * 1024 * 1024,
                    help="static VMEM estimate cap per kernel function")
    args = ap.parse_args()

    if args.self_test:
        return run_self_test(args.tier)

    findings = []
    if args.tier in ("ast", "all"):
        config = AnalysisConfig(psum_budget=args.psum_budget,
                                vmem_cap_bytes=args.vmem_cap_bytes)
        findings += analyze_paths(REPO, args.paths or None, config)
    if args.tier in ("semantic", "all"):
        if args.paths:
            print(f"{_TAG} note: the semantic tier always runs the "
                  f"full trace registry (path selection is ast-only)")
        _prepare_semantic_env()
        from repro.analysis.semantic import semantic_findings
        findings += semantic_findings()
    try:
        allow = load_json(args.allowlist, default={})
    except ValueError as e:
        print(f"{_TAG} allowlist {args.allowlist} is not valid JSON: "
              f"{e}", file=sys.stderr)
        return 2
    kept, allowed, stale = apply_allowlist(findings, allow)

    if args.json:
        dump_json(args.json, {
            "tier": args.tier,
            "findings": [{"rule": f.rule, "path": f.path,
                          "line": f.line, "message": f.message}
                         for f in findings],
            "kept": [f.key for f in kept],
            "allowlisted": [f.key for f in allowed],
            "stale": sorted(stale),
        })
        print(f"{_TAG} report -> {args.json}")

    if args.update:
        fresh = {}
        for f in findings:
            fresh.setdefault(
                f.key, allow.get(f.key,
                                 "ratcheted legacy finding; fix, then "
                                 "prune with --update"))
        dump_json(args.allowlist, fresh)
        print(f"{_TAG} allowlist <- {len(fresh)} entr"
              f"{'y' if len(fresh) == 1 else 'ies'} "
              f"({len(stale)} stale pruned) -> {args.allowlist}")
        return 0

    print(f"{_TAG} scanned tree: {len(findings)} finding(s), "
          f"{len(allowed)} allowlisted, {len(stale)} stale "
          f"allowlist entr{'y' if len(stale) == 1 else 'ies'}")
    rc = 0
    if kept:
        rc = 1
        for f in kept:
            print(f"  FINDING {f}")
        print(f"{_TAG} {len(kept)} finding(s): fix, add an inline "
              f"`# repro: ignore[rule]` with a justification, or "
              f"ratchet with --update")
    if stale:
        for key in stale:
            print(f"  stale allowlist entry: {key} "
                  f"({allow.get(key, '')!r})")
        if not args.allow_stale:
            print(f"{_TAG} stale entries fail the gate (the ratchet "
                  f"only moves forward) — prune with --update")
            rc = 1
    if rc == 0:
        print(f"{_TAG} OK: tree is clean under the committed allowlist")
    return rc


if __name__ == "__main__":
    sys.exit(main())
