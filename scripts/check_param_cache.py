"""CI guard for the engine_setup disk cache (benchmarks/common.py).

The bench-smoke job points REPRO_BENCH_CACHE at a workspace directory
so every bench process on the runner reuses one training run. This
script is the trust anchor for that reuse: it materializes the cache
(training at most once), then retrains from scratch with the disk
layer bypassed and asserts the cached and fresh setups are
bit-identical — same param leaves, same plan, and, end to end, the
same greedily decoded tokens through a ServeEngine. A stale or corrupt
cache (e.g. restored across a source change the cache key missed)
fails here instead of silently skewing every bench number downstream.

  REPRO_BENCH_CACHE=.bench-cache PYTHONPATH=src \
      python scripts/check_param_cache.py --train-steps 10
"""
from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--activation", default="relu2")
    ap.add_argument("--mode", default="relu")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=10,
                    help="must match the bench invocations sharing the "
                         "cache (tiny CI smoke trains 10 steps)")
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    if not os.environ.get("REPRO_BENCH_CACHE"):
        print("REPRO_BENCH_CACHE is not set; nothing to verify",
              file=sys.stderr)
        return 2

    import numpy as np
    from benchmarks.common import engine_setup, _setup_cache_path
    import jax

    key = (args.arch, args.activation, args.mode, args.seed,
           args.train_steps)
    path = _setup_cache_path(*key)

    # Pass 1 — through the cache: loads if the restored cache already
    # has this key, trains and writes it otherwise. Either way the
    # bench processes that follow will hit disk.
    cfg, model, params_c, plan_c, prompt = engine_setup(
        args.arch, activation=args.activation, mode=args.mode,
        seed=args.seed, train_steps=args.train_steps, cache=True)
    assert os.path.exists(path), f"cache file not written: {path}"

    # Pass 2 — fresh: disk layer bypassed, full retrain in-process.
    _, _, params_f, plan_f, _ = engine_setup(
        args.arch, activation=args.activation, mode=args.mode,
        seed=args.seed, train_steps=args.train_steps, cache=False)

    leaves_c = jax.tree.leaves(params_c)
    leaves_f = jax.tree.leaves(params_f)
    assert len(leaves_c) == len(leaves_f)
    for i, (a, b) in enumerate(zip(leaves_c, leaves_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"param leaf {i} differs "
                                              f"between cache and fresh")
    np.testing.assert_array_equal(plan_c.neuron_order, plan_f.neuron_order)

    # End-to-end: both param sets must decode identically (greedy).
    from repro.core.baselines import POWERINFER2
    from repro.serving.engine import ServeEngine

    def decode(params, plan):
        eng = ServeEngine(cfg, params, plan, spec=POWERINFER2,
                          offload_ratio=0.5, seed=args.seed)
        res = eng.generate(prompt, max_new=args.max_new, temperature=0.0)
        eng.close()
        return res.tokens

    tok_c = decode(params_c, plan_c)
    tok_f = decode(params_f, plan_f)
    assert np.array_equal(tok_c, tok_f), \
        f"cached vs fresh decode diverged:\n{tok_c}\n{tok_f}"
    print(f"OK param cache: {len(leaves_c)} leaves identical, "
          f"{tok_c.shape[0]}x{tok_c.shape[1]} greedy tokens identical "
          f"({path})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
